"""Fault tolerance demo: train, 'crash', auto-resume from the latest valid
checkpoint, finish — with identical data order after the restart.

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import shutil
import tempfile

from repro.launch.train import train_loop

ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
print(f"checkpoints -> {ckpt_dir}")

print("\n=== phase 1: run 12 of 24 steps, checkpoint every 5, then 'crash' ===")
r1 = train_loop("stablelm-3b", steps=12, batch=4, seq=16,
                ckpt_dir=ckpt_dir, ckpt_every=5)

print("\n=== phase 2: relaunch the same job — it resumes automatically ===")
r2 = train_loop("stablelm-3b", steps=24, batch=4, seq=16,
                ckpt_dir=ckpt_dir, ckpt_every=5)
assert r2.resumed_from is not None
print(f"\nresumed from step {r2.resumed_from}; "
      f"ran only {r2.steps_run} remaining steps; "
      f"final loss {r2.final_loss:.4f}")
shutil.rmtree(ckpt_dir)
