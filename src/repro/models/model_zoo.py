"""Model facade: arch name -> params / loss / prefill / decode + input specs.

``Model`` wraps the composable decoder (repro.models.transformer) behind the
four entry points the launcher lowers:
    loss(params, batch)             -- train_4k
    forward_logits(params, batch)   -- prefill_32k
    decode_step(params, batch, cache, t) -- decode_32k / long_500k
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape, get_arch
from repro.models import layers, transformer
from repro.models.transformer import RunConfig


class Model:
    def __init__(self, cfg: ArchConfig, rcfg: Optional[RunConfig] = None):
        self.cfg = cfg
        self.rcfg = rcfg or RunConfig()

    # -- params --------------------------------------------------------------

    def init(self, key):
        """Returns (param_values, param_logical_axes)."""
        leafs = transformer.init_params(key, self.cfg, self.rcfg)
        return layers.values(leafs), layers.axes(leafs)

    def abstract_params(self, key=None):
        """ShapeDtypeStruct params tree + logical axes (no allocation)."""
        key = key if key is not None else jax.random.key(0)
        shapes = jax.eval_shape(lambda k: self.init(k)[0], key)
        leafs = jax.eval_shape(
            lambda k: transformer.init_params(k, self.cfg, self.rcfg), key)
        # axes metadata survives eval_shape via the Leaf pytree aux data
        axes = jax.tree.map(
            lambda l: l.axes, leafs,
            is_leaf=lambda x: isinstance(x, layers.Leaf))
        return shapes, axes

    # -- entry points ----------------------------------------------------------

    def loss(self, params, batch):
        loss, metrics = transformer.loss_fn(params, batch, self.cfg, self.rcfg)
        return loss, metrics

    def forward_logits(self, params, batch):
        logits, _, _ = transformer.forward(params, batch, self.cfg, self.rcfg)
        return logits

    def prefill(self, params, batch):
        """Returns (last-token logits, populated cache) for serving."""
        logits, _, cache = transformer.forward(
            params, batch, self.cfg, self.rcfg, build_cache=True)
        return logits[:, -1], cache

    def init_cache(self, batch_size: int, max_seq: int):
        return transformer.init_cache(self.cfg, self.rcfg, batch_size, max_seq)

    def cache_axes(self):
        return transformer.cache_logical_axes(self.cfg)

    def decode_step(self, params, batch, cache, t):
        """One serving step: new token(s) at position t with a seq_len cache."""
        logits, _, new_cache = transformer.forward(
            params, batch, self.cfg, self.rcfg, cache=cache, t=t)
        return logits[:, 0], new_cache

    # -- input specs -----------------------------------------------------------

    def input_specs(self, shape: InputShape, *, dtype=jnp.int32):
        """ShapeDtypeStruct stand-ins for every model input of a cell."""
        B, S = shape.global_batch, shape.seq_len
        f32 = jnp.bfloat16 if self.rcfg.compute_dtype == jnp.bfloat16 else jnp.float32
        if shape.kind in ("train", "prefill"):
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), dtype)}
            if self.cfg.frontend:
                specs["embeds"] = jax.ShapeDtypeStruct(
                    (B, S, self.cfg.frontend_dim), f32)
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((B, S), dtype)
            return specs
        # decode: one new token; the KV/state cache covers seq_len positions.
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), dtype)}
        if self.cfg.frontend:
            specs["embeds"] = jax.ShapeDtypeStruct(
                (B, 1, self.cfg.frontend_dim), f32)
        return specs


def build_model(arch: str, rcfg: Optional[RunConfig] = None,
                *, reduced: bool = False) -> Model:
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    return Model(cfg, rcfg)
