"""Fault-tolerant checkpointing: atomic (tmp+rename), versioned, optionally
async (background thread), with auto-resume from the latest *valid* step.

Format: one .npz per checkpoint (flattened pytree with '/'-joined keys) +
a JSON manifest written LAST — a checkpoint without a manifest is treated
as torn and ignored on restore, so a node failure mid-write is harmless.
Elastic restore: arrays are loaded on host and re-sharded by the caller's
``device_put`` with the (possibly different) current mesh — checkpoint
layout is mesh-independent.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return _restore_lists(root)


def _restore_lists(node):
    if not isinstance(node, dict):
        return node
    if node and all(k.startswith("#") for k in node):
        items = sorted(node.items(), key=lambda kv: int(kv[0][1:]))
        return tuple(_restore_lists(v) for _, v in items)
    return {k: _restore_lists(v) for k, v in node.items()}


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ------------------------------------------------------------

    def save(self, step: int, tree: Any, *, blocking: Optional[bool] = None):
        """Device->host fetch happens synchronously (cheap vs. train step);
        serialization + fsync happen on a background thread."""
        host = jax.tree.map(lambda a: np.asarray(a), tree)
        self.wait()
        blocking = (not self.async_save) if blocking is None else blocking
        if blocking:
            self._write(step, host)
        else:
            self._pending = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._pending.start()

    def _write(self, step: int, host_tree):
        flat = _flatten(host_tree)
        path = os.path.join(self.dir, f"ckpt_{step:08d}")
        tmp = path + ".tmp.npz"
        np.savez(tmp, **flat)
        os.replace(tmp, path + ".npz")
        manifest = {"step": step, "time": time.time(),
                    "arrays": len(flat)}
        mtmp = path + ".manifest.tmp"
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
        os.replace(mtmp, path + ".manifest.json")
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = self.valid_steps()
        for s in steps[:-self.keep]:
            for suffix in (".npz", ".manifest.json"):
                p = os.path.join(self.dir, f"ckpt_{s:08d}{suffix}")
                if os.path.exists(p):
                    os.remove(p)

    # -- restore -----------------------------------------------------------

    def valid_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.endswith(".manifest.json"):
                step = int(name[len("ckpt_"):-len(".manifest.json")])
                if os.path.exists(os.path.join(
                        self.dir, f"ckpt_{step:08d}.npz")):
                    steps.append(step)
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.valid_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *, shardings=None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        path = os.path.join(self.dir, f"ckpt_{step:08d}.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten(flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return step, tree
