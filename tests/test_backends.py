"""Executor-backend subsystem: registry semantics and the equivalence
contract — every registered runner backend, on every config in a small
grid, must reproduce the single-stream host reference."""
import numpy as np
import pytest

from repro.core.backends import (REFERENCE_BACKEND, MeshBackend,
                                 StreamBackend, get_backend, list_backends,
                                 register_backend, split_arrays)
from repro.core.stream_config import SINGLE_STREAM, StreamConfig
from repro.core.streams import StreamedRunner
from repro.core.workloads import get_workload

# one shared-buffer-free, one shared-matrix, one shared-vector workload
EQUIV_WORKLOADS = ["vecadd", "sgemm", "mvmult"]
EQUIV_CONFIGS = [SINGLE_STREAM, StreamConfig(1, 4), StreamConfig(2, 2),
                 StreamConfig(4, 8)]


def _concat_outputs(runner, config):
    return np.concatenate(
        [np.asarray(o) for o in runner._dispatch(config)], axis=0)


@pytest.fixture(scope="module")
def references():
    """Single-stream reference outputs per workload, on the reference
    backend."""
    refs = {}
    for name in EQUIV_WORKLOADS:
        wl = get_workload(name)
        rng = np.random.default_rng(0)
        chunked, shared = wl.make_data(wl.datasets[0], rng)
        runner = StreamedRunner(wl, chunked, shared,
                                backend=REFERENCE_BACKEND)
        refs[name] = (chunked, shared, _concat_outputs(runner, SINGLE_STREAM))
    return refs


@pytest.mark.parametrize("backend", list_backends(kind="runner"))
@pytest.mark.parametrize("name", EQUIV_WORKLOADS)
def test_backend_matches_single_stream_reference(backend, name, references):
    chunked, shared, ref = references[name]
    wl = get_workload(name)
    runner = StreamedRunner(wl, chunked, shared, backend=backend)
    for cfg in EQUIV_CONFIGS:
        got = _concat_outputs(runner, cfg)
        # different chunk shapes change XLA's reduction order slightly
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=1e-3,
                                   err_msg=f"{backend} {name} {cfg}")


@pytest.mark.parametrize("backend", list_backends(kind="runner"))
def test_backend_output_count_and_timing(backend):
    wl = get_workload("vecadd")
    rng = np.random.default_rng(1)
    chunked, shared = wl.make_data(256, rng)
    runner = StreamedRunner(wl, chunked, shared, backend=backend)
    cfg = StreamConfig(2, 4)
    assert len(runner._dispatch(cfg)) == cfg.partitions * cfg.tasks
    t = runner.run(cfg, reps=1)
    assert 0 < t < 10.0


# -- registry ----------------------------------------------------------------


def test_registry_contents():
    assert set(list_backends(kind="runner")) >= {"host-sync",
                                                 "host-pipelined"}
    assert list_backends(kind="train-step") == ["mesh"]
    assert list_backends() == sorted(list_backends())
    assert get_backend("host-sync").kind == "runner"


def test_unknown_backend_raises():
    with pytest.raises(KeyError, match="unknown backend"):
        get_backend("no-such-backend")
    with pytest.raises(KeyError):
        StreamedRunner(get_workload("vecadd"), {"a": np.zeros((4, 2))},
                       {}, backend="no-such-backend")


def test_duplicate_registration_rejected():
    class Dup(StreamBackend):
        name = "host-sync"

    with pytest.raises(ValueError, match="already registered"):
        register_backend(Dup())


def test_runner_rejects_train_step_backend():
    wl = get_workload("vecadd")
    rng = np.random.default_rng(2)
    chunked, shared = wl.make_data(256, rng)
    with pytest.raises(ValueError, match="not a runner"):
        StreamedRunner(wl, chunked, shared, backend="mesh")


def test_mesh_backend_is_not_a_runner():
    with pytest.raises(NotImplementedError):
        MeshBackend().dispatch(None, SINGLE_STREAM)


def test_split_arrays_roundtrip():
    arrs = {"a": np.arange(12).reshape(12, 1)}
    parts = split_arrays(arrs, 4)
    assert len(parts) == 4
    np.testing.assert_array_equal(
        np.concatenate([p["a"] for p in parts]), arrs["a"])


def test_custom_backend_pluggable():
    """A third-party backend registers, runs, and matches the reference."""

    class ReversedTasksBackend(StreamBackend):
        # dispatches tasks in reverse but returns outputs in task order —
        # exercises that only output ORDER is part of the contract
        name = "test-reversed"
        kind = "runner"

        def dispatch(self, ctx, config):
            import jax
            tasks = split_arrays(ctx.chunked, config.tasks)
            outs = [None] * len(tasks)
            for i in reversed(range(len(tasks))):
                dev = jax.device_put(tasks[i], ctx.device)
                outs[i] = [ctx.jit_kernel(p, ctx.shared_dev)
                           for p in split_arrays(dev, config.partitions)]
            return [o for task in outs for o in task]

    try:
        register_backend(ReversedTasksBackend())
        wl = get_workload("vecadd")
        rng = np.random.default_rng(3)
        chunked, shared = wl.make_data(256, rng)
        ref = _concat_outputs(
            StreamedRunner(wl, chunked, shared), SINGLE_STREAM)
        runner = StreamedRunner(wl, chunked, shared,
                                backend="test-reversed")
        got = _concat_outputs(runner, StreamConfig(2, 4))
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=1e-3)
    finally:
        from repro.core import backends as bk
        bk._BACKENDS.pop("test-reversed", None)
