"""Back-compat shim: moved to :mod:`repro.core.modeling.dataset`.

Note for monkeypatchers: rebinding names here (e.g. ``grid_for``) does
NOT affect the real call sites inside the modeling package — patch
``repro.core.modeling.dataset`` instead (tests/conftest.py does)."""
from repro.core.modeling.dataset import (DEFAULT_CACHE, Sample,
                                         default_cache_path, generate,
                                         grid_for, loo_split,
                                         profile_sample, training_matrix)

__all__ = ["DEFAULT_CACHE", "default_cache_path", "Sample", "generate",
           "grid_for", "loo_split", "profile_sample", "training_matrix"]
