import os
import sys

import pytest

# tests must see the real single CPU device (the 512-device flag is only
# ever set inside launch/dryrun.py's own process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


@pytest.fixture(autouse=True, scope="session")
def fast_profile_defaults():
    """Shrink profiling defaults so test runs stay under budget.

    Any test that profiles through ``dataset.grid_for`` — fast or slow
    tier — gets a 4x8 config grid instead of the paper's 32x64 sweep.
    Set REPRO_FULL_PROFILE=1 to restore the full grid; the real sweep
    lives in ``benchmarks/run.py``, which does not run under pytest and
    is unaffected.
    """
    if os.environ.get("REPRO_FULL_PROFILE"):
        yield
        return
    # patch the real module, not the repro.core.dataset shim: the call
    # sites (profile_sample) resolve grid_for in the modeling namespace
    from repro.core.modeling import dataset

    orig_grid_for = dataset.grid_for

    def small_grid(n_rows, max_partitions=4, max_tasks=8):
        return orig_grid_for(n_rows, max_partitions, max_tasks)

    dataset.grid_for = small_grid
    try:
        yield
    finally:
        dataset.grid_for = orig_grid_for
