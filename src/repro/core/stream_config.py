"""Stream configuration: the (#partitions, #tasks) pair the paper tunes.

Two realizations of the same concept (DESIGN.md §2):
  host backend  — #tasks   = transfer/compute pipeline chunks,
                  #partitions = per-task kernel sub-slices (cache blocking +
                  dispatch granularity);   used by the CPU reproduction.
  mesh backend  — #tasks   = microbatches per training step (grad-accum
                  pipeline), #partitions = sub-meshes of the data axis;
                  used at pod scale.
"""
from __future__ import annotations

import dataclasses
import itertools


@dataclasses.dataclass(frozen=True, order=True)
class StreamConfig:
    partitions: int
    tasks: int

    def __post_init__(self):
        assert self.partitions >= 1 and self.tasks >= 1

    @property
    def single_stream(self) -> bool:
        return self.partitions == 1 and self.tasks == 1

    def as_tuple(self) -> tuple[int, int]:
        return (self.partitions, self.tasks)

    # JSON forms used by the persistent tuning cache
    def to_json(self) -> list[int]:
        return [self.partitions, self.tasks]

    @staticmethod
    def from_json(d) -> "StreamConfig":
        p, t = d
        return StreamConfig(int(p), int(t))


SINGLE_STREAM = StreamConfig(1, 1)


def default_space(
    max_partitions: int = 32,
    max_tasks: int = 64,
) -> list[StreamConfig]:
    """The candidate grid searched at runtime (paper §3.1.2: 1..224 x 1..256
    on XeonPhi; powers of two here to keep the CPU profile budget sane —
    the model itself accepts ANY configuration, including off-grid ones)."""
    parts = _pow2_upto(max_partitions)
    tasks = _pow2_upto(max_tasks)
    return [StreamConfig(p, t) for p, t in itertools.product(parts, tasks)]


def dense_space(max_partitions: int = 16, max_tasks: int = 64,
                step: int = 1) -> list[StreamConfig]:
    """A denser grid used to demonstrate generalization to configs that
    were never profiled during training (regression-model advantage)."""
    return [
        StreamConfig(p, t)
        for p in range(1, max_partitions + 1, step)
        for t in range(1, max_tasks + 1, step)
        if t >= p  # fewer tasks than partitions leaves partitions idle
    ]


def _pow2_upto(n: int) -> list[int]:
    out = []
    v = 1
    while v <= n:
        out.append(v)
        v *= 2
    return out
