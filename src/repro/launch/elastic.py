"""Elastic scaling: remesh a running job when the healthy device count
changes (node failure / capacity add).

The checkpoint format is mesh-independent (host numpy trees), so elastic
restore = rebuild mesh from the surviving devices -> rebuild shardings from
the same logical axis rules -> device_put the restored tree.  This module
provides the remesh planning + a simulated-failure harness used by tests
(CPU: device counts simulated via sub-meshes).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.parallel.sharding_rules import AxisRules, tree_specs


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    data: int
    model: int

    @property
    def size(self) -> int:
        return self.data * self.model


def plan_remesh(n_devices: int, *, prefer_model: int) -> MeshPlan:
    """Choose a (data, model) factorization for the surviving devices:
    keep the model axis as close to `prefer_model` as divisibility allows
    (TP degree is constrained by weight shapes), put the rest on data."""
    model = min(prefer_model, n_devices)
    while n_devices % model:
        model -= 1
    return MeshPlan(data=n_devices // model, model=model)


def build_mesh(plan: MeshPlan, devices=None):
    devices = devices if devices is not None else jax.devices()
    assert len(devices) >= plan.size, (len(devices), plan.size)
    arr = np.array(devices[: plan.size]).reshape(plan.data, plan.model)
    return jax.sharding.Mesh(arr, ("data", "model"))


def reshard_tree(host_tree, axes_tree, mesh, rules: Optional[AxisRules] = None):
    """device_put a host (numpy) pytree with shardings from logical axes.

    Elastic meshes can have odd axis sizes (e.g. 6 devices -> model=3);
    dims that no longer divide gracefully degrade to replication."""
    rules = rules or AxisRules.pod()
    specs = tree_specs(axes_tree, rules)

    def put(arr, spec):
        fitted = []
        for dim, ax in zip(arr.shape, tuple(spec) + (None,) * arr.ndim):
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,) if ax else ()):
                size *= mesh.shape[a]
            fitted.append(ax if dim % size == 0 else None)
        return jax.device_put(
            arr, NamedSharding(mesh, type(spec)(*fitted)))

    return jax.tree.map(put, host_tree, specs)


def simulate_failure_and_remesh(host_tree, axes_tree, *, old_mesh,
                                lost_devices: int, prefer_model: int):
    """Test harness: drop `lost_devices`, replan, reshard. Returns
    (new_mesh, resharded_tree)."""
    survivors = [d for d in old_mesh.devices.flatten()][
        : old_mesh.size - lost_devices]
    plan = plan_remesh(len(survivors), prefer_model=prefer_model)
    new_mesh = build_mesh(plan, survivors)
    return new_mesh, reshard_tree(host_tree, axes_tree, new_mesh)
