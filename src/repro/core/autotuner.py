"""End-to-end runtime autotuner (paper Fig. 4): features -> model ->
ranked configs -> StreamConfig, in milliseconds, per program x dataset.

New in the backend refactor: a **persistent tuning cache**.  Feature
extraction profiles the workload for a few iterations, which is fine at
tuning time but not at serving time; the cache memoizes ``TuneResult``s
keyed by (workload name, shape-bucketed data signature, backend) and
round-trips through JSON, so a serving process warm-starts a previously
seen (program, dataset-bucket) in microseconds instead of re-profiling —
the runtime-deployment story of paper Fig. 4 at production request rates.

Also hosts the pod-scale face of the technique: ``rank_by_roofline``
scores (mesh factorization x microbatch) candidates for a training step
from dry-run roofline features — the TPU-native generalization where
"profiling" is exact static analysis (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import warnings
from typing import Optional, Sequence

import numpy as np

from repro.core import features as feat_lib
from repro.core.modeling.perf_model import PerformanceModel
from repro.core.modeling.search import search_best
from repro.core.stream_config import StreamConfig, default_space
from repro.core.streams import StreamedRunner
from repro.core.workloads import Workload


@dataclasses.dataclass
class TuneResult:
    config: StreamConfig
    predicted_speedup: float
    feature_seconds: float
    search_seconds: float
    backend: str = "host-sync"
    cached: bool = False
    #: provenance: "model" = ranked by the performance model;
    #: "refined" = re-profiled by the serving drift-refinement loop, so
    #: predicted_speedup is a *measured* speedup, not a model output
    source: str = "model"

    def to_json(self) -> dict:
        return {
            "config": self.config.to_json(),
            "predicted_speedup": self.predicted_speedup,
            "feature_seconds": self.feature_seconds,
            "search_seconds": self.search_seconds,
            "backend": self.backend,
            "source": self.source,
        }

    @staticmethod
    def from_json(d: dict) -> "TuneResult":
        return TuneResult(
            config=StreamConfig.from_json(d["config"]),
            predicted_speedup=float(d["predicted_speedup"]),
            feature_seconds=float(d["feature_seconds"]),
            search_seconds=float(d["search_seconds"]),
            backend=d.get("backend", "host-sync"),
            source=d.get("source", "model"),
        )


# ---------------------------------------------------------------------------
# Persistent tuning cache
# ---------------------------------------------------------------------------


def quarantine_file(path) -> Optional[str]:
    """Move a corrupt persisted file aside (``<path>.corrupt``,
    ``.corrupt-1``, ...) so the caller can rebuild from empty while the
    evidence survives for inspection.  Returns the quarantine path, or
    None if the file vanished underneath us."""
    path = str(path)
    if not os.path.exists(path):
        return None
    n = 0
    while True:
        dest = f"{path}.corrupt" + (f"-{n}" if n else "")
        if not os.path.exists(dest):
            break
        n += 1
    os.replace(path, dest)
    return dest


def shape_bucket(n: int) -> int:
    """Round the leading (iteration-space) dim up to a power of two.

    Serving traffic rarely repeats exact batch sizes; bucketed keys make
    every request in (2^k, 2^(k+1)] share one tuning entry, trading at
    most one octave of shape mismatch for a 100%-hit steady state."""
    n = int(n)
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def data_signature(chunked: dict, shared: dict) -> str:
    """Canonical shape/dtype signature with the chunked leading dim
    bucketed (inner dims and shared buffers are part of the program, so
    they stay exact)."""
    def one(d: dict, bucket_rows: bool) -> list:
        items = []
        for k in sorted(d):
            a = d[k]
            shape = list(a.shape)
            if bucket_rows and shape:
                shape[0] = shape_bucket(shape[0])
            items.append([k, shape, str(a.dtype)])
        return items

    return json.dumps({"chunked": one(chunked, True),
                       "shared": one(shared, False)},
                      separators=(",", ":"))


class TuningCache:
    """(workload, signature, backend) -> TuneResult, with JSON persistence.

    Typical deployment flow::

        cache = TuningCache("tuning_cache.json")   # warm-start if present
        tuner = AutoTuner(model, cache=cache)
        ...serve...
        cache.save()                               # persist new entries
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._entries: dict[str, TuneResult] = {}
        self.hits = 0
        self.misses = 0
        #: path the corrupt file was moved to, if a load quarantined one
        self.quarantined: Optional[str] = None
        if path and os.path.exists(path):
            try:
                self.load(path)
            except (OSError, ValueError, KeyError, TypeError) as e:
                # corrupt/unreadable cache ==> quarantine-and-rebuild,
                # not a crash: the damaged file moves aside (evidence
                # survives; the next save() atomically writes a fresh
                # one) and serving cold-starts
                self._entries.clear()
                self.quarantined = quarantine_file(path)
                warnings.warn(
                    f"unreadable tuning cache {path} ({e}); quarantined "
                    f"to {self.quarantined} and rebuilding empty")

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def key(workload: str, chunked: dict, shared: dict, backend: str,
            model_tag: str = "", namespace: str = "") -> str:
        """Cache key, optionally prefixed with a tenant ``namespace``.

        An empty namespace yields the exact pre-tenancy key format, so
        persisted caches written before isolation existed keep hitting.
        Namespaced entries share the file but never collide across
        tenants — the serving scheduler's per-tenant cache isolation."""
        base = (f"{workload}|{backend}|{model_tag}|"
                f"{data_signature(chunked, shared)}")
        return f"tenant:{namespace}|{base}" if namespace else base

    def keys(self) -> list[str]:
        return list(self._entries)

    def peek(self, key: str) -> Optional[TuneResult]:
        """Raw lookup WITHOUT hit/miss accounting — for introspection
        (the resilience layer's nearest-bucket scan), not serving."""
        return self._entries.get(key)

    def get(self, key: str, *, valid=None) -> Optional[TuneResult]:
        """Stats-counted lookup; an entry failing the ``valid`` predicate
        counts as a miss (the caller will re-tune)."""
        hit = self._entries.get(key)
        if hit is not None and (valid is None or valid(hit)):
            self.hits += 1
            return hit
        self.misses += 1
        return None

    def put(self, key: str, result: TuneResult) -> None:
        self._entries[key] = result

    def invalidate(self, key: str) -> Optional[TuneResult]:
        """Drop an entry (drift refinement evicts before re-profiling so a
        concurrent reader re-tunes rather than serving the stale config)."""
        return self._entries.pop(key, None)

    # -- persistence ---------------------------------------------------------

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        assert path, "no cache path given"
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({k: r.to_json() for k, r in self._entries.items()},
                      f, indent=0)
            f.flush()
            os.fsync(f.fileno())   # crash-safe: rename lands AFTER the data
        os.replace(tmp, path)
        return path

    def load(self, path: Optional[str] = None) -> "TuningCache":
        path = path or self.path
        with open(path) as f:
            raw = json.load(f)
        self._entries.update(
            {k: TuneResult.from_json(v) for k, v in raw.items()})
        return self


class AutoTuner:
    def __init__(self, model: PerformanceModel,
                 candidates: Optional[Sequence[StreamConfig]] = None,
                 *, cache: Optional[TuningCache] = None,
                 backend: str = "host-sync", model_tag: str = ""):
        # ``model_tag`` should name the model version when the cache is
        # persistent — entries are keyed by it, so retraining the model
        # under a new tag invalidates old configs instead of serving them.
        self.model = model
        self.candidates = list(candidates or default_space())
        self.cache = cache
        self.backend = backend
        self.model_tag = model_tag

    def tune(self, wl: Workload, chunked: dict, shared: dict,
             *, runner: Optional[StreamedRunner] = None) -> TuneResult:
        n_rows = next(iter(chunked.values())).shape[0]
        backend = runner.backend.name if runner is not None else self.backend
        if self.cache is not None:
            key = self.cache.key(wl.name, chunked, shared, backend,
                                 self.model_tag)
            # shape bucketing can hand back a config tuned on a larger
            # batch in the same bucket; only honor it if it is still
            # splittable for THIS batch, else re-tune (and overwrite the
            # entry with the more conservative config).
            hit = self.cache.get(key, valid=lambda r: (
                r.config.partitions * r.config.tasks <= n_rows))
            if hit is not None:
                return dataclasses.replace(hit, cached=True)
        t0 = time.perf_counter()
        runner = runner or StreamedRunner(wl, chunked, shared,
                                          backend=backend)
        feats = feat_lib.extract_features(runner, profile_reps=1)
        t_feat = time.perf_counter() - t0
        # guard: an empty filtered list would make search_best fall back
        # to the FULL default grid and return an unsplittable config
        cands = [c for c in self.candidates
                 if c.partitions * c.tasks <= n_rows] or [StreamConfig(1, 1)]
        best, preds, t_search = search_best(self.model, feats.values, cands)
        result = TuneResult(best, float(np.max(preds)), t_feat, t_search,
                            backend=backend)
        if self.cache is not None:
            self.cache.put(key, result)
        return result


# ---------------------------------------------------------------------------
# Pod-scale candidate ranking (mesh backend)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshCandidate:
    """A pod-scale 'stream configuration': how the fixed chip grid is
    factorized (spatial) and how many microbatches per step (temporal)."""

    data: int
    model: int
    microbatches: int

    @property
    def stream_config(self) -> StreamConfig:
        return StreamConfig(self.data, self.microbatches)


def rank_by_roofline(candidates, terms: dict) -> list:
    """Rank MeshCandidates by their dry-run roofline makespan estimate.

    ``terms`` maps candidate -> dict(compute=, memory=, collective=) in
    seconds (from repro.roofline.analysis).  The makespan model assumes the
    collective term overlaps compute up to the dominant-term bound — the
    same overlap objective the paper's model learns.
    """
    def makespan(c):
        t = terms[c]
        return max(t["compute"], t["memory"]) + max(
            0.0, t["collective"] - 0.5 * max(t["compute"], t["memory"]))

    return sorted(candidates, key=makespan)
