"""Fleet data plane: wire codec, framing edges, event-driven collect.

Pure tests pin the wire schema (positional rows must match the
TelemetrySample dataclass field for field), the frame codec (version
guard, size-window splitting), the worker-side serve folding, and the
adaptive dispatch chunking.  Real-process tests cover the corners the
overhaul introduced: dispatch_chunk=1 (every request its own task
message), a batch smaller than the chunk, a result frame racing a
SIGKILL (no lost or duplicated terminals), and the legacy wire escape
hatch.  A source-level test keeps the hot path honest: no time.sleep
polling anywhere in fleet/."""
import dataclasses
import os
import queue as queue_mod
import types

import pytest

from repro.serving import FleetRouter, WorkerConfig, make_trace, shard_for
from repro.serving.fleet import aggregate as fleet_aggregate
from repro.serving.fleet import wire
from repro.serving.fleet.router import DISPATCH_FLOOR, MAX_DISPATCH_CHUNK
from repro.serving.fleet.worker import _drain_serve
from repro.serving.telemetry import WIRE_FIELDS, TelemetrySample


def _sample(**kw):
    base = dict(seq=7, tenant="tenant-3", workload="vecadd", key="vecadd",
                backend="host-sync", partitions=4, tasks=8, cache_hit=True,
                predicted_s=0.01, measured_s=0.012, rel_error=0.2,
                status="ok", trace_id="r000007", worker="w1")
    base.update(kw)
    return TelemetrySample(**base)


# -- wire schema --------------------------------------------------------------


def test_wire_fields_cover_the_dataclass_exactly():
    """The positional row IS the schema: WIRE_FIELDS must list every
    TelemetrySample field in declaration order — a field added to the
    dataclass but not the tuple would silently fall off the wire."""
    assert WIRE_FIELDS == tuple(
        f.name for f in dataclasses.fields(TelemetrySample))


def test_sample_row_roundtrip_and_forward_compat():
    s = _sample()
    assert TelemetrySample.from_row(s.to_row()) == s
    # a row from an OLDER worker (fewer trailing fields) rehydrates
    # with defaults for the missing tail — append-only evolution
    short = s.to_row()[:-2]
    back = TelemetrySample.from_row(short)
    assert back.trace_id is None and back.worker is None
    assert back.seq == s.seq and back.measured_s == s.measured_s


def test_resolve_wire_mode_explicit_env_and_unknown(monkeypatch):
    monkeypatch.delenv(wire.WIRE_ENV_VAR, raising=False)
    assert wire.resolve_wire_mode("auto") == "v2"
    assert wire.resolve_wire_mode("legacy") == "legacy"
    monkeypatch.setenv(wire.WIRE_ENV_VAR, "legacy")
    assert wire.resolve_wire_mode("auto") == "legacy"
    assert wire.resolve_wire_mode("v2") == "v2"   # explicit beats env
    with pytest.raises(ValueError, match="unknown fleet wire mode"):
        wire.resolve_wire_mode("v3")


def test_results_frame_roundtrip_and_version_guard():
    items = [("r000001", _sample().to_row())]
    frame = wire.make_results_frame("w0", 0.25, items)
    assert frame[0] == "results" and frame[2] == wire.WIRE_VERSION
    busy, back = wire.parse_results_frame(frame)
    assert busy == 0.25 and back == items

    stale = ("results", "w0", wire.WIRE_VERSION + 1, 0.0, [])
    with pytest.raises(wire.WireProtocolError, match="wire version"):
        wire.parse_results_frame(stale)


def test_split_frames_size_window():
    batch = list(range(5))
    assert [list(f) for f in wire.split_frames(batch, 2)] \
        == [[0, 1], [2, 3], [4]]
    assert [list(f) for f in wire.split_frames(batch, 99)] == [batch]
    # degenerate frame_max clamps to 1 instead of looping forever
    assert [list(f) for f in wire.split_frames([1, 2], 0)] == [[1], [2]]
    assert list(wire.split_frames([], 4)) == []


def test_payload_from_sample_rehydrates_the_legacy_shape():
    p = fleet_aggregate.payload_from_sample(_sample())
    assert p["status"] == "served"          # "ok" maps back
    assert p["config"] == [4, 8]
    assert p["cache_hit"] is True and p["tenant"] == "tenant-3"
    assert p["sample"]["worker"] == "w1"
    # partitions == 0 means no config was ever decided
    p = fleet_aggregate.payload_from_sample(
        _sample(partitions=0, tasks=0, status="failed", error="boom"))
    assert p["status"] == "failed" and p["config"] is None
    assert p["error"] == "boom"


# -- worker-side folding / router-side chunking -------------------------------


def test_drain_serve_folds_until_first_control_message():
    q = queue_mod.Queue()     # same Empty semantics as the mp queue
    q.put(("serve", [("t1", "r1")]))
    q.put(("serve", [("t2", "r2"), ("t3", "r3")]))
    q.put(("refresh", "latest"))
    q.put(("serve", [("t4", "r4")]))     # after the control: NOT folded
    batch, ctrl = _drain_serve(q, [("t0", "r0")])
    assert [t for t, _ in batch] == ["t0", "t1", "t2", "t3"]
    assert ctrl == ("refresh", "latest")
    assert q.get_nowait() == ("serve", [("t4", "r4")])

    batch, ctrl = _drain_serve(q, [])
    assert batch == [] and ctrl is None      # empty queue ends the drain


def test_adaptive_dispatch_chunk_tracks_queue_depth():
    r = FleetRouter.__new__(FleetRouter)     # no processes needed
    r.dispatch_chunk = None                  # default: adaptive
    r.n_workers = 2
    r._slots = [None, None]
    assert r._chunk_for_depth(0) == DISPATCH_FLOOR
    assert r._chunk_for_depth(6) == DISPATCH_FLOOR   # shallow: floor wins
    assert r._chunk_for_depth(100) == 50     # deep: an even share each
    assert r._chunk_for_depth(10_000) == MAX_DISPATCH_CHUNK
    r.dispatch_chunk = 1                     # explicit: pinned, not adapted
    assert r._chunk_for_depth(10_000) == 1


def test_truncated_frame_eofs_instead_of_hanging():
    """A SIGKILL mid-send leaves a partial frame: a length header whose
    promised bytes never arrive.  Because the router holds no write end,
    the reader sees EOF — _drain_slot must return, not block or raise."""
    import multiprocessing

    reader, writer = multiprocessing.Pipe(duplex=False)
    # 4-byte big-endian length header claiming 4096 bytes, then death
    os.write(writer.fileno(), (4096).to_bytes(4, "big") + b"\x80\x04")
    writer.close()
    slot = types.SimpleNamespace(conn=reader, label="w0")
    r = FleetRouter.__new__(FleetRouter)
    assert FleetRouter._drain_slot(r, slot) is False
    reader.close()


def test_no_sleep_polls_left_in_fleet_sources():
    """The tentpole claim, enforced at the source level: the fleet data
    plane is event-driven — nothing in fleet/ sleeps in a loop."""
    import repro.serving.fleet as fleet_pkg
    pkg_dir = os.path.dirname(fleet_pkg.__file__)
    for fname in sorted(os.listdir(pkg_dir)):
        if fname.endswith(".py"):
            with open(os.path.join(pkg_dir, fname)) as f:
                assert "time.sleep" not in f.read(), \
                    f"sleep-poll reintroduced in fleet/{fname}"


# -- real worker processes ----------------------------------------------------


def test_dispatch_chunk_one_and_batch_smaller_than_chunk():
    """Framing edges end to end: dispatch_chunk=1 puts every request in
    its own task message (max pipelining, most frames), then a single
    submitted request rides a batch far smaller than the chunk — both
    must retire every request exactly once."""
    reqs = make_trace(["vecadd"], occurrences=6, tenants=8, scale_index=0)
    with FleetRouter(2, worker=WorkerConfig(model="heuristic"),
                     dispatch_chunk=1) as fr:
        fr.submit_all(reqs)
        results = fr.run()
        assert len(results) == len(reqs)
        assert all(r["status"] in ("served", "degraded") for r in results)
        assert fr.stats["dispatch_frames"] == len(reqs)   # one per request

        lone = make_trace(["vecadd"], occurrences=1, tenants=8,
                          scale_index=0, seed=3)
        fr.submit_all(lone)
        again = fr.run()
        assert len(again) == 1
        assert again[0]["status"] in ("served", "degraded")
        assert fr.stats["duplicate_results"] == 0
        assert fr.last_run["ipc_overhead_fraction"] is not None
        assert 0.0 <= fr.last_run["ipc_overhead_fraction"] <= 1.0


def test_result_frame_racing_sigkill_loses_and_duplicates_nothing():
    """frame_max=2 forces several frames per engine run, and the kill
    fires after the first results land — the victim dies with frames
    and un-acked work in flight.  The at-least-once contract must hold
    exactly: every admitted request terminal, first ack wins."""
    reqs = make_trace(["vecadd"], occurrences=12, tenants=8, scale_index=0)
    with FleetRouter(2, worker=WorkerConfig(model="heuristic", frame_max=2)
                     ) as fr:
        fr.submit_all(reqs)
        fr.inject_kill(fr.shard_for("tenant-0"), after_results=1)
        results = fr.run()

        assert len(results) == len(reqs)                  # nothing lost
        seen_tokens = {r["sample"]["trace_id"] for r in results}
        assert len(seen_tokens) == len(reqs)              # nothing doubled
        assert all(r["status"] in ("served", "degraded", "failed")
                   for r in results)
        assert fr.stats["injected_kills"] == 1
        assert fr.stats["worker_deaths"] == 1
        assert fr.stats["worker_respawns"] == 1
    assert fr.summary()["requests"] == len(reqs)


def test_legacy_wire_end_to_end(tmp_path):
    """REPRO_FLEET_WIRE=legacy / WorkerConfig(wire='legacy'): the
    per-request payload-dict wire still works and produces the same
    payload shape; busy accounting is unavailable, so the ipc fraction
    reports unknown rather than a made-up number."""
    reqs = make_trace(["vecadd"], occurrences=6, tenants=8, scale_index=0)
    with FleetRouter(2, worker=WorkerConfig(model="heuristic",
                                            wire="legacy")) as fr:
        fr.submit_all(reqs)
        results = fr.run()
        assert len(results) == len(reqs)
        for r in results:
            assert r["status"] in ("served", "degraded")
            s = TelemetrySample.from_json(r["sample"])
            assert s.worker == f"w{shard_for(s.tenant, 2)}"
        assert fr.last_run["ipc_overhead_fraction"] is None
        assert fr.summary()["ipc_overhead_fraction"] is None
