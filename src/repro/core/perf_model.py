"""The learned performance model (paper §3) in pure JAX + numpy.

Pipeline (faithful to §3.2.1-§3.2.2, §6.6.2-§6.6.3):
  raw program features ++ config encoding
    -> Z-score standardization
    -> correlation pruning (|Pearson rho| > 0.7 drops the later feature)
    -> PCA (9 components; paper: "PCA with 9 components gives the best
       overall result")
    -> MLP regression, 3 hidden layers x 9 neurons, tanh, adam
  target: speedup over single-stream, Z-score standardized.

Alternative learners for the Table-5 comparison live here too: a CART
regression tree, a bagged random forest, RBF kernel ridge regression (the
closed-form stand-in for the paper's SVR — no sklearn offline), and
k-nearest-neighbour / tree / MLP classifiers over merged config labels.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.features import config_feature_matrix

# ---------------------------------------------------------------------------
# Feature pipeline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FeaturePipeline:
    mean: np.ndarray
    std: np.ndarray
    keep_idx: np.ndarray          # surviving columns after pruning
    pca_components: np.ndarray    # (kept, n_comp)
    pca_mean: np.ndarray
    y_mean: float
    y_std: float

    @staticmethod
    def fit(X: np.ndarray, y: np.ndarray, *, n_components: int = 9,
            corr_threshold: float = 0.7) -> "FeaturePipeline":
        mean = X.mean(axis=0)
        std = X.std(axis=0)
        std[std < 1e-12] = 1.0
        Z = (X - mean) / std

        # correlation pruning: keep the earlier feature of any |rho|>0.7 pair
        n = Z.shape[1]
        with np.errstate(divide="ignore", invalid="ignore"):
            corr = np.corrcoef(Z, rowvar=False)
        corr = np.nan_to_num(corr)
        keep: list[int] = []
        for j in range(n):
            if all(abs(corr[j, i]) <= corr_threshold for i in keep):
                keep.append(j)
        keep_idx = np.array(keep, dtype=np.int64)
        Zk = Z[:, keep_idx]

        # PCA
        n_comp = min(n_components, Zk.shape[1])
        pca_mean = Zk.mean(axis=0)
        Zc = Zk - pca_mean
        _, _, vt = np.linalg.svd(Zc, full_matrices=False)
        components = vt[:n_comp].T  # (kept, n_comp)

        y_mean, y_std = float(y.mean()), float(max(y.std(), 1e-9))
        return FeaturePipeline(mean, std, keep_idx, components, pca_mean,
                               y_mean, y_std)

    def transform(self, X: np.ndarray) -> np.ndarray:
        Z = (X - self.mean) / self.std
        Zk = Z[:, self.keep_idx]
        return (Zk - self.pca_mean) @ self.pca_components

    def transform_y(self, y: np.ndarray) -> np.ndarray:
        return (y - self.y_mean) / self.y_std

    def inverse_y(self, yn: np.ndarray) -> np.ndarray:
        return yn * self.y_std + self.y_mean


# ---------------------------------------------------------------------------
# MLP (pure JAX)
# ---------------------------------------------------------------------------


def _init_mlp(key, in_dim: int, hidden: Sequence[int] = (9, 9, 9)):
    dims = [in_dim, *hidden, 1]
    params = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, k = jax.random.split(key)
        w = jax.random.normal(k, (a, b)) * np.sqrt(2.0 / a)
        params.append({"w": w, "b": jnp.zeros((b,))})
    return params


def _mlp_forward(params, x):
    h = x
    for layer in params[:-1]:
        h = jnp.tanh(h @ layer["w"] + layer["b"])
    out = h @ params[-1]["w"] + params[-1]["b"]
    return out[..., 0]


@jax.jit
def _mse(params, X, y):
    pred = _mlp_forward(params, X)
    return jnp.mean((pred - y) ** 2)


def _adam_train(params, X, y, *, lr=1e-2, epochs=600, seed=0):
    opt_m = jax.tree.map(jnp.zeros_like, params)
    opt_v = jax.tree.map(jnp.zeros_like, params)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)

    @jax.jit
    def step(i, params, m, v):
        loss, g = jax.value_and_grad(_mse)(params, Xj, yj)
        m = jax.tree.map(lambda m_, g_: 0.9 * m_ + 0.1 * g_, m, g)
        v = jax.tree.map(lambda v_, g_: 0.999 * v_ + 0.001 * g_**2, v, g)
        mh = jax.tree.map(lambda m_: m_ / (1 - 0.9 ** (i + 1)), m)
        vh = jax.tree.map(lambda v_: v_ / (1 - 0.999 ** (i + 1)), v)
        params = jax.tree.map(
            lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + 1e-8),
            params, mh, vh)
        return loss, params, m, v

    loss = None
    for i in range(epochs):
        loss, params, opt_m, opt_v = step(i, params, opt_m, opt_v)
    return params, float(loss)


# ---------------------------------------------------------------------------
# The regression performance model (ours)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PerformanceModel:
    pipeline: FeaturePipeline
    mlp_params: list
    hidden: tuple = (9, 9, 9)

    @staticmethod
    def train(X_raw: np.ndarray, y_speedup: np.ndarray, *,
              hidden=(9, 9, 9), n_components: int = 9, epochs: int = 600,
              lr: float = 1e-2, seed: int = 0) -> "PerformanceModel":
        """X_raw rows = program features ++ config encoding; y = speedup."""
        pipe = FeaturePipeline.fit(X_raw, y_speedup, n_components=n_components)
        X = pipe.transform(X_raw)
        y = pipe.transform_y(y_speedup)
        params = _init_mlp(jax.random.key(seed), X.shape[1], hidden)
        params, _ = _adam_train(params, X, y, lr=lr, epochs=epochs, seed=seed)
        return PerformanceModel(pipe, params, tuple(hidden))

    def predict(self, X_raw: np.ndarray) -> np.ndarray:
        X = self.pipeline.transform(np.atleast_2d(X_raw))
        yn = np.asarray(_mlp_forward(self.mlp_params, jnp.asarray(X)))
        return self.pipeline.inverse_y(yn)

    def refit(self, X_raw: np.ndarray, y_speedup: np.ndarray, *,
              epochs: int = 150, lr: float = 3e-3) -> float:
        """Incremental online refit: continue adam from the current
        parameters on freshly *measured* (features ++ config, speedup)
        rows.  The feature pipeline stays frozen so the input space is
        stable across refits; only the MLP moves.  This is the serving
        drift-correction hook — a few hundred cheap steps on a handful of
        rows, not a retrain.  Returns the final training loss."""
        X = self.pipeline.transform(np.atleast_2d(np.asarray(X_raw, float)))
        yn = self.pipeline.transform_y(
            np.asarray(y_speedup, float).reshape(-1))
        self.mlp_params, loss = _adam_train(self.mlp_params, X, yn,
                                            lr=lr, epochs=epochs)
        return float(loss)

    def fork(self) -> "PerformanceModel":
        """A refit-isolated copy sharing the frozen feature pipeline.

        ``refit`` rebinds ``mlp_params`` to freshly built trees (adam
        never mutates arrays in place), so copying the layer containers
        is enough: the fork and the original diverge from the first
        refit on either side.  This is the serving tenancy hook — every
        tenant refits its own fork of the shared read-only base model."""
        return PerformanceModel(self.pipeline,
                                [dict(layer) for layer in self.mlp_params],
                                self.hidden)

    def predict_configs(self, prog_feats: np.ndarray,
                        configs) -> np.ndarray:
        """Rank many configs for one or many programs (the runtime search
        core).  ``prog_feats`` may be a single ``(F,)`` feature vector —
        returns ``(C,)`` predictions — or a ``(B, F)`` matrix of programs
        — returns ``(B, C)``, one MLP forward for the whole batch (the
        serving engine's batched cold path)."""
        P = np.atleast_2d(np.asarray(prog_feats, dtype=np.float64))
        rows = assemble_rows(P, configs)
        preds = self.predict(rows).reshape(P.shape[0], len(configs))
        return preds[0] if np.ndim(prog_feats) == 1 else preds


def assemble_rows(prog_feats: np.ndarray, configs) -> np.ndarray:
    """Program features ++ config encodings, vectorized: ``(F,)`` input
    yields ``(C, F+3)`` rows; ``(B, F)`` input yields ``(B*C, F+3)`` rows
    grouped program-major."""
    P = np.atleast_2d(np.asarray(prog_feats, dtype=np.float64))
    C = config_feature_matrix(configs)
    return np.concatenate([np.repeat(P, len(configs), axis=0),
                           np.tile(C, (P.shape[0], 1))], axis=1)


# ---------------------------------------------------------------------------
# Alternative learners (Table 5)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _TreeNode:
    feature: int = -1
    thresh: float = 0.0
    value: float = 0.0
    left: Optional["_TreeNode"] = None
    right: Optional["_TreeNode"] = None


def _build_tree(X, y, depth, min_leaf=8) -> _TreeNode:
    node = _TreeNode(value=float(y.mean()))
    if depth == 0 or len(y) < 2 * min_leaf or y.std() < 1e-9:
        return node
    best = (None, None, np.inf)
    n_feat = X.shape[1]
    for j in range(n_feat):
        order = np.argsort(X[:, j])
        xs, ys = X[order, j], y[order]
        csum = np.cumsum(ys)
        csq = np.cumsum(ys ** 2)
        total, total_sq = csum[-1], csq[-1]
        for i in range(min_leaf, len(ys) - min_leaf):
            if xs[i] == xs[i - 1]:
                continue
            nl, nr = i, len(ys) - i
            sl, sr = csum[i - 1], total - csum[i - 1]
            ql, qr = csq[i - 1], total_sq - csq[i - 1]
            sse = (ql - sl**2 / nl) + (qr - sr**2 / nr)
            if sse < best[2]:
                best = (j, (xs[i] + xs[i - 1]) / 2, sse)
    if best[0] is None:
        return node
    j, t, _ = best
    mask = X[:, j] <= t
    node.feature, node.thresh = j, t
    node.left = _build_tree(X[mask], y[mask], depth - 1, min_leaf)
    node.right = _build_tree(X[~mask], y[~mask], depth - 1, min_leaf)
    return node


def _tree_predict_one(node: _TreeNode, x) -> float:
    while node.feature >= 0:
        node = node.left if x[node.feature] <= node.thresh else node.right
    return node.value


@dataclasses.dataclass
class TreeRegressor:
    pipeline: FeaturePipeline
    root: _TreeNode

    @staticmethod
    def train(X_raw, y, *, depth=10, n_components=9,
              max_rows=4000, seed=0) -> "TreeRegressor":
        pipe = FeaturePipeline.fit(X_raw, y, n_components=n_components)
        X = pipe.transform(X_raw)
        yn = pipe.transform_y(y)
        if len(yn) > max_rows:
            idx = np.random.default_rng(seed).choice(
                len(yn), max_rows, replace=False)
            X, yn = X[idx], yn[idx]
        root = _build_tree(X, yn, depth)
        return TreeRegressor(pipe, root)

    def predict(self, X_raw) -> np.ndarray:
        X = self.pipeline.transform(np.atleast_2d(X_raw))
        yn = np.array([_tree_predict_one(self.root, x) for x in X])
        return self.pipeline.inverse_y(yn)


@dataclasses.dataclass
class ForestRegressor:
    pipeline: FeaturePipeline
    roots: list

    @staticmethod
    def train(X_raw, y, *, n_trees=5, depth=8, n_components=9,
              max_rows=2000, seed=0) -> "ForestRegressor":
        pipe = FeaturePipeline.fit(X_raw, y, n_components=n_components)
        X = pipe.transform(X_raw)
        yn = pipe.transform_y(y)
        rng = np.random.default_rng(seed)
        roots = []
        for _ in range(n_trees):
            idx = rng.integers(0, len(yn), min(len(yn), max_rows))
            roots.append(_build_tree(X[idx], yn[idx], depth))
        return ForestRegressor(pipe, roots)

    def predict(self, X_raw) -> np.ndarray:
        X = self.pipeline.transform(np.atleast_2d(X_raw))
        yn = np.mean([[_tree_predict_one(r, x) for x in X]
                      for r in self.roots], axis=0)
        return self.pipeline.inverse_y(yn)


@dataclasses.dataclass
class KernelRidgeRBF:
    """RBF kernel ridge regression — closed-form SVR stand-in (no sklearn
    offline; documented substitution for the paper's SVM regressor)."""

    pipeline: FeaturePipeline
    X_train: np.ndarray
    alpha: np.ndarray
    gamma: float

    @staticmethod
    def train(X_raw, y, *, lam=1e-2, gamma=None,
              n_components=9, max_train=3000, seed=0) -> "KernelRidgeRBF":
        pipe = FeaturePipeline.fit(X_raw, y, n_components=n_components)
        X = pipe.transform(X_raw)
        yn = pipe.transform_y(y)
        if len(yn) > max_train:
            rng = np.random.default_rng(seed)
            idx = rng.choice(len(yn), max_train, replace=False)
            X, yn = X[idx], yn[idx]
        gamma = gamma or 1.0 / X.shape[1]
        K = _rbf(X, X, gamma)
        alpha = np.linalg.solve(K + lam * np.eye(len(yn)), yn)
        return KernelRidgeRBF(pipe, X, alpha, gamma)

    def predict(self, X_raw) -> np.ndarray:
        X = self.pipeline.transform(np.atleast_2d(X_raw))
        yn = _rbf(X, self.X_train, self.gamma) @ self.alpha
        return self.pipeline.inverse_y(yn)


def _rbf(A, B, gamma):
    d2 = (np.sum(A**2, 1)[:, None] + np.sum(B**2, 1)[None, :]
          - 2 * A @ B.T)
    return np.exp(-gamma * np.maximum(d2, 0.0))
