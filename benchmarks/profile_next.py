"""Incremental profile-corpus growth: profile the next few not-yet-
covered programs of the 39-program suite into the profile cache.

    PYTHONPATH=src python -m benchmarks.profile_next --count 3
    PYTHONPATH=src python -m benchmarks.profile_next --list-covered

The trained model's frac-of-oracle is corpus-bound (ROADMAP: 0.75 on
the 6-program seed corpus, target 0.93 on a broad one), but profiling
the full suite in one sitting is hours of grid sweeps.  This tool makes
growth *incremental*: each invocation picks the first ``--count``
programs (suite order, so runs are deterministic and disjoint) that
have no cached cell yet, profiles ``--datasets`` scales each into the
cache at ``REPRO_PROFILE_CACHE`` (or the committed default), and prints
a JSON report.  The nightly CI job runs this against an actions-cached
copy — three programs per night, zero per-PR cost — and re-evaluates
the model on whatever the corpus has grown to (``--list-covered``
feeds the grown program list to ``benchmarks.run --model-eval``).

Already-covered programs are never re-profiled here; a committed-seed
refresh is a deliberate act (delete cells / change the corpus hash),
not a nightly side effect.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.core.modeling import dataset as ds  # noqa: E402
from repro.core.workloads import list_workloads  # noqa: E402


def covered_programs(cache_path=None) -> list[str]:
    """Programs with at least one profiled cell in the cache, in suite
    order (cache keys are ``program@scale``)."""
    cache = ds._load_cache(cache_path or ds.default_cache_path())
    have = {k.rsplit("@", 1)[0] for k in cache}
    return [p for p in list_workloads() if p in have]


def next_uncovered(count: int, cache_path=None) -> list[str]:
    cache = ds._load_cache(cache_path or ds.default_cache_path())
    have = {k.rsplit("@", 1)[0] for k in cache}
    return [p for p in list_workloads() if p not in have][:count]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--count", type=int, default=3,
                    help="programs to profile this run (suite order, "
                         "first uncovered)")
    ap.add_argument("--datasets", type=int, default=2,
                    help="dataset scales per program (matches "
                         "--model-eval's --eval-datasets default)")
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--cache", default=None,
                    help="profile cache JSON (default: "
                         "REPRO_PROFILE_CACHE or the committed seed)")
    ap.add_argument("--list-covered", action="store_true",
                    help="print the covered program list (comma-"
                         "separated) and exit — the --model-eval input")
    args = ap.parse_args()

    cache_path = args.cache or str(ds.default_cache_path())
    if args.list_covered:
        print(",".join(covered_programs(cache_path)))
        return 0

    todo = next_uncovered(args.count, cache_path)
    report = {
        "cache": cache_path,
        "suite_size": len(list_workloads()),
        "covered_before": len(covered_programs(cache_path)),
        "profiled": todo,
    }
    if not todo:
        remaining = len(next_uncovered(len(list_workloads()), cache_path))
        report["note"] = ("corpus complete: every program has cached cells"
                          if remaining == 0
                          else f"nothing profiled ({remaining} uncovered)")
        print(json.dumps(report, indent=1))
        return 0
    t0 = time.perf_counter()
    # generate() profiles only missing cells and checkpoints the cache
    # atomically per program, so a nightly-job timeout loses at most the
    # in-flight program, never the cache file
    ds.generate(todo, datasets_per_program=args.datasets, reps=args.reps,
                cache_path=cache_path, verbose=True)
    report["covered_after"] = len(covered_programs(cache_path))
    report["wall_s"] = time.perf_counter() - t0
    print(json.dumps(report, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
