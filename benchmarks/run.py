"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Consumes the profiled
sample cache (generated on first run; a cached run takes ~2-4 min, a cold
run also profiles the 39-program suite).

    PYTHONPATH=src python -m benchmarks.run [--programs a,b] [--datasets N]
    PYTHONPATH=src python -m benchmarks.run --quick    # tiny subset
    PYTHONPATH=src python -m benchmarks.run --compare-backends  # executor A/B
    PYTHONPATH=src python -m benchmarks.run --serve-concurrent  # engine A/B
    PYTHONPATH=src python -m benchmarks.run --serve-oracle --tenants 3
                                # steady-state regret vs the per-workload
                                # oracle -> BENCH_oracle.json
    PYTHONPATH=src python -m benchmarks.run --serve-trace
                                # virtual-time tail-latency trace replay
                                # (10^5 requests) -> BENCH_latency.json

A dry-run roofline summary (from benchmarks/data/dryrun/*.json, produced
by benchmarks/dryrun_sweep.py) is appended when available.
"""
from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

# CPU-serving thread discipline for the engine A/B: one intra-op thread
# per request, scale across concurrent requests (the standard production
# CPU-inference configuration).  Must be set before jaxlib creates its
# client, hence before the imports below; applies to BOTH engines, so it
# is a deployment mode, not a thumb on the scale.
if ("--serve-concurrent" in sys.argv or "--serve-oracle" in sys.argv
        or "--serve-real-trace" in sys.argv or "--serve-chaos" in sys.argv
        or "--serve-fleet" in sys.argv):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_cpu_multi_thread_eigen=false"
                                 " intra_op_parallelism_threads=1")

import numpy as np  # noqa: E402

from repro.core import dataset as ds  # noqa: E402
from repro.core.backends import list_backends  # noqa: E402
from repro.core.stream_config import StreamConfig  # noqa: E402
from repro.core.streams import (StreamedRunner,  # noqa: E402
                                profile_grid_interleaved)
from repro.core.workloads import get_workload  # noqa: E402

from benchmarks import paper_figures as pf  # noqa: E402

QUICK_PROGRAMS = ["vecadd", "binomial", "sgemm", "jacobi-1d", "mri-q",
                  "blackscholes", "dotprod", "fwt"]

COMPARE_PROGRAMS = ["vecadd", "sgemm", "blackscholes"]
COMPARE_CONFIGS = [StreamConfig(1, 8), StreamConfig(4, 8),
                   StreamConfig(8, 16)]

SERVE_PROGRAMS = ["vecadd", "dotprod", "mvmult"]
STATIC_GRID = [StreamConfig(1, 1), StreamConfig(1, 4), StreamConfig(2, 4),
               StreamConfig(4, 8)]


def compare_backends(programs=None, *, reps: int = 3) -> list[str]:
    """Executor-backend A/B: every runner backend on the same
    (workload, config) cells, vs the host-sync reference."""
    rows = []
    for prog in programs or COMPARE_PROGRAMS:
        wl = get_workload(prog)
        scale = wl.datasets[-1]
        chunked, shared = wl.make_data(scale, np.random.default_rng(0))
        runners = {name: StreamedRunner(wl, chunked, shared, backend=name)
                   for name in list_backends(kind="runner")}
        for cfg in COMPARE_CONFIGS:
            base = runners["host-sync"].run(cfg, reps=reps)
            for name, runner in runners.items():
                t = base if name == "host-sync" else runner.run(cfg,
                                                                reps=reps)
                rows.append(
                    f"backends.{prog}@{scale}.{cfg.partitions}x{cfg.tasks}"
                    f".{name},{t*1e6:.0f},vs_sync={base/t:.3f}x")
    return rows


def serve_trace(programs=None, *, n_requests: int = 12,
                backend: str = "host-sync",
                json_path: str | None = None) -> list[str]:
    """Static-best-config vs adaptive scheduling under the same mixed
    multi-tenant trace.

    The static deployment picks ONE config for the whole fleet — the
    grid point with the best summed runtime over each workload's first
    occurrence (the realistic offline choice) — and serves every request
    with it.  The adaptive scheduler makes a per-request decision
    (model search on cold miss, cache hit after) and self-corrects via
    telemetry-driven refinement.
    """
    from repro.serving import (AdaptiveScheduler, DriftDetector,
                               OverlapHeuristicModel, TelemetryLog,
                               make_trace)

    programs = programs or SERVE_PROGRAMS
    occurrences = -(-n_requests // len(programs))

    rows = []

    # --- static: one fixed config chosen offline, applied to all ---------
    trace = make_trace(programs, occurrences=occurrences)[:n_requests]
    first = {}
    for req in trace:
        first.setdefault(req.workload, req)
    runners = {name: StreamedRunner(get_workload(name), req.chunked,
                                    req.shared, backend=backend)
               for name, req in first.items()}
    min_rows = min(next(iter(r.chunked.values())).shape[0]
                   for r in runners.values())
    grid_cost = {}
    for cfg in STATIC_GRID:
        if cfg.partitions * cfg.tasks > min_rows:
            continue
        grid_cost[cfg] = sum(r.run(cfg, reps=2) for r in runners.values())
    static_cfg = min(grid_cost, key=grid_cost.get)

    t0 = time.perf_counter()
    static_total = 0.0
    for req in trace:
        runner = StreamedRunner(get_workload(req.workload), req.chunked,
                                req.shared, backend=backend)
        static_total += runner.run(static_cfg, reps=1, warmed=True)
    static_wall = time.perf_counter() - t0
    rows.append(f"serve.static.{static_cfg.partitions}x{static_cfg.tasks}"
                f".{backend},{static_total/len(trace)*1e6:.0f},"
                f"total_ms={static_total*1e3:.1f}")

    # --- adaptive: per-request decision + telemetry + refinement ---------
    trace = make_trace(programs, occurrences=occurrences)[:n_requests]
    # a tight drift threshold: the zero-training heuristic model WILL
    # mispredict some buckets, and the point of the comparison is that
    # telemetry-driven refinement re-profiles and corrects them online
    sched = AdaptiveScheduler(OverlapHeuristicModel(), backend=backend,
                              drift=DriftDetector(threshold=0.75,
                                                  min_samples=2),
                              telemetry=TelemetryLog(), keep_outputs=False)
    sched.submit_all(trace)
    t0 = time.perf_counter()
    results = sched.run()
    adaptive_wall = time.perf_counter() - t0
    adaptive_total = sum(r.measured_s for r in results)
    # steady state: the last round, after caches are warm and drift
    # refinements have corrected any mispredicted bucket
    tail = results[-len(programs):]
    steady_us = sum(r.measured_s for r in tail) / len(tail) * 1e6
    summary = sched.telemetry.summary()
    rows.append(f"serve.adaptive.{backend},"
                f"{adaptive_total/len(results)*1e6:.0f},"
                f"total_ms={adaptive_total*1e3:.1f},"
                f"steady_us={steady_us:.0f},"
                f"hit_rate={summary['hit_rate']:.2f},"
                f"refinements={summary['refinements']},"
                f"vs_static={static_total/max(adaptive_total, 1e-12):.3f}x")

    if json_path:
        payload = {
            "programs": programs,
            "n_requests": n_requests,
            "backend": backend,
            "static": {"config": static_cfg.as_tuple(),
                       "total_s": static_total, "wall_s": static_wall},
            "adaptive": {"total_s": adaptive_total,
                         "wall_s": adaptive_wall, **summary},
            "telemetry": [s.to_json() for s in sched.telemetry],
        }
        os.makedirs(os.path.dirname(os.path.abspath(json_path)),
                    exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        rows.append(f"# serve JSON written to {json_path}")
    return rows


SERVE_CONCURRENT_PROGRAMS = ["binomial", "deriche", "mri-q"]


def _parallel_capacity(programs, scale_index, workers, *,
                       reps: int = 8) -> float:
    """Calibrate the box: how much does raw kernel execution speed up
    when issued from ``workers`` threads instead of one?  Uses the
    trace's own kernels (compiled + device-resident, max-of-2 trials;
    the timing core is :func:`repro.core.streams.parallel_capacity`,
    shared with the engine's load-aware drift calibration), so the
    number is the hardware ceiling the engine is chasing — on a
    steal-heavy 2-vCPU container this can be well under the thread
    count, and the engine can't beat physics."""
    import jax

    from repro.core.streams import parallel_capacity
    from repro.core.workloads import get_workload

    calls = []
    for name in programs:
        wl = get_workload(name)
        scale = wl.datasets[min(scale_index, len(wl.datasets) - 1)]
        chunked, shared = wl.make_data(scale, np.random.default_rng(0))
        jitk = jax.jit(wl.kernel)
        dev = jax.device_put(chunked)
        sh = jax.device_put(shared)
        jax.block_until_ready(jitk(dev, sh))        # compile, untimed

        def call(jitk=jitk, dev=dev, sh=sh):
            jax.block_until_ready(jitk(dev, sh))
        calls.append(call)

    return parallel_capacity(calls, workers, reps=reps)


def serve_concurrent_trace(programs=None, *, n_requests: int = 18,
                           backend: str = "host-sync", window: int = 8,
                           workers: int | None = None, scale_index: int = 8,
                           reps: int = 3,
                           json_path: str = "BENCH_serving.json") -> list[str]:
    """Long-trace steady-state throughput: the serial AdaptiveScheduler
    vs the concurrent engine on the SAME mixed multi-tenant trace.

    Fairness protocol:
      * one intra-op XLA thread (env set at module import) — both
        engines run the standard CPU-serving thread discipline, so
        request-level overlap is the only concurrency axis;
      * a shared decision pass first populates ONE TuningCache and the
        process-global compile caches — both timed engines then serve
        all-warm-hit traces with IDENTICAL per-request configs, so the
        A/B measures the engines, not model noise or compile warmth;
      * min wall over ``reps`` timed runs per engine (steal-time spikes
        on shared boxes otherwise decide the result);
      * a calibration probe reports the box's raw ``workers``-thread
        kernel-scaling ceiling next to the speedup —
        ``capacity_fraction`` says how much of the achievable overlap
        the engine delivers.

    Results land in ``BENCH_serving.json`` — the serving perf
    trajectory's first point.
    """
    from repro.core.autotuner import TuningCache
    from repro.serving import (AdaptiveScheduler, ConcurrentScheduler,
                               DriftDetector, OverlapHeuristicModel,
                               TelemetryLog, make_trace)

    programs = programs or SERVE_CONCURRENT_PROGRAMS
    workers = workers or max(2, min(window, os.cpu_count() or 2))
    occurrences = -(-n_requests // len(programs))
    # a lenient drift threshold on BOTH sides: concurrent measured_s is
    # wall time under contention, and a refinement storm mid-trace would
    # benchmark the refiner, not the engines
    cache = TuningCache()

    def sched_kwargs():
        return dict(backend=backend, cache=cache,
                    drift=DriftDetector(threshold=1e9),
                    telemetry=TelemetryLog(), keep_outputs=False)

    def trace():
        return make_trace(programs, occurrences=occurrences,
                          scale_index=scale_index)[:n_requests]

    rows = []
    # shared decision pass: cold-tunes every bucket into the shared
    # cache and warms the process-global compile caches, untimed
    decide = AdaptiveScheduler(OverlapHeuristicModel(), **sched_kwargs())
    decide.submit_all(make_trace(programs, occurrences=1,
                                 scale_index=scale_index))
    decide.run()

    def timed(factory):
        sched = factory()
        # inherit the decide pass's profiled single-stream anchors: a
        # long-lived serving process carries these, and without them
        # every bucket would re-anchor (a measured run + a pool drain in
        # the engine) inside the timed steady state
        sched._t_single.update(decide._t_single)
        sched._feats.update(decide._feats)
        best = float("inf")
        # one scheduler across reps: the first rep absorbs per-(bucket,
        # config) warmups, later reps are pure steady state — min wall
        # is the steady-state trace time, same protocol for both engines.
        # telemetry resets per rep so the recorded summary describes ONE
        # trace pass (matching n_requests), not the sum of all reps
        for _ in range(reps):
            sched.telemetry = TelemetryLog()
            sched.submit_all(trace())
            t0 = time.perf_counter()
            sched.run()
            best = min(best, time.perf_counter() - t0)
        return best, sched

    serial_wall, serial = timed(
        lambda: AdaptiveScheduler(OverlapHeuristicModel(),
                                  **sched_kwargs()))
    serial_rps = n_requests / serial_wall
    rows.append(f"serve_concurrent.serial.{backend},"
                f"{serial_wall/n_requests*1e6:.0f},"
                f"wall_ms={serial_wall*1e3:.1f},rps={serial_rps:.1f}")

    conc_wall, engine = timed(
        lambda: ConcurrentScheduler(OverlapHeuristicModel(), window=window,
                                    workers=workers, **sched_kwargs()))
    conc_rps = n_requests / conc_wall
    speedup = serial_wall / max(conc_wall, 1e-12)

    capacity = _parallel_capacity(programs, scale_index, workers)
    rows.append(f"serve_concurrent.window{window}.{backend},"
                f"{conc_wall/n_requests*1e6:.0f},"
                f"wall_ms={conc_wall*1e3:.1f},rps={conc_rps:.1f},"
                f"ctx_reuses={engine.stats['ctx_reuses']},"
                f"speedup={speedup:.3f}x")
    rows.append(f"serve_concurrent.capacity.{workers}threads,"
                f"{0:.0f},scaling={capacity:.3f}x,"
                f"capacity_fraction={speedup/max(capacity, 1e-12):.3f}")

    payload = {
        "programs": programs,
        "n_requests": n_requests,
        "backend": backend,
        "window": window,
        "workers": workers,
        "scale_index": scale_index,
        "reps": reps,
        "cpu_count": os.cpu_count(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "serial": {"wall_s": serial_wall, "throughput_rps": serial_rps,
                   **serial.telemetry.summary()},
        "concurrent": {"wall_s": conc_wall, "throughput_rps": conc_rps,
                       "ctx_reuses": int(engine.stats["ctx_reuses"]),
                       **engine.telemetry.summary()},
        "speedup": speedup,
        "parallel_capacity": capacity,
        "capacity_fraction": speedup / max(capacity, 1e-12),
    }
    os.makedirs(os.path.dirname(os.path.abspath(json_path)), exist_ok=True)
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=1)
    rows.append(f"# serving benchmark JSON written to {json_path}")
    return rows


FLEET_PROGRAMS = ["vecadd", "dotprod", "mvmult"]


def serve_fleet(programs=None, *, n_workers: int = 4, n_requests: int = 24,
                window: int = 2, backend: str = "host-sync",
                scale_index: int = 4, tenants: int = 8, reps: int = 3,
                kill_drill: bool = True,
                json_path: str = "BENCH_fleet.json") -> list[str]:
    """Fleet throughput scaling: the tenant-sharding router over 1..N
    worker PROCESSES on the same mixed multi-tenant trace, plus a
    SIGKILL drill proving worker death never loses a request.

    Fairness protocol (mirrors ``--serve-concurrent``):
      * one intra-op XLA thread per process (env set at module import) —
        process count is the only concurrency axis;
      * per worker-count, a fresh fleet serves one untimed warmup pass
        (spawn + compile + cold tunes) and then ``reps`` timed passes;
        min wall is the steady-state number;
      * the raw N-process speedup is normalized by the SAME run's
        measured parallel-capacity ceiling (the trace's own kernels
        issued from ``n_workers`` threads) — on a 1-2 vCPU CI box the
        physics caps scaling near 1x, and ``fleet_scaling_fraction``
        (speedup / min(N, ceiling)) is what the regression gate judges,
        not the host's core count.

    Besides throughput, every worker count reports end-to-end request
    latency percentiles (p50/p95/p99 over all timed passes) and the
    data-plane's ``ipc_overhead_fraction`` — the share of the best
    pass's router wall NOT covered by the busiest worker's engine wall,
    i.e. what dispatch, pickling, and collection cost; lower is better
    and CI gates it.

    The kill drill reuses the max-N fleet: SIGKILL one worker mid-trace,
    assert the router respawns the slot, requeues the un-acked work, and
    every admitted request still reaches a terminal status —
    ``fleet_kill_lost_requests`` has an exact-zero baseline.  Results
    land in ``BENCH_fleet.json``.
    """
    from repro.serving import latency_stats, make_trace
    from repro.serving.fleet import FleetRouter, WorkerConfig, shard_for

    programs = programs or FLEET_PROGRAMS
    occurrences = -(-n_requests // len(programs))

    def trace():
        return make_trace(programs, occurrences=occurrences,
                          tenants=tenants, scale_index=scale_index
                          )[:n_requests]

    counts = sorted({n for n in (1, 2, 4) if n <= n_workers} | {n_workers})
    rows, walls, crashes = [], {}, 0
    latency, ipc = {}, {}
    router = None
    try:
        for n in counts:
            router = FleetRouter(
                n, worker=WorkerConfig(window=window, backend=backend,
                                       model="heuristic"))
            router.start()
            router.submit_all(trace())     # warmup: compile + cold tunes
            router.run()
            best, best_ipc, lats = float("inf"), None, []
            for _ in range(reps):
                reqs = trace()
                router.submit_all(reqs)
                t0 = time.perf_counter()
                results = router.run()
                wall = time.perf_counter() - t0
                lats.extend(r["sample"]["latency_s"] for r in results
                            if r["sample"].get("latency_s") is not None)
                if wall < best:
                    best = wall
                    best_ipc = router.last_run.get("ipc_overhead_fraction")
            walls[n] = best
            ipc[n] = best_ipc
            # end-to-end request latency (enqueue -> retire) across all
            # timed passes; perf_counter stamps are comparable across
            # router and workers (CLOCK_MONOTONIC process-agnostic)
            lstats = latency_stats(lats)
            latency[n] = {
                "p50_ms": lstats["p50_s"] * 1e3 if lstats else None,
                "p95_ms": lstats["p95_s"] * 1e3 if lstats else None,
                "p99_ms": lstats["p99_s"] * 1e3 if lstats else None,
            }
            crashes += router.stats.get("worker_deaths", 0) \
                - router.stats.get("injected_kills", 0)
            ipc_s = (f",ipc={best_ipc:.3f}" if best_ipc is not None else "")
            lat_s = ("" if lstats is None else
                     f",p50_ms={latency[n]['p50_ms']:.1f}"
                     f",p99_ms={latency[n]['p99_ms']:.1f}")
            rows.append(f"serve_fleet.workers{n}.{backend},"
                        f"{best/n_requests*1e6:.0f},"
                        f"wall_ms={best*1e3:.1f},"
                        f"rps={n_requests/best:.1f},"
                        f"speedup={walls[1]/best:.3f}x"
                        + lat_s + ipc_s)
            if n != n_workers:
                router.close()
                router = None

        speedup = walls[1] / max(walls[n_workers], 1e-12)
        capacity = _parallel_capacity(programs, scale_index, n_workers)
        ceiling = min(float(n_workers), max(1.0, capacity))
        scaling_fraction = speedup / ceiling
        rows.append(f"serve_fleet.capacity.{n_workers}procs,0,"
                    f"scaling={capacity:.3f}x,ceiling={ceiling:.3f},"
                    f"scaling_fraction={scaling_fraction:.3f}")

        kill = None
        if kill_drill and router is not None:
            # reuse the warm max-N fleet; kill the worker that owns
            # tenant-0 once a quarter of the trace has retired
            victim = shard_for("tenant-0", n_workers)
            base_deaths = router.stats.get("worker_deaths", 0)
            reqs = trace()
            router.submit_all(reqs)
            router.inject_kill(victim, after_results=max(1, n_requests // 4))
            results = router.run()
            terminal = sum(r["status"] in ("served", "degraded", "failed",
                                           "timeout") for r in results)
            kill = {
                "victim_slot": victim,
                "results": len(results),
                "terminal": terminal,
                "deaths": router.stats.get("worker_deaths", 0) - base_deaths,
                "respawns": router.stats.get("worker_respawns", 0),
                "requeued": router.stats.get("requeued_requests", 0),
                "duplicates": router.stats.get("duplicate_results", 0),
            }
            rows.append(f"serve_fleet.kill_drill.slot{victim},0,"
                        f"deaths={kill['deaths']},"
                        f"respawns={kill['respawns']},"
                        f"requeued={kill['requeued']},"
                        f"terminal={terminal}/{n_requests}")
    finally:
        if router is not None:
            router.close()
    fleet_summary = router.summary() if router is not None else {}

    payload = {
        "programs": programs,
        "n_requests": n_requests,
        "n_workers": n_workers,
        "window": window,
        "backend": backend,
        "scale_index": scale_index,
        "tenants": tenants,
        "reps": reps,
        "cpu_count": os.cpu_count(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "walls_s": {str(n): walls[n] for n in counts},
        "throughput_rps": {str(n): n_requests / walls[n] for n in counts},
        "latency_by_workers": {str(n): latency[n] for n in counts},
        "ipc_overhead_fraction_by_workers": {str(n): ipc[n] for n in counts},
        "fleet_speedup": speedup,
        "parallel_capacity": capacity,
        "capacity_ceiling": ceiling,
        # -- gated --
        "ipc_overhead_fraction": ipc.get(n_workers),
        "fleet_scaling_fraction": scaling_fraction,
        "fleet_worker_crashes": crashes,
        "fleet_kill_lost_requests": (n_requests - kill["results"]
                                     if kill else None),
        "fleet_kill_terminal_fraction": (kill["terminal"] / n_requests
                                         if kill else None),
        "kill_drill": kill,
        "fleet": {k: v for k, v in fleet_summary.items()
                  if k != "metrics"},
    }
    os.makedirs(os.path.dirname(os.path.abspath(json_path)), exist_ok=True)
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=1)
    rows.append(f"# fleet benchmark JSON written to {json_path}")
    return rows


SERVE_ORACLE_PROGRAMS = ["vecadd", "dotprod", "mvmult", "binomial"]
# the regret protocol's shared candidate space: small enough to profile
# exhaustively (the oracle side), identical for the adaptive scheduler
# (the achieved side) — regret compares picks over the SAME choices
ORACLE_GRID = [StreamConfig(p, t) for p in (1, 2, 4)
               for t in (1, 2, 4, 8, 16) if t >= p]


def serve_oracle_trace(programs=None, *, tenants: int = 3, rounds: int = 12,
                       backend: str = "host-sync", window: int = 4,
                       workers: int | None = None, scale_index: int = 8,
                       oracle_reps: int = 3,
                       json_path: str = "BENCH_oracle.json") -> list[str]:
    """Long-trace oracle-regret benchmark: the adaptive engine's
    steady state vs a theoretically perfect predictor, per tenant.

    The paper's headline claim is that the learnt predictor delivers
    over 93% of the oracle's performance.  This measures our *serving
    loop* against the same bar:

      oracle    exhaustively profile ``ORACLE_GRID`` per workload
                bucket — the perfect predictor's pick and its runtime.
                The grid is profiled TWICE, before and after serving,
                and merged min-per-config: a neighbor-load spike during
                either pass then cannot masquerade as (or hide) regret
                on this shared-vCPU class of CI box;
      achieved  serve a ``rounds``-round multi-tenant trace through the
                concurrent engine with tenant isolation and load-aware
                drift, then read each tenant's steady-state cache entry
                (the config its NEXT request would use) and look its
                idle runtime up in the same profiled grid;
      regret    oracle_runtime / achieved_runtime per (tenant,
                workload), in (0, 1]; reported per tenant and overall.

    Reading achieved runtimes from the same idle-profiled grid keeps
    contention out of the *metric* (the engine still serves under
    contention — that is what the load-aware drift signal is being
    scored on: spurious refinements are also reported).
    """
    from repro.core.autotuner import TuningCache
    from repro.serving import (ConcurrentScheduler, DriftDetector,
                               OverlapHeuristicModel, Refiner,
                               TelemetryLog, make_trace)

    programs = programs or SERVE_ORACLE_PROGRAMS
    workers = workers or max(2, min(window, os.cpu_count() or 2))
    tenant_names = [f"tenant-{i}" for i in range(tenants)]
    rows = []

    # --- oracle pass A: exhaustive profiling per workload bucket ---------
    trace = make_trace(programs, occurrences=rounds, tenants=tenant_names,
                       scale_index=scale_index)
    first = {}
    for req in trace:
        first.setdefault(req.workload, req)
    runners = {name: StreamedRunner(get_workload(name), req.chunked,
                                    req.shared, backend=backend)
               for name, req in first.items()}
    grids = {}           # workload -> {cfg: min wall over both passes}
    for name, runner in runners.items():
        n_rows = next(iter(runner.chunked.values())).shape[0]
        cands = [c for c in ORACLE_GRID
                 if c.partitions * c.tasks <= n_rows]
        grids[name] = profile_grid_interleaved(runner, cands,
                                                sweeps=oracle_reps)

    # --- achieved: isolated multi-tenant adaptive serving ----------------
    model = OverlapHeuristicModel()
    cache = TuningCache()
    sched = ConcurrentScheduler(
        model, window=window, workers=workers,
        backend=backend, policy="fair", cache=cache,
        candidates=list(ORACLE_GRID), isolate_tenants=True,
        drift=DriftDetector(window=8, threshold=0.35, min_samples=2,
                            cooldown=2),
        refiner=Refiner(model, cache, candidates=list(ORACLE_GRID),
                        top_k=3, reps=3),
        telemetry=TelemetryLog(), keep_outputs=False)
    with sched:
        sched.submit_all(trace)
        t0 = time.perf_counter()
        sched.run()
        wall = time.perf_counter() - t0

        # --- oracle pass B + min-merge ----------------------------------
        oracle = {}      # workload -> (best cfg, t_s, merged grid)
        for name, runner in runners.items():
            merged = profile_grid_interleaved(
                runner, list(grids[name]), sweeps=oracle_reps,
                prior=grids[name])
            best = min(merged, key=merged.get)
            oracle[name] = (best, merged[best], merged)
            rows.append(f"serve_oracle.oracle.{name},"
                        f"{merged[best]*1e6:.0f},"
                        f"config={best.partitions}x{best.tasks}")

        # steady state: the cache entry each (tenant, workload) would
        # serve its NEXT request from, scored on the idle-profiled grid
        per_tenant = {}
        for tenant in tenant_names:
            ctx = sched.tenancy.get(tenant)
            per_workload = {}
            for name, req in first.items():
                key = sched.cache.key(name, req.chunked, req.shared,
                                      backend, sched.model_tag,
                                      namespace=ctx.namespace)
                entry = sched.cache.get(key)
                if entry is None:        # tenant never saw this workload
                    continue
                _, t_oracle, measured = oracle[name]
                achieved = measured.get(entry.config)
                if achieved is None:     # off-grid (cannot happen today)
                    achieved = StreamedRunner(
                        get_workload(name), req.chunked, req.shared,
                        backend=backend).run(entry.config,
                                             reps=oracle_reps)
                per_workload[name] = {
                    "config": entry.config.as_tuple(),
                    "source": entry.source,
                    "achieved_s": achieved,
                    "oracle_s": t_oracle,
                    "regret": t_oracle / max(achieved, 1e-12),
                }
            regrets = [w["regret"] for w in per_workload.values()]
            regret = sum(regrets) / len(regrets) if regrets else None
            per_tenant[tenant] = {
                "regret": regret,
                "refinements": ctx.refinements,
                "served": ctx.served,
                "per_workload": per_workload,
            }
            regret_str = f"{regret:.3f}" if regret is not None else "n/a"
            rows.append(
                f"serve_oracle.{tenant},0,regret={regret_str},"
                f"refinements={ctx.refinements},served={ctx.served}")

        all_regrets = [t["regret"] for t in per_tenant.values()
                       if t["regret"] is not None]
        # a tenant can go unserved when the trace is shorter than the
        # tenant count (tiny smoke configs) — regret is then undefined
        mean_regret = (sum(all_regrets) / len(all_regrets)
                       if all_regrets else None)
        summary = sched.telemetry.summary()
        mean_str = (f"{mean_regret:.3f}" if mean_regret is not None
                    else "n/a")
        rows.append(f"serve_oracle.mean,0,regret={mean_str},"
                    f"target=0.93,refinements={summary['refinements']},"
                    f"requests={summary['requests']}")

        payload = {
            "programs": programs,
            "tenants": tenant_names,
            "rounds": rounds,
            "n_requests": len(trace),
            "backend": backend,
            "window": window,
            "workers": workers,
            "scale_index": scale_index,
            "oracle_reps": oracle_reps,
            "cpu_count": os.cpu_count(),
            "wall_s": wall,
            "oracle": {name: {"config": cfg.as_tuple(), "t_s": t}
                       for name, (cfg, t, _) in oracle.items()},
            "per_tenant": per_tenant,
            "mean_regret": mean_regret,
            "target_regret": 0.93,
            "parallel_capacity": sched.parallel_capacity,
            "telemetry_summary": summary,
        }
    os.makedirs(os.path.dirname(os.path.abspath(json_path)), exist_ok=True)
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=1)
    rows.append(f"# oracle-regret JSON written to {json_path}")
    return rows


TRACE_POLICIES = ("fifo", "priority", "fair", "deadline")


def serve_latency_trace(*, n_requests: int = 100_000, seed: int = 0,
                        window: int = 8, capacity: float = 1.6,
                        json_path: str = "BENCH_latency.json") -> list[str]:
    """Tail-latency trace replay: every queue policy on the SAME seeded
    bursty million-scale trace, in virtual time.

    Uses :mod:`repro.serving.traces`: a deterministic MMPP/Zipf trace
    over the registered workload suite is replayed through the real
    request queue + drift detector on a virtual clock, so a 10^5-request
    run takes seconds and the p50/p95/p99 latencies, SLO-violation
    rates, shed counts, and queue-depth stats are exactly reproducible
    — the regression gate can hold them to tight tolerances because no
    wall-clock noise enters the numbers.

    Two extra runs pin the drift detector's long-trace behaviour:
      * a stationary Poisson trace at the same window must produce ZERO
        refinements (contention at window=8 must not masquerade as
        drift — the load-aware signal's acceptance bar);
      * the bursty ``deadline`` run must beat ``fifo`` on SLO-violation
        rate (EDF boost + shedding earning their keep).
    """
    from repro.serving.traces import (TraceConfig, generate_trace,
                                      simulate_trace)

    rows = []
    reports = {}
    bursty = TraceConfig(n_requests=n_requests, seed=seed, arrival="bursty")
    for policy in TRACE_POLICIES:
        r = simulate_trace(generate_trace(bursty), policy=policy,
                           window=window, capacity=capacity, seed=seed)
        reports[policy] = r
        lat, slo, qd = r["latency"], r["slo"], r["queue_depth"]
        rows.append(
            f"serve_trace.bursty.{policy},{lat['p95_s']*1e6:.0f},"
            f"p50_ms={lat['p50_s']*1e3:.2f},p99_ms={lat['p99_s']*1e3:.2f},"
            f"viol_rate={slo['violation_rate']:.4f},shed={slo['shed']},"
            f"depth_p95={qd['p95']},refinements={r['refinements']}")

    stationary = simulate_trace(
        generate_trace(TraceConfig(n_requests=n_requests, seed=seed + 1,
                                   arrival="poisson")),
        policy="fifo", window=window, capacity=capacity, seed=seed + 1)
    rows.append(
        f"serve_trace.stationary.fifo,"
        f"{stationary['latency']['p95_s']*1e6:.0f},"
        f"refinements={stationary['refinements']},"
        f"viol_rate={stationary['slo']['violation_rate']:.4f}")

    fifo_rate = reports["fifo"]["slo"]["violation_rate"]
    dl_rate = reports["deadline"]["slo"]["violation_rate"]
    payload = {
        "n_requests": n_requests,
        "seed": seed,
        "window": window,
        "capacity": capacity,
        "arrival": "bursty",
        "policies": reports,
        "stationary": stationary,
        # gated, lower is better (deterministic virtual-time numbers)
        "deadline_slo_violation_rate": dl_rate,
        "fifo_slo_violation_rate": fifo_rate,
        "deadline_p95_latency_ms":
            reports["deadline"]["latency"]["p95_s"] * 1e3,
        "stationary_refinements": stationary["refinements"],
        # gated, higher is better: how much EDF+shedding beats FIFO
        "deadline_vs_fifo_violation_improvement":
            fifo_rate / max(dl_rate, 1e-9),
    }
    os.makedirs(os.path.dirname(os.path.abspath(json_path)), exist_ok=True)
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=1)
    rows.append(f"# latency-trace JSON written to {json_path}")
    return rows


REAL_TRACE_PROGRAMS = ["vecadd", "dotprod", "mvmult"]


def serve_real_trace(*, n_requests: int = 10_000, seed: int = 0,
                     window: int = 8, workers: int | None = None,
                     scale_index: int = 0, backend: str = "host-sync",
                     profile_alloc: bool = False,
                     alloc_requests: int = 2_000,
                     chrome_trace: str | None = None,
                     metrics_out: str | None = None,
                     json_path: str = "BENCH_overhead.json") -> list[str]:
    """Real-engine hot-path profiling: replay a generated 10^4-request
    trace through the real :class:`ConcurrentScheduler` — kernels
    executing, wall clock — with span tracing and the metrics registry
    live, and attribute where the time went.

    This is ROADMAP's real-engine-replay item: the virtual-time harness
    (``--serve-trace``) answers tail-latency questions at 10^5+ scale,
    but only a wall-clock run exposes the *scheduler's own* overheads —
    coordinator Python time per decision, retire-path bookkeeping,
    hot-path allocations.  The trace reuses :func:`generate_trace`
    (seeded Poisson arrivals, Zipf workload/tenant skew) restricted to a
    small program set at one scale, with virtual-epoch arrival stamps
    cleared so the engine re-stamps them on its own clock.

    Reported to ``BENCH_overhead.json``:

      * per-stage wall attribution (decide/tune/dispatch/retire/refine)
        from top-level spans;
      * ``kernel_exec_s`` (sum of measured kernel walls) vs
        ``wall_s`` — and ``python_overhead_fraction``: coordinator
        decide+retire wall over total wall, the gated metric (a
        same-run ratio, so host drift largely cancels);
      * with ``--profile-alloc``, top allocation sites from a separate,
        shorter tracemalloc'd pass (tracemalloc ~doubles allocation
        cost, so the timed pass runs untraced).
    """
    from repro.serving import (ConcurrentScheduler, DriftDetector,
                               HotPathProfiler, MetricsRegistry,
                               OverlapHeuristicModel, TelemetryLog,
                               Tracer)
    from repro.serving.traces import TraceConfig, generate_trace

    workers = workers or max(2, min(window, os.cpu_count() or 2))
    cfg = TraceConfig(
        n_requests=n_requests, seed=seed, arrival="poisson",
        workloads=tuple(REAL_TRACE_PROGRAMS),
        scale_indices=(scale_index,), churn_prob=0.0,
        slo_choices=None)

    def requests():
        reqs = list(generate_trace(cfg))
        for r in reqs:
            # generated stamps live on the virtual trace epoch; the real
            # engine's clock is perf_counter — submit() re-stamps
            r.arrival_s = None
        return reqs

    def build(tracer, metrics):
        # a storm-proof drift threshold: refinements re-profile on a
        # quiesced pool and would benchmark the refiner, not the
        # serving hot path
        return ConcurrentScheduler(
            OverlapHeuristicModel(), window=window, workers=workers,
            backend=backend, drift=DriftDetector(threshold=1e9),
            telemetry=TelemetryLog(), keep_outputs=False,
            tracer=tracer, metrics=metrics)

    rows = []
    tracer = Tracer()
    metrics = MetricsRegistry()
    sched = build(tracer, metrics)
    with sched:
        sched.submit_all(requests())
        prof = HotPathProfiler(tracer)
        with prof:
            results = sched.run()
        report = prof.report()

    wall = report["wall_s"]
    stages = report["stages"]
    kernel_exec_s = sum(r.measured_s for r in results)
    coord_s = stages["decide"]["wall_s"] + stages["retire"]["wall_s"]
    overhead_fraction = coord_s / max(wall, 1e-12)
    rps = len(results) / max(wall, 1e-12)

    rows.append(f"serve_real.window{window}.{backend},"
                f"{wall / max(len(results), 1) * 1e6:.0f},"
                f"requests={len(results)},wall_s={wall:.2f},"
                f"rps={rps:.1f},"
                f"python_overhead_fraction={overhead_fraction:.4f}")
    for stage in ("decide", "tune", "dispatch", "retire", "refine"):
        st = stages[stage]
        mean_us = (st["mean_s"] * 1e6) if st["mean_s"] is not None else 0
        rows.append(f"serve_real.stage.{stage},{mean_us:.0f},"
                    f"wall_s={st['wall_s']:.3f},count={st['count']}")

    allocations = None
    if profile_alloc:
        # separate pass: tracemalloc roughly doubles allocation cost, so
        # the timed numbers above stay clean and this one stays short
        n_alloc = min(alloc_requests, n_requests)
        alloc_cfg = dataclasses.replace(cfg, n_requests=n_alloc)
        tracer2 = Tracer()
        sched2 = build(tracer2, MetricsRegistry())
        with sched2:
            reqs = list(generate_trace(alloc_cfg))
            for r in reqs:
                r.arrival_s = None
            sched2.submit_all(reqs)
            prof2 = HotPathProfiler(tracer2, alloc=True)
            with prof2:
                sched2.run()
        allocations = prof2.report()["allocations"]
        for a in allocations[:5]:
            site = a["site"]
            if len(site) > 72:
                site = "..." + site[-69:]
            rows.append(f"serve_real.alloc,0,site={site},"
                        f"kb={a['size_kb']:.0f},count={a['count']}")

    if chrome_trace:
        n_spans = tracer.export_chrome(chrome_trace)
        rows.append(f"# chrome trace ({n_spans} spans) written to "
                    f"{chrome_trace}")
    if metrics_out:
        metrics.save(metrics_out)
        rows.append(f"# metrics snapshot written to {metrics_out}")

    payload = {
        "programs": REAL_TRACE_PROGRAMS,
        "n_requests": len(results),
        "seed": seed,
        "backend": backend,
        "window": window,
        "workers": workers,
        "scale_index": scale_index,
        "cpu_count": os.cpu_count(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "wall_s": wall,
        "cpu_s": report["cpu_s"],
        "throughput_rps": rps,
        "per_stage_s": stages,
        "kernel_exec_s": kernel_exec_s,
        "dispatch_overhead_s": stages["dispatch"]["wall_s"]
                               - kernel_exec_s,
        "coordinator_s": coord_s,
        "python_overhead_fraction": overhead_fraction,
        "telemetry_summary": sched.telemetry.summary(),
        "metrics": metrics.snapshot(),
    }
    if allocations is not None:
        payload["allocations"] = allocations
    os.makedirs(os.path.dirname(os.path.abspath(json_path)), exist_ok=True)
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=1)
    rows.append(f"# overhead JSON written to {json_path}")
    return rows


DEFAULT_FAULT_SCHEDULE = os.path.join(ROOT, "benchmarks", "data",
                                      "chaos_faults.json")


def serve_chaos(*, n_requests: int = 400, seed: int = 0, window: int = 8,
                workers: int | None = None, scale_index: int = 0,
                backend: str = "host-threads",
                fault_schedule: str = DEFAULT_FAULT_SCHEDULE,
                watchdog_s: float = 0.25, slo_margin: float = 2.0,
                slo_floor_s: float = 0.25,
                json_path: str = "BENCH_resilience.json") -> list[str]:
    """Chaos benchmark: the PR 6 bursty trace through the REAL concurrent
    engine twice — fault-free, then under the committed fault schedule —
    with the resilience layer live in both runs.

    Measures what the fault-tolerance layer actually buys (ROADMAP's
    robustness item): the chaos run must complete with **zero scheduler
    crashes** and every request terminal (served / degraded / failed /
    timeout — never lost), and the report splits *degraded* (answered
    via a fallback rung) from *failed* so graceful degradation is
    distinguishable from dropped work.

    SLO accounting: request ``i``'s deadline is
    ``slo_floor_s + slo_margin * (its own fault-free latency)`` — a
    per-request yardstick from the baseline run, so the fault-free
    violation rate is 0 by construction and
    ``chaos_slo_violation_delta`` *is* the latency damage the faults
    caused (gated; the committed schedule bounds how much a retry storm
    or breaker window may cost).  Breaker open→closed transitions give
    ``mean_recovery_s``.

    Gated in ``BENCH_resilience.json``: ``chaos_crashes`` (exact-zero),
    ``chaos_terminal_fraction`` (higher), ``chaos_failed_fraction``
    (lower), ``chaos_slo_violation_delta`` (lower).
    """
    import collections

    from repro.serving import (BreakerConfig, ConcurrentScheduler,
                               DriftDetector, FaultPlan, MetricsRegistry,
                               OverlapHeuristicModel, ResiliencePolicy,
                               TelemetryLog)
    from repro.serving.traces import TraceConfig, generate_trace

    workers = workers or max(2, min(window, os.cpu_count() or 2))
    # two scales + a churn trickle: the nearest-bucket rung needs a
    # neighboring shape bucket in the cache to borrow from
    cfg = TraceConfig(
        n_requests=n_requests, seed=seed, arrival="bursty",
        workloads=tuple(REAL_TRACE_PROGRAMS),
        scale_indices=(scale_index, scale_index + 1), churn_prob=0.05,
        slo_choices=None)
    # breaker cooldown scaled to the run: the committed outage window
    # spans a few hundred ms of wall, and recovery (open -> half-open
    # probe -> closed) must happen INSIDE the measured run
    policy = ResiliencePolicy(
        breaker=BreakerConfig(k=3, cooldown_s=0.3), watchdog_s=watchdog_s)

    def run_once(faults, deadline_offsets):
        # fresh requests every run: the engine mutates arrival stamps
        reqs = list(generate_trace(cfg))
        for r in reqs:
            r.arrival_s = None
            r.deadline_s = None
        metrics = MetricsRegistry()
        sched = ConcurrentScheduler(
            OverlapHeuristicModel(), window=window, workers=workers,
            backend=backend, drift=DriftDetector(threshold=1e9),
            telemetry=TelemetryLog(), keep_outputs=False,
            metrics=metrics, faults=faults, resilience=policy)
        with sched:
            sched.submit_all(reqs)      # stamps arrival_s on the real clock
            if deadline_offsets is not None:
                for r, off in zip(reqs, deadline_offsets):
                    r.deadline_s = r.arrival_s + off
            t0 = time.perf_counter()
            results = sched.run()
            wall = time.perf_counter() - t0
        return sched, metrics, results, wall

    rows = []

    # -- jit warmup: first-compile walls (100s of ms) would otherwise
    # read as watchdog timeouts and poison the per-request SLO yardstick
    run_once(None, None)

    # -- baseline: resilience live, no faults --------------------------------
    _, _, base_results, base_wall = run_once(None, None)
    base_lat = [r.sample.latency_s for r in base_results]
    offsets = [slo_floor_s + slo_margin * (lat if lat is not None else 0.0)
               for lat in base_lat]
    base_viol = sum(1 for lat, off in zip(base_lat, offsets)
                    if lat is None or lat > off)
    base_rate = base_viol / max(len(base_results), 1)
    rows.append(f"serve_chaos.baseline,"
                f"{base_wall / max(len(base_results), 1) * 1e6:.0f},"
                f"requests={len(base_results)},wall_s={base_wall:.2f},"
                f"slo_violation_rate={base_rate:.4f}")

    # -- chaos: same engine, same policy, committed fault schedule -----------
    faults = FaultPlan.load(fault_schedule)
    crashes = 0
    try:
        sched, metrics, results, wall = run_once(faults, offsets)
    except BaseException as e:  # noqa: BLE001 — a crash IS the measurement
        crashes = 1
        rows.append(f"serve_chaos.CRASH,0,error={type(e).__name__}: {e}")
        sched = metrics = None
        results, wall = [], 0.0

    statuses = collections.Counter(r.status for r in results)
    n_terminal = len(results)
    terminal_fraction = n_terminal / max(n_requests, 1)
    failed = statuses["failed"] + statuses["timeout"]
    failed_fraction = failed / max(n_requests, 1)
    degraded_fraction = statuses["degraded"] / max(n_requests, 1)
    chaos_viol = sum(
        1 for r in results
        if r.status in ("failed", "timeout") or (
            r.sample.latency_s is not None
            and r.sample.deadline_s is not None
            and r.sample.t_retire_s is not None
            and r.sample.t_retire_s > r.sample.deadline_s))
    chaos_rate = chaos_viol / max(n_terminal, 1)
    slo_delta = max(0.0, chaos_rate - base_rate)

    recoveries = []
    if sched is not None:
        opened_at: dict = {}
        for t, key, state in sched.breaker.events:
            if state == "open":
                opened_at.setdefault(key, t)
            elif state == "closed" and key in opened_at:
                recoveries.append(t - opened_at.pop(key))
    mean_recovery_s = (sum(recoveries) / len(recoveries)
                       if recoveries else None)

    stats = dict(sched.stats) if sched is not None else {}

    def counter_total(name):
        snap = metrics.snapshot() if metrics is not None else {}
        return sum(v["value"] for v in snap.get(name, {}).get("values", []))

    recovered = counter_total("serving.faults.recovered")
    rows.append(f"serve_chaos.window{window}.{backend},"
                f"{wall / max(n_terminal, 1) * 1e6:.0f},"
                f"requests={n_terminal}/{n_requests},wall_s={wall:.2f},"
                f"crashes={crashes},"
                f"faults_injected={faults.fired}")
    rows.append(f"serve_chaos.outcomes,0,"
                f"served={statuses['served']},"
                f"degraded={statuses['degraded']},"
                f"failed={statuses['failed']},"
                f"timeout={statuses['timeout']},"
                f"recovered={recovered},"
                f"watchdog_fired={stats.get('watchdog_fired', 0)}")
    rows.append(f"serve_chaos.slo,0,"
                f"base_rate={base_rate:.4f},chaos_rate={chaos_rate:.4f},"
                f"delta={slo_delta:.4f},"
                f"breaker_recoveries={len(recoveries)},"
                f"mean_recovery_s="
                f"{mean_recovery_s if mean_recovery_s is None else round(mean_recovery_s, 3)}")

    payload = {
        "programs": REAL_TRACE_PROGRAMS,
        "n_requests": n_requests,
        "seed": seed,
        "backend": backend,
        "window": window,
        "workers": workers,
        "scale_index": scale_index,
        "watchdog_s": watchdog_s,
        "fault_schedule": os.path.relpath(fault_schedule, ROOT),
        "fault_plan": faults.to_json(),
        "faults_injected": faults.fired,
        "cpu_count": os.cpu_count(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "baseline_wall_s": base_wall,
        "chaos_wall_s": wall,
        "statuses": dict(statuses),
        "stats": stats,
        "chaos_crashes": crashes,
        "chaos_recovered": recovered,
        "chaos_terminal_fraction": terminal_fraction,
        "chaos_failed_fraction": failed_fraction,
        "chaos_degraded_fraction": degraded_fraction,
        "base_slo_violation_rate": base_rate,
        "chaos_slo_violation_rate": chaos_rate,
        "chaos_slo_violation_delta": slo_delta,
        "breaker_recoveries": len(recoveries),
        "mean_recovery_s": mean_recovery_s,
        "metrics": metrics.snapshot() if metrics is not None else {},
        "telemetry_summary": (sched.telemetry.summary()
                              if sched is not None else None),
    }
    os.makedirs(os.path.dirname(os.path.abspath(json_path)), exist_ok=True)
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=1)
    rows.append(f"# resilience JSON written to {json_path}")
    return rows


def model_eval(programs=None, *, datasets: int = 2, reps: int = 1,
               epochs: int = 600,
               json_path: str = "BENCH_model.json") -> list[str]:
    """Leave-one-program-out model evaluation: the learnt MLP's achieved
    speedup vs the per-cell oracle AND vs the zero-training overlap
    heuristic on the SAME profiled corpus.

    This is the offline-model quality gate (the paper's §5.3.1 protocol
    on our corpus): ``model_frac_of_oracle`` tracks the headline
    "% of oracle" number, and ``model_vs_heuristic`` asserts the trained
    model actually beats the stand-in it replaced on the serving default
    path.  Both land in ``BENCH_model.json`` for
    ``check_regression.py``; profiling reuses (and extends) the persistent
    profile cache, which CI restores via ``actions/cache``."""
    from repro.core.modeling import OverlapHeuristicModel
    from repro.core.modeling.artifacts import corpus_fingerprint
    from repro.core.modeling.evaluate import evaluate_model, loo_evaluate
    from repro.launch.train_model import DEFAULT_TRAIN_PROGRAMS

    programs = programs or list(DEFAULT_TRAIN_PROGRAMS)
    samples = ds.generate(programs, datasets_per_program=datasets,
                          reps=reps, verbose=True)
    rows = []

    t0 = time.perf_counter()
    cv = loo_evaluate(samples, train_kwargs={"epochs": epochs},
                      verbose=True)
    t_cv = time.perf_counter() - t0
    heur = evaluate_model(OverlapHeuristicModel(), samples)

    for prog, r in sorted(cv["per_program"].items()):
        rows.append(f"model_eval.loo.{prog},0,"
                    f"achieved={r['achieved']:.3f}x,"
                    f"oracle={r['oracle']:.3f}x,"
                    f"pct_of_oracle={100 * r['frac_of_oracle']:.1f}")
    vs_heur = cv["mean_achieved"] / heur["mean_speedup"]
    rows.append(f"model_eval.mean,0,"
                f"model={cv['mean_achieved']:.3f}x,"
                f"heuristic={heur['mean_speedup']:.3f}x,"
                f"oracle={cv['mean_oracle']:.3f}x,"
                f"frac_of_oracle={cv['frac_of_oracle']:.3f},"
                f"vs_heuristic={vs_heur:.3f}x")

    payload = {
        "programs": programs,
        "datasets_per_program": datasets,
        "reps": reps,
        "epochs": epochs,
        "n_cells": cv["n_cells"],
        "corpus_fingerprint": corpus_fingerprint(samples),
        "cv_wall_s": t_cv,
        "model": cv,
        "heuristic": heur,
        "model_frac_of_oracle": cv["frac_of_oracle"],
        "heuristic_frac_of_oracle": heur["frac_of_oracle"],
        "model_vs_heuristic": vs_heur,
        "target_frac_of_oracle": 0.93,
    }
    os.makedirs(os.path.dirname(os.path.abspath(json_path)), exist_ok=True)
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=1)
    rows.append(f"# model-eval JSON written to {json_path}")
    return rows


def dryrun_summary() -> list[str]:
    rows = []
    for path in sorted(glob.glob(os.path.join(
            ROOT, "benchmarks", "data", "dryrun", "*.json"))):
        try:
            with open(path) as f:
                d = json.load(f)
        except (OSError, ValueError):
            continue
        if "roofline" not in d:
            continue
        r = d["roofline"]
        rows.append(
            f"dryrun.{d['arch']}.{d['shape']}."
            f"{'pod2' if 'pod' in d['mesh'] else 'pod1'},"
            f"{r['bound_s']*1e6:.0f},"
            f"dominant={r['dominant']},frac={r['roofline_fraction']:.4f}"
            if "bound_s" in r else
            f"dryrun.{d['arch']}.{d['shape']},"
            f"{max(r['compute_s'], r['memory_s'], r['collective_s'])*1e6:.0f},"
            f"dominant={r['dominant']},frac={r['roofline_fraction']:.4f}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--programs", default=None)
    ap.add_argument("--datasets", type=int, default=3)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--compare-backends", action="store_true",
                    help="A/B every runner backend; skips the paper figures")
    ap.add_argument("--serve", action="store_true",
                    help="static-vs-adaptive serving trace; skips the "
                         "paper figures")
    ap.add_argument("--serve-requests", type=int, default=12)
    ap.add_argument("--serve-backend", default="host-sync")
    ap.add_argument("--serve-json", default=None,
                    help="write the serving comparison + telemetry JSON")
    ap.add_argument("--serve-concurrent", action="store_true",
                    help="serial-vs-concurrent engine throughput on a "
                         "long mixed trace; writes BENCH_serving.json")
    ap.add_argument("--serve-window", type=int, default=8,
                    help="concurrent engine in-flight window")
    ap.add_argument("--serve-workers", type=int, default=None)
    ap.add_argument("--serve-scale", type=int, default=8,
                    help="dataset scale index for the concurrent trace")
    ap.add_argument("--serve-trace", action="store_true",
                    help="virtual-time tail-latency trace replay over "
                         "every queue policy; writes BENCH_latency.json")
    ap.add_argument("--trace-requests", type=int, default=100_000,
                    help="requests per generated trace for --serve-trace")
    ap.add_argument("--trace-seed", type=int, default=0)
    ap.add_argument("--serve-real-trace", action="store_true",
                    help="replay a generated trace through the REAL "
                         "concurrent engine (kernels executing, wall "
                         "clock) with span tracing + metrics live; "
                         "writes BENCH_overhead.json")
    ap.add_argument("--real-trace-requests", type=int, default=10_000,
                    help="requests for --serve-real-trace")
    ap.add_argument("--real-trace-scale", type=int, default=0,
                    help="dataset scale index for --serve-real-trace")
    ap.add_argument("--profile-alloc", action="store_true",
                    help="--serve-real-trace: add a shorter tracemalloc "
                         "pass reporting top hot-path allocation sites")
    ap.add_argument("--chrome-trace", default=None,
                    help="--serve-real-trace: export the span trace as "
                         "Chrome trace-event JSON (Perfetto-loadable)")
    ap.add_argument("--metrics-out", default=None,
                    help="--serve-real-trace: save the metrics registry "
                         "snapshot JSON here")
    ap.add_argument("--serve-chaos", action="store_true",
                    help="fault-free vs fault-injected run of the real "
                         "engine with the resilience layer live; writes "
                         "BENCH_resilience.json")
    ap.add_argument("--chaos-requests", type=int, default=400,
                    help="requests per run for --serve-chaos")
    ap.add_argument("--chaos-backend", default="host-threads",
                    help="--serve-chaos primary backend (must differ "
                         "from host-sync for the dispatch-fallback rung "
                         "to be exercised)")
    ap.add_argument("--fault-schedule", default=DEFAULT_FAULT_SCHEDULE,
                    help="--serve-chaos: committed FaultPlan JSON")
    ap.add_argument("--chaos-watchdog-ms", type=float, default=250.0,
                    help="--serve-chaos execution watchdog (ms)")
    ap.add_argument("--serve-fleet", action="store_true",
                    help="fleet throughput scaling: tenant-sharded "
                         "router over 1..N worker processes + SIGKILL "
                         "respawn drill -> BENCH_fleet.json")
    ap.add_argument("--fleet-workers", type=int, default=4,
                    help="max worker-process count for --serve-fleet")
    ap.add_argument("--fleet-requests", type=int, default=24,
                    help="requests per trace pass for --serve-fleet")
    ap.add_argument("--fleet-window", type=int, default=2,
                    help="per-worker engine window for --serve-fleet")
    ap.add_argument("--fleet-scale", type=int, default=4,
                    help="dataset scale index for --serve-fleet")
    ap.add_argument("--fleet-reps", type=int, default=3,
                    help="timed passes per worker count (min wall wins)")
    ap.add_argument("--fleet-tenants", type=int, default=8,
                    help="tenant count for --serve-fleet (8 spreads "
                         "evenly over 2 and 4 shards)")
    ap.add_argument("--no-kill-drill", action="store_true",
                    help="skip the --serve-fleet SIGKILL respawn drill")
    ap.add_argument("--serve-oracle", action="store_true",
                    help="long-trace oracle-regret benchmark (adaptive "
                         "steady state vs exhaustive per-workload "
                         "oracle); writes BENCH_oracle.json")
    ap.add_argument("--tenants", type=int, default=3,
                    help="isolated tenants for --serve-oracle")
    ap.add_argument("--oracle-rounds", type=int, default=12,
                    help="trace rounds over the program mix for "
                         "--serve-oracle")
    ap.add_argument("--oracle-scale", type=int, default=8,
                    help="dataset scale index for --serve-oracle")
    ap.add_argument("--model-eval", action="store_true",
                    help="leave-one-program-out model quality: learnt "
                         "MLP vs heuristic vs oracle on one profiled "
                         "corpus; writes BENCH_model.json")
    ap.add_argument("--eval-epochs", type=int, default=600,
                    help="MLP epochs per LOO fold for --model-eval")
    ap.add_argument("--eval-datasets", type=int, default=2,
                    help="dataset scales per program for --model-eval")
    args = ap.parse_args()

    if args.model_eval:
        print("name,us_per_call,derived")
        for row in model_eval(
                args.programs.split(",") if args.programs else None,
                datasets=args.eval_datasets, reps=args.reps,
                epochs=args.eval_epochs,
                json_path=args.serve_json or "BENCH_model.json"):
            print(row)
        return

    if args.serve_real_trace:
        print("name,us_per_call,derived")
        for row in serve_real_trace(
                n_requests=args.real_trace_requests,
                seed=args.trace_seed, window=args.serve_window,
                workers=args.serve_workers,
                scale_index=args.real_trace_scale,
                backend=args.serve_backend,
                profile_alloc=args.profile_alloc,
                chrome_trace=args.chrome_trace,
                metrics_out=args.metrics_out,
                json_path=args.serve_json or "BENCH_overhead.json"):
            print(row)
        return

    if args.serve_chaos:
        print("name,us_per_call,derived")
        for row in serve_chaos(
                n_requests=args.chaos_requests, seed=args.trace_seed,
                window=args.serve_window, workers=args.serve_workers,
                backend=args.chaos_backend,
                fault_schedule=args.fault_schedule,
                watchdog_s=args.chaos_watchdog_ms / 1e3,
                json_path=args.serve_json or "BENCH_resilience.json"):
            print(row)
        return

    if args.serve_fleet:
        print("name,us_per_call,derived")
        for row in serve_fleet(
                args.programs.split(",") if args.programs else None,
                n_workers=args.fleet_workers,
                n_requests=args.fleet_requests,
                window=args.fleet_window,
                backend=args.serve_backend,
                scale_index=args.fleet_scale,
                tenants=args.fleet_tenants,
                reps=args.fleet_reps,
                kill_drill=not args.no_kill_drill,
                json_path=args.serve_json or "BENCH_fleet.json"):
            print(row)
        return

    if args.serve_trace:
        print("name,us_per_call,derived")
        for row in serve_latency_trace(
                n_requests=args.trace_requests, seed=args.trace_seed,
                window=args.serve_window,
                json_path=args.serve_json or "BENCH_latency.json"):
            print(row)
        return

    if args.serve_oracle:
        print("name,us_per_call,derived")
        for row in serve_oracle_trace(
                args.programs.split(",") if args.programs else None,
                tenants=args.tenants, rounds=args.oracle_rounds,
                backend=args.serve_backend,
                window=args.serve_window, workers=args.serve_workers,
                scale_index=args.oracle_scale,
                json_path=args.serve_json or "BENCH_oracle.json"):
            print(row)
        return

    if args.serve_concurrent:
        print("name,us_per_call,derived")
        for row in serve_concurrent_trace(
                args.programs.split(",") if args.programs else None,
                n_requests=args.serve_requests,
                backend=args.serve_backend,
                window=args.serve_window, workers=args.serve_workers,
                scale_index=args.serve_scale,
                json_path=args.serve_json or "BENCH_serving.json"):
            print(row)
        return

    if args.compare_backends:
        print("name,us_per_call,derived")
        for row in compare_backends(
                args.programs.split(",") if args.programs else None,
                reps=max(args.reps, 3)):
            print(row)
        return

    if args.serve:
        print("name,us_per_call,derived")
        for row in serve_trace(
                args.programs.split(",") if args.programs else None,
                n_requests=args.serve_requests,
                backend=args.serve_backend,
                json_path=args.serve_json):
            print(row)
        return

    if args.programs:
        programs = args.programs.split(",")
    elif args.quick:
        programs = QUICK_PROGRAMS
    else:
        programs = None  # all 39

    samples = ds.generate(programs, datasets_per_program=args.datasets,
                          reps=args.reps, verbose=True)
    print(f"# {len(samples)} profiled samples over "
          f"{len({s.program for s in samples})} programs")
    print("name,us_per_call,derived")

    for row in pf.fig2_heatmap(samples):
        print(row)
    fig9_rows, summary = pf.fig9_overall(samples)
    for row in fig9_rows:
        print(row)
    for row in pf.fig10_fixed(samples):
        print(row)
    for row in pf.fig12_analytical(samples):
        print(row)
    for row in pf.fig14_classifier(samples):
        print(row)
    for row in pf.table5_models(samples):
        print(row)
    for row in pf.search_overhead(samples):
        print(row)
    for row in dryrun_summary():
        print(row)
    print(f"# SUMMARY ours={summary['ours']:.3f}x "
          f"oracle={summary['oracle']:.3f}x "
          f"pct_of_oracle={summary['pct']:.1f}%")


if __name__ == "__main__":
    main()
