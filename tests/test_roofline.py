"""Roofline machinery: collective parser (incl. while-loop trip counts)
and the jaxpr cost walker vs XLA's own analysis on unrolled modules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (RooflineTerms, _loop_trip_count,
                                     collective_bytes)
from repro.roofline.jaxpr_cost import Cost, step_cost

FAKE_HLO = """\
HloModule test

%add.clone (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %r = f32[] add(%x, %y)
}

%cond (arg: (s32[], f32[16,8])) -> pred[] {
  %arg = (s32[], f32[16,8]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (arg: (s32[], f32[16,8])) -> (s32[], f32[16,8]) {
  %arg = (s32[], f32[16,8]) parameter(0)
  %x = f32[16,8] get-tuple-element(%arg), index=1
  %ar = f32[16,8] all-reduce(%x), replica_groups={}, to_apply=%add.clone
  %i = s32[] get-tuple-element(%arg), index=0
  ROOT %t = (s32[], f32[16,8]) tuple(%i, %ar)
}

ENTRY %main (p0: f32[16,8], p1: f32[32,4]) -> f32[16,8] {
  %p0 = f32[16,8] parameter(0)
  %p1 = f32[32,4] parameter(1)
  %ag = f32[32,4] all-gather(%p1), dimensions={0}
  %init = (s32[], f32[16,8]) tuple(s32[] constant(0), %p0)
  %w = (s32[], f32[16,8]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[16,8] get-tuple-element(%w), index=1
}
"""


def test_collective_parser_with_loop_multiplier():
    res = collective_bytes(FAKE_HLO)
    # all-reduce inside a 12-trip while: 16*8*4 bytes * 12
    assert res["all-reduce"] == 16 * 8 * 4 * 12
    assert res["counts"]["all-reduce"] == 12
    # all-gather at top level once: operand f32[32,4]
    assert res["all-gather"] == 32 * 4 * 4
    assert res["total"] == res["all-reduce"] + res["all-gather"]


def test_trip_count_extraction():
    assert _loop_trip_count(["  %c = s32[] constant(42)"]) == 42
    assert _loop_trip_count([]) == 1


def test_jaxpr_walker_dot_flops():
    f = lambda a, b: a @ b
    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 16), jnp.float32)
    c = step_cost(f, a, b)
    assert c.flops == 2 * 32 * 64 * 16
    assert c.bytes == (32 * 64 + 64 * 16 + 32 * 16) * 4


def test_jaxpr_walker_scan_multiplies():
    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    c = step_cost(f, x)
    assert c.flops == 7 * 2 * 16 ** 3


def test_jaxpr_walker_grad_includes_backward():
    def loss(w, x):
        return jnp.sum((x @ w) ** 2)
    w = jax.ShapeDtypeStruct((8, 4), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 8), jnp.float32)
    fwd = step_cost(loss, w, x)
    both = step_cost(jax.grad(loss), w, x)
    # grad-wrt-w only: forward + the dw matmul (~2x the forward flops)
    assert both.flops >= 1.8 * fwd.flops


@pytest.mark.slow
def test_walker_vs_xla_on_unrolled_model():
    """Agreement with XLA cost analysis on a no-loop module (the case
    where XLA's numbers are trustworthy)."""
    from repro.models.model_zoo import build_model
    from repro.models.transformer import RunConfig
    m = build_model("stablelm-3b", RunConfig(scan_layers=False),
                    reduced=True)
    params, _ = m.init(jax.random.key(0))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    fn = jax.jit(lambda p, b: m.loss(p, b)[0])
    compiled = fn.lower(params, batch).compile()
    from repro.core.xla_cost import cost_analysis_dict
    xla_flops = float(cost_analysis_dict(compiled)["flops"])
    ours = step_cost(fn, params, batch).flops
    assert 0.5 < ours / xla_flops < 2.0, (ours, xla_flops)


def test_roofline_terms_dominant():
    t = RooflineTerms(compute_s=1.0, memory_s=0.5, collective_s=2.0,
                      flops_per_chip=1, bytes_per_chip=1,
                      coll_bytes_per_chip=1, model_flops=197e12 * 256,
                      n_chips=256)
    assert t.dominant == "collective"
    assert t.bound_s == 2.0
    assert 0 < t.roofline_fraction <= 1.0


def test_cost_addition():
    a = Cost(1.0, 2.0, 0.0) + Cost(3.0, 4.0, 1.0) * 2
    assert a.flops == 7.0 and a.bytes == 10.0
