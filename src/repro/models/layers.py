"""Core layers and the Leaf param system.

Parameters are created as ``Leaf(value, axes)`` where ``axes`` is a tuple of
*logical* axis names consumed by ``repro.parallel.sharding_rules.AxisRules``.
``split(tree)`` separates values from axes so the values tree can be passed
through jit/grad while the axes tree builds PartitionSpecs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding_rules import AxisRules


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Leaf:
    value: jax.Array
    axes: tuple

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)


def is_leaf(x) -> bool:
    return isinstance(x, Leaf)


def values(tree):
    return jax.tree.map(lambda l: l.value, tree, is_leaf=is_leaf)


def axes(tree):
    return jax.tree.map(lambda l: l.axes, tree, is_leaf=is_leaf)


def param_count(tree) -> int:
    return sum(
        int(l.value.size) for l in jax.tree.leaves(tree, is_leaf=is_leaf)
    )


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, logical_axes, dtype=jnp.float32, *, fan_in=None) -> Leaf:
    """Truncated-normal scaled by 1/sqrt(fan_in) (first axis by default)."""
    fan = fan_in if fan_in is not None else shape[0]
    std = 1.0 / jnp.sqrt(jnp.maximum(fan, 1)).astype(jnp.float32)
    v = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std
    return Leaf(v.astype(dtype), tuple(logical_axes))


def embed_init(key, shape, logical_axes, dtype=jnp.float32) -> Leaf:
    v = jax.random.normal(key, shape, jnp.float32)
    return Leaf(v.astype(dtype), tuple(logical_axes))


def zeros_init(shape, logical_axes, dtype=jnp.float32) -> Leaf:
    return Leaf(jnp.zeros(shape, dtype), tuple(logical_axes))


def ones_init(shape, logical_axes, dtype=jnp.float32) -> Leaf:
    return Leaf(jnp.ones(shape, dtype), tuple(logical_axes))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": ones_init((d,), ("embed",), dtype)}


def rmsnorm_apply(params: dict, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# MLP (gated SwiGLU or classic GELU)
# ---------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, *, gated: bool, dtype=jnp.float32,
             ff_axis: str = "ff") -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(ks[0], (d_model, d_ff), ("embed", ff_axis), dtype),
        "w_out": dense_init(ks[1], (d_ff, d_model), (ff_axis, "embed"), dtype),
    }
    if gated:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), ("embed", ff_axis), dtype)
    return p


def mlp_apply(params: dict, x: jax.Array, rules: AxisRules) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, params["w_in"])
    h = rules.constrain(h, *(("batch",) + ("seq",) * (x.ndim - 2) + ("ff",)))
    if "w_gate" in params:
        g = jnp.einsum("...d,df->...f", x, params["w_gate"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    out = jnp.einsum("...f,fd->...d", h, params["w_out"])
    return rules.constrain(
        out, *(("batch",) + ("seq",) * (x.ndim - 2) + ("embed_act",))
    )


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def embedding_init(key, vocab: int, d_model: int, dtype=jnp.float32) -> dict:
    return {"table": embed_init(key, (vocab, d_model), ("vocab", "embed"), dtype)}


def embedding_lookup(params: dict, tokens: jax.Array, rules: AxisRules) -> jax.Array:
    out = jnp.take(params["table"], tokens, axis=0)
    return rules.constrain(out, "batch", "seq", "embed_act")


def lm_head_apply(table: jax.Array, x: jax.Array, rules: AxisRules) -> jax.Array:
    """Project hidden states to vocab logits (weights (vocab, d_model))."""
    logits = jnp.einsum("...d,vd->...v", x, table)
    return rules.constrain(logits, *(("batch",) + ("seq",) * (x.ndim - 2) + ("vocab",)))


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token cross-entropy. logits (B,S,V) f32-upcast, labels (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
