"""The two analytical baselines the paper compares against (§5.2).

Both need per-program coefficients fitted from small empirical probes — the
same probes our feature extractor already measures (transfer time, compute
time vs. data size).

Liu et al. [12]: linear models  T_t = alpha*m + beta,  T_c = eta*m + gamma;
kernel-dominated total  T = alpha*m + N*gamma/m + N*eta + beta  minimized at
m* = sqrt(N*gamma/alpha)  ->  n = N/m*.  Transfer-dominated programs get
m = N/2 (2 tasks).  #partitions := #tasks (as the paper does on XeonPhi).

Werkhoven et al. [10]: LogGP transfer model; the optimal #tasks solves
  B_dh*G_dh + g*(Ns-1) = max(T_kernel/Ns + B_dh/Ns*G_dh,
                             B_hd/Ns*G_hd + T_kernel/Ns).
We solve it numerically by evaluating the predicted makespan over the
candidate Ns grid and taking the argmin — equivalent and robust.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.stream_config import StreamConfig


@dataclasses.dataclass
class ProgramProbe:
    """Per-program empirical probe (seconds / bytes)."""

    n_rows: int
    bytes_h2d: float
    bytes_d2h: float
    t_transfer: float   # H2D time of the full input
    t_kernel: float     # single-stream kernel time
    t_overhead: float = 20e-6  # per-dispatch overhead (beta / g / o)


def liu_config(probe: ProgramProbe, max_tasks: int = 64) -> StreamConfig:
    N = float(probe.n_rows)
    alpha = probe.t_transfer / max(probe.bytes_h2d, 1.0)   # s/byte
    beta = probe.t_overhead
    eta = probe.t_kernel / N                               # s/row
    gamma = probe.t_overhead                               # per-task kernel setup

    if probe.t_kernel >= probe.t_transfer:
        # kernel-dominated: m* = sqrt(N*gamma/alpha_rows)
        alpha_rows = probe.t_transfer / N
        m_star = math.sqrt(N * gamma / max(alpha_rows, 1e-12))
        n = N / max(m_star, 1.0)
    else:
        # transfer-dominated: optimal m = N/2 -> 2 tasks
        n = 2.0
    n = int(np.clip(round(n), 1, max_tasks))
    return StreamConfig(partitions=n, tasks=n)


def werkhoven_config(probe: ProgramProbe, max_tasks: int = 64) -> StreamConfig:
    """Evaluate the LogGP makespan for each Ns and take the argmin."""
    g = probe.t_overhead
    Gdh = probe.t_transfer / max(probe.bytes_h2d, 1.0)  # s/byte (symmetric)
    Ghd = Gdh
    Bdh, Bhd = probe.bytes_d2h, probe.bytes_h2d
    Tk = probe.t_kernel

    best_ns, best_t = 1, float("inf")
    ns = 1
    while ns <= max_tasks:
        if Bdh > Bhd:
            rhs = Tk / ns + (Bdh / ns) * Gdh
        else:
            rhs = (Bhd / ns) * Ghd + Tk / ns
        makespan = max(Bdh * Gdh + g * (ns - 1), rhs) + Bhd * Ghd / ns
        if makespan < best_t:
            best_ns, best_t = ns, makespan
        ns *= 2
    return StreamConfig(partitions=best_ns, tasks=best_ns)


def probe_from_features(feats: dict) -> ProgramProbe:
    """Build a probe from the raw feature dict (features.RAW_FEATURE_NAMES)."""
    return ProgramProbe(
        n_rows=int(feats["loop_count"]),
        bytes_h2d=float(feats["dts"]),
        bytes_d2h=float(feats["out_bytes"]),
        t_transfer=float(feats["t_transfer_us"]) * 1e-6,
        t_kernel=float(feats["t_compute_us"]) * 1e-6,
    )
