"""Property-based tests (hypothesis) on system invariants.

hypothesis is an optional test dependency (see README); the module is
skipped cleanly when it is absent so collection never fails.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.checkpoint.checkpointer import _flatten, _unflatten
from repro.core.features import config_features
from repro.core.perf_model import FeaturePipeline
from repro.core.stream_config import StreamConfig
from repro.models.attention import flash_attention, reference_attention
from repro.optim.grad_compression import dequantize_int8, quantize_int8

SETTINGS = dict(max_examples=20, deadline=None)


@settings(**SETTINGS)
@given(
    B=st.integers(1, 2),
    S=st.integers(1, 48),
    KV=st.sampled_from([1, 2, 4]),
    G=st.sampled_from([1, 2, 3]),
    hd=st.sampled_from([4, 8, 16]),
    qb=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_invariant(B, S, KV, G, hd, qb, seed):
    """Blocked online-softmax == naive attention for ALL shapes/blocks."""
    H = KV * G
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = flash_attention(q, k, v, q_block=qb, kv_block=qb)
    ref = reference_attention(q, k, v)
    assert jnp.allclose(out, ref, atol=3e-5)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1),
       scale=st.floats(1e-3, 1e3),
       n=st.integers(1, 512))
def test_int8_quantization_error_bound(seed, scale, n):
    """|dequant(quant(g)) - g| <= scale_step/2 elementwise."""
    g = np.random.default_rng(seed).normal(0, scale, n).astype(np.float32)
    q, s = quantize_int8(jnp.asarray(g))
    back = dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 0.5 + 1e-6


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1),
       rows=st.integers(30, 200),
       cols=st.integers(3, 12),
       ncomp=st.integers(1, 9))
def test_feature_pipeline_invariants(seed, rows, cols, ncomp):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(rows, cols))
    y = rng.normal(size=rows)
    pipe = FeaturePipeline.fit(X, y, n_components=ncomp)
    Z = pipe.transform(X)
    assert Z.shape[0] == rows and Z.shape[1] <= ncomp
    assert np.isfinite(Z).all()
    np.testing.assert_allclose(pipe.inverse_y(pipe.transform_y(y)), y,
                               rtol=1e-6, atol=1e-8)


@settings(**SETTINGS)
@given(p=st.sampled_from([1, 2, 4, 8, 16, 32]),
       t=st.sampled_from([1, 2, 4, 8, 16, 32, 64]))
def test_config_features_finite(p, t):
    f = config_features(p, t)
    assert np.isfinite(f).all()
    assert StreamConfig(p, t).as_tuple() == (p, t)


@settings(**SETTINGS)
@given(st.recursive(
    st.integers(0, 5).map(lambda n: np.arange(n, dtype=np.float32)),
    lambda children: st.dictionaries(
        st.text(alphabet="abcdef", min_size=1, max_size=4), children,
        min_size=1, max_size=3),
    max_leaves=8).filter(lambda t: isinstance(t, dict)))
def test_checkpoint_flatten_roundtrip(tree):
    back = _unflatten(_flatten(tree))
    la, lb = jax.tree.leaves(tree), jax.tree.leaves(back)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(a, b)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), rows=st.integers(8, 64),
       eps=st.floats(1e-6, 1e-3))
def test_rmsnorm_output_scale(seed, rows, eps):
    """RMSNorm output has unit RMS when scale=1."""
    from repro.models.layers import rmsnorm_apply
    x = np.random.default_rng(seed).normal(2.0, 3.0, (rows, 32)).astype(
        np.float32)
    y = rmsnorm_apply({"scale": jnp.ones(32)}, jnp.asarray(x), eps=eps)
    rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1))
    assert jnp.allclose(rms, 1.0, atol=1e-2)
