"""Training-data generation + cross-validation (paper §3.1.1, §5.3.1).

Exhaustively profiles every (program, dataset, stream-config) cell, caches
the results as JSON (profiling is the expensive one-off "at the factory"
step), and assembles (features ++ config) -> speedup training matrices with
leave-one-out splits over *programs*.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time
from pathlib import Path
from typing import Optional, Sequence

import numpy as np

from repro.core import REPO_ROOT
from repro.core import features as feat_lib
from repro.core.stream_config import StreamConfig, default_space
from repro.core.streams import StreamedRunner, profile_config_grid
from repro.core.workloads import get_workload, list_workloads


def default_cache_path() -> Path:
    """The profile cache location: ``REPRO_PROFILE_CACHE`` when set
    (resolved per call, so tests and CI can redirect it), else the
    in-repo ``benchmarks/data/profile_cache.json``."""
    env = os.environ.get("REPRO_PROFILE_CACHE")
    return Path(env) if env else (
        REPO_ROOT / "benchmarks" / "data" / "profile_cache.json")


#: import-time snapshot, kept for callers that treat it as a constant;
#: prefer ``default_cache_path()`` (honors a later env override)
DEFAULT_CACHE = default_cache_path()


@dataclasses.dataclass
class Sample:
    """One (program, dataset) cell with its full profiled config grid."""

    program: str
    scale: int
    features: np.ndarray                 # (22,) raw features
    t_single: float                      # single-stream seconds
    times: dict                          # {(p, t): seconds}

    def speedup(self, cfg: StreamConfig) -> float:
        return self.t_single / self.times[cfg.as_tuple()]

    @property
    def best_config(self) -> StreamConfig:
        p, t = min(self.times, key=self.times.get)
        return StreamConfig(p, t)

    @property
    def oracle_speedup(self) -> float:
        return self.t_single / min(self.times.values())

    def to_json(self) -> dict:
        return {
            "program": self.program,
            "scale": self.scale,
            "features": self.features.tolist(),
            "t_single": self.t_single,
            "times": [[p, t, v] for (p, t), v in self.times.items()],
        }

    @staticmethod
    def from_json(d: dict) -> "Sample":
        return Sample(
            d["program"], d["scale"], np.asarray(d["features"], np.float64),
            d["t_single"],
            {(p, t): v for p, t, v in d["times"]},
        )


def grid_for(n_rows: int, max_partitions: int = 32,
             max_tasks: int = 64) -> list[StreamConfig]:
    return [c for c in default_space(max_partitions, max_tasks)
            if c.partitions * c.tasks <= n_rows]


def profile_sample(program: str, scale: int, *, reps: int = 2,
                   seed: int = 0) -> Sample:
    wl = get_workload(program)
    rng = np.random.default_rng(seed + scale)
    chunked, shared = wl.make_data(scale, rng)
    runner = StreamedRunner(wl, chunked, shared)
    feats = feat_lib.extract_features(runner, profile_reps=reps)
    grid = grid_for(scale)
    times = profile_config_grid(runner, grid, reps=reps)
    t_single = times[StreamConfig(1, 1)]
    return Sample(program, scale, feats.values, t_single,
                  {c.as_tuple(): v for c, v in times.items()})


def generate(
    programs: Optional[Sequence[str]] = None,
    *,
    datasets_per_program: int = 4,
    reps: int = 2,
    cache_path: "str | Path | None" = None,
    verbose: bool = True,
) -> list[Sample]:
    """Profile (or load cached) samples for the suite."""
    programs = list(programs or list_workloads())
    cache_path = Path(cache_path) if cache_path else default_cache_path()
    cache = _load_cache(cache_path)
    samples: list[Sample] = []
    dirty = False
    for prog in programs:
        wl = get_workload(prog)
        scales = _pick_scales(wl.datasets, datasets_per_program)
        for scale in scales:
            key = f"{prog}@{scale}"
            if key in cache:
                samples.append(Sample.from_json(cache[key]))
                continue
            t0 = time.perf_counter()
            s = profile_sample(prog, scale, reps=reps)
            cache[key] = s.to_json()
            dirty = True
            samples.append(s)
            if verbose:
                # progress goes to stderr: callers (serve --adaptive,
                # benchmarks) reserve stdout for JSON/CSV payloads
                print(f"profiled {key:28s} oracle={s.oracle_speedup:5.2f}x "
                      f"({time.perf_counter()-t0:5.1f}s)",
                      file=sys.stderr, flush=True)
        if dirty:
            _save_cache(cache_path, cache)  # checkpoint per program
            dirty = False
    return samples


def _pick_scales(scales: tuple, k: int) -> list[int]:
    if k >= len(scales):
        return list(scales)
    idx = np.linspace(0, len(scales) - 1, k).round().astype(int)
    return [scales[i] for i in np.unique(idx)]


def _load_cache(path: "str | Path") -> dict:
    path = Path(path)
    if path.exists():
        with open(path) as f:
            return json.load(f)
    return {}


def _save_cache(path: "str | Path", cache: dict) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(path.suffix + ".tmp")
    with open(tmp, "w") as f:
        json.dump(cache, f)
    os.replace(tmp, path)


# ---------------------------------------------------------------------------
# Matrices + cross-validation
# ---------------------------------------------------------------------------


def training_matrix(samples: Sequence[Sample]):
    """Rows = (program features ++ config encoding); target = speedup."""
    X, y = [], []
    for s in samples:
        for (p, t), sec in s.times.items():
            X.append(np.concatenate(
                [s.features, feat_lib.config_features(p, t)]))
            y.append(s.t_single / sec)
    return np.stack(X), np.asarray(y)


def loo_split(samples: Sequence[Sample], test_program: str):
    """Leave-one-out over programs (§5.3.1).  convsepr*/fftx* siblings are
    excluded together, as the paper does for convolutionFFT2d/Separable."""
    fam = _family(test_program)
    train = [s for s in samples if _family(s.program) != fam]
    test = [s for s in samples if s.program == test_program]
    return train, test


def _family(name: str) -> str:
    for prefix in ("convsepr", "fftx"):
        if name.startswith(prefix):
            return prefix
    return name
