"""Mixture-of-Experts FFN — GShard/Switch-style dispatch & combine einsums.

Two sharding modes (cf. DESIGN.md §Arch-applicability):
  - "ep": expert dim sharded over 'model' (arctic 128e, jamba 16e). The
    dispatch einsum keeps tokens batch-sharded; XLA inserts the all-to-all.
  - "tp": each expert's d_ff sharded over 'model' (grok 8e < 16-way axis);
    experts replicated, activations psum on the output contraction.

Top-k routing with capacity factor; overflowed tokens are dropped (their
combine weight is zero) — the dense-residual path (arctic) and the residual
stream keep them alive.  A load-balance auxiliary loss is returned.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import layers
from repro.parallel.sharding_rules import AxisRules


def moe_init(key, d_model: int, cfg: MoEConfig, *, gated: bool,
             dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    E, F = cfg.num_experts, cfg.expert_d_ff
    ff_axis = "expert_ff_tp" if cfg.sharding == "tp" else "expert_ff"
    e_axis = None if cfg.sharding == "tp" else "expert"
    p = {
        "router": layers.dense_init(
            ks[0], (d_model, E), ("embed", None), dtype),
        "w_in": layers.dense_init(
            ks[1], (E, d_model, F), (e_axis, "embed", ff_axis), dtype,
            fan_in=d_model),
        "w_out": layers.dense_init(
            ks[2], (E, F, d_model), (e_axis, ff_axis, "embed"), dtype,
            fan_in=F),
    }
    if gated:
        p["w_gate"] = layers.dense_init(
            ks[3], (E, d_model, F), (e_axis, "embed", ff_axis), dtype,
            fan_in=d_model)
    if cfg.dense_residual:
        p["dense"] = layers.mlp_init(
            ks[4], d_model, cfg.dense_d_ff, gated=gated, dtype=dtype)
    return p


def _top_k_mask(probs: jax.Array, k: int):
    """probs (..., E) -> (weights, one_hot_assignments list per slot)."""
    out_w, out_idx = jax.lax.top_k(probs, k)  # (..., k)
    return out_w, out_idx


def moe_apply(
    params: dict,
    x: jax.Array,  # (B, S, D)
    cfg: MoEConfig,
    rules: AxisRules,
    *,
    capacity_factor: float = 1.25,
    group_size: int = 512,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux load-balance loss scalar).

    Tokens are re-grouped to (n_groups, group_size) GShard-style before
    dispatch: the one-hot dispatch tensor is (G, g, E, C) with per-group
    capacity C = cf*g*k/E, so its footprint scales with tokens*g*cf*k
    instead of tokens*S*cf*k (a 4096-token sequence would otherwise
    materialize a multi-TB dispatch mask at pod scale)."""
    B0, S0, D = x.shape
    tokens = B0 * S0
    g = group_size
    while tokens % g:
        g //= 2
    x = x.reshape(tokens // g, g, D)
    B, S, _ = x.shape
    E, K = cfg.num_experts, cfg.top_k
    C = max(1, int(capacity_factor * S * K / E))

    router_logits = jnp.einsum(
        "bsd,de->bse", x, params["router"],
        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(router_logits, axis=-1)          # (B,S,E)
    gate_w, gate_idx = _top_k_mask(probs, K)                # (B,S,K)
    gate_w = gate_w / jnp.maximum(
        jnp.sum(gate_w, axis=-1, keepdims=True), 1e-9)

    # Position of each token in its expert's buffer, per routing slot.
    # one-hot over experts per slot: (B,S,K,E)
    slot_onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)
    # cumulative count along S and K gives the capacity position
    flat = slot_onehot.reshape(B, S * K, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat         # (B,S*K,E)
    pos_in_expert = jnp.sum(pos_in_expert * flat, axis=-1)  # (B,S*K)
    pos_in_expert = pos_in_expert.reshape(B, S, K)
    keep = pos_in_expert < C                                # drop overflow
    gate_w = gate_w * keep

    # dispatch (B,S,E,C) = sum_k onehot_e * onehot_c
    cap_onehot = jax.nn.one_hot(pos_in_expert, C, dtype=jnp.float32)  # (B,S,K,C)
    dispatch = jnp.einsum(
        "bske,bskc->bsec", slot_onehot, cap_onehot * keep[..., None])
    combine = jnp.einsum(
        "bske,bskc->bsec", slot_onehot * gate_w[..., None], cap_onehot)

    # dispatch/combine einsums run in the compute dtype: at pod scale the
    # combine contraction over the (model-sharded) expert dim is all-reduced
    # — f32 here would double that ICI traffic (§Perf, arctic hillclimb).
    expert_in = jnp.einsum(
        "bsec,bsd->becd", dispatch.astype(x.dtype), x)
    expert_in = rules.constrain(expert_in, "batch", "expert", None, "embed_act")

    h = jnp.einsum("becd,edf->becf", expert_in, params["w_in"])
    if "w_gate" in params:
        g = jnp.einsum("becd,edf->becf", expert_in, params["w_gate"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    expert_out = jnp.einsum("becf,efd->becd", h, params["w_out"])
    expert_out = rules.constrain(expert_out, "batch", "expert", None, "embed_act")

    y = jnp.einsum("bsec,becd->bsd", combine.astype(x.dtype), expert_out)

    if cfg.dense_residual:
        y = y + layers.mlp_apply(params["dense"], x, rules)

    # Switch-style load-balance aux loss.
    frac_tokens = jnp.mean(slot_onehot[:, :, 0, :], axis=(0, 1))  # top-1 assign
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)
    y = y.reshape(B0, S0, D)
    return rules.constrain(y, "batch", "seq", "embed_act"), aux
