"""Quickstart: the two faces of the framework in ~60 seconds on CPU.

1. Train a reduced-config assigned architecture end-to-end (synthetic data,
   AdamW, checkpointing).
2. Autotune the stream configuration of a data-parallel workload with the
   learned performance model (the paper's technique).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import dataset as ds
from repro.core.autotuner import AutoTuner
from repro.core.perf_model import PerformanceModel
from repro.core.workloads import get_workload
from repro.launch.train import train_loop

print("=== 1. train a reduced yi-9b for 30 steps ===")
res = train_loop("yi-9b", steps=30, batch=4, seq=32, verbose=True)
print(f"loss {res.losses[0]:.3f} -> {res.final_loss:.3f}\n")

print("=== 2. learn a performance model on 3 programs, tune a 4th ===")
samples = ds.generate(["vecadd", "binomial", "sgemm"],
                      datasets_per_program=2, reps=1,
                      cache_path="/tmp/quickstart_cache.json")
X, y = ds.training_matrix(samples)
model = PerformanceModel.train(X, y, epochs=300)

wl = get_workload("dotprod")  # never seen in training
chunked, shared = wl.make_data(2048, np.random.default_rng(0))
result = AutoTuner(model).tune(wl, chunked, shared)
print(f"chosen stream config for dotprod: "
      f"(partitions={result.config.partitions}, tasks={result.config.tasks})")
print(f"predicted speedup {result.predicted_speedup:.2f}x; "
      f"search took {result.search_seconds*1e3:.2f} ms "
      f"(feature extraction {result.feature_seconds*1e3:.0f} ms)")
