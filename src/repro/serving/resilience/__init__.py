"""Fault tolerance for the serving path.

Three pieces, threaded through ``AdaptiveScheduler``/
``ConcurrentScheduler`` via the ``faults=`` and ``resilience=``
constructor kwargs (both default off — the legacy path is untouched
when unset):

- :mod:`.faults` — deterministic seeded fault injection at named
  serving sites, so chaos results replay and gate in CI.
- :mod:`.retry` — deadline-aware capped-exponential-backoff retry
  around cold search and dispatch.
- :mod:`.degrade` — per-(tenant, stage) circuit breaker over the
  documented fallback ladder, plus crash-safe JSON persistence
  (atomic-write-rename, quarantine-and-rebuild).

:class:`ResiliencePolicy` bundles the knobs a scheduler needs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serving.resilience.degrade import (            # noqa: F401
    BreakerConfig, CircuitBreaker, atomic_write_json,
    nearest_bucket_entry, quarantine_file,
)
from repro.serving.resilience.faults import (             # noqa: F401
    NULL_FAULTS, SITES, FaultPlan, FaultSpec, InjectedFault,
    corrupt_json_file,
)
from repro.serving.resilience.retry import (              # noqa: F401
    RetryPolicy, call_with_retry,
)


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """Everything the schedulers need to survive a failing stage.

    ``watchdog_s`` arms the concurrent engine's execution watchdog: a
    dispatch running past it is abandoned (the worker finishes in the
    background and its runner is reclaimed on completion) and the
    request is requeued on a fresh runner at most ``requeue_limit``
    times before failing individually with ``status="timeout"``.
    """

    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    breaker: BreakerConfig = dataclasses.field(
        default_factory=BreakerConfig)
    watchdog_s: Optional[float] = None
    requeue_limit: int = 1
    fallback_backend: str = "host-sync"
    seed: int = 0


__all__ = [
    "BreakerConfig", "CircuitBreaker", "FaultPlan", "FaultSpec",
    "InjectedFault", "NULL_FAULTS", "ResiliencePolicy", "RetryPolicy",
    "SITES", "atomic_write_json", "call_with_retry", "corrupt_json_file",
    "nearest_bucket_entry", "quarantine_file",
]
