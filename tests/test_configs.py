"""Architecture config registry: exact specs + derived quantities."""
import pytest

from repro.configs.base import ALL_SHAPES, get_arch, list_archs

EXPECTED = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
    "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
    "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
    "yi-9b": (48, 4096, 32, 4, 11008, 64000),
    "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
    "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
    "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
    "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
}

# published total parameter counts (billions) and tolerance
PARAM_CHECKS = {
    "arctic-480b": (480, 0.05),
    "grok-1-314b": (314, 0.05),
    "jamba-1.5-large-398b": (398, 0.05),
    "starcoder2-15b": (15.5, 0.10),
    "pixtral-12b": (12.4, 0.10),
    "yi-9b": (8.8, 0.10),
    "codeqwen1.5-7b": (7.3, 0.15),
    "xlstm-350m": (0.35, 0.20),
}


def test_all_ten_archs_registered():
    assert len(list_archs()) == 10
    assert set(list_archs()) == set(EXPECTED)


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_exact_config(name):
    c = get_arch(name)
    exp = EXPECTED[name]
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == exp


@pytest.mark.parametrize("name,target", sorted(PARAM_CHECKS.items()))
def test_param_counts_vs_published(name, target):
    billions, tol = target
    got = get_arch(name).param_counts()["total"] / 1e9
    assert abs(got - billions) / billions < tol, (name, got, billions)


def test_jamba_active_params():
    pc = get_arch("jamba-1.5-large-398b").param_counts()
    assert abs(pc["active"] / 1e9 - 94) / 94 < 0.05  # paper: 94B active


def test_long_context_applicability():
    # sub-quadratic archs run long_500k; full-attention archs skip it
    subq = {a for a in list_archs() if get_arch(a).subquadratic}
    assert subq == {"jamba-1.5-large-398b", "xlstm-350m"}
    for a in list_archs():
        shapes = {s.name for s in get_arch(a).shapes()}
        assert ("long_500k" in shapes) == (a in subq)
        assert {"train_4k", "prefill_32k", "decode_32k"} <= shapes


def test_cell_count_is_40():
    # 10 archs x 4 shapes assigned; 32 run + 8 documented skips = 40 cells
    total = sum(len(ALL_SHAPES) for _ in list_archs())
    runnable = sum(len(get_arch(a).shapes()) for a in list_archs())
    skipped = sum(len(get_arch(a).skipped_shapes()) for a in list_archs())
    assert total == 40 and runnable == 32 and skipped == 8


def test_reduced_configs_are_tiny():
    for a in list_archs():
        r = get_arch(a).reduced()
        assert r.d_model <= 64 and r.vocab_size <= 256
        assert r.param_counts()["total"] < 5e6


def test_model_flops_ordering():
    c = get_arch("yi-9b")
    f = {s.name: c.model_flops(s) for s in c.shapes()}
    assert f["train_4k"] > f["prefill_32k"] > f["decode_32k"]
