"""Back-compat shim: moved to :mod:`repro.core.modeling.search`."""
from repro.core.modeling.search import (search_best, search_best_batch,
                                        simulated_annealing)

__all__ = ["search_best", "search_best_batch", "simulated_annealing"]
