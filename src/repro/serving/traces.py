"""Seeded synthetic request traces + a virtual-time replay harness.

The serving stack's tail behaviour — queueing delay under bursts,
SLO-violation rates per queue policy, drift-detector stability at deep
windows — only shows up at trace scale, and wall-clock replay of 10^5+
requests is hours.  This module makes those runs take seconds:

:func:`generate_trace`
    A deterministic generator of :class:`WorkloadRequest` streams.
    Arrivals are Poisson or bursty (a 2-state Markov-modulated Poisson
    process alternating quiet and burst segments); workload popularity
    is Zipf over the registered suite (all 39 programs by default);
    tenants are Zipf-skewed so one chatty tenant dominates; dataset
    scales churn (a rotating "hot" scale plus random off-scale draws)
    so new shape buckets keep arriving and the bucketed tuning cache
    never saturates; each request optionally carries an SLO deadline
    drawn from a mix of tight and slack classes.  Everything is driven
    by one seed: the same config always yields the identical trace.

:func:`simulate_trace`
    A discrete-event replay on a :class:`~repro.serving.clock.
    VirtualClock`.  It reuses the *real* serving primitives — the
    :class:`RequestQueue` (so ``deadline`` sheds in virtual time), the
    real :class:`DriftDetector`, real bucketed cache keys via
    :meth:`TuningCache.key` — and substitutes only the execution layer:
    service times come from a seeded :class:`ServiceModel` instead of
    running kernels.  Service noise is pre-drawn per arrival index, so
    two policies replaying the same trace see identical per-request
    service draws and their tail-latency numbers are directly
    comparable.  ``drift_injections`` shifts a workload's true cost
    mid-trace to exercise the detect→refine loop deterministically.

The harness models the coordinator/worker split the concurrent engine
has: placement decisions (cache lookup, cold tune, refinement) occupy a
serial coordinator timeline (``busy_until``), execution overlaps on up
to ``window`` slots, and each request's wall time is inflated by the
same :func:`contention_factor` the engine divides out of its drift
signal — plus a residual, occupancy-scaled noise term the normalization
cannot cancel, which is exactly the signal ``load_discount`` exists to
keep below the drift threshold.
"""
from __future__ import annotations

import dataclasses
import heapq
import zlib
from typing import Iterable, Iterator, Optional

import numpy as np

from repro.core.autotuner import TuningCache
from repro.core.workloads import get_workload, list_workloads
from repro.serving.clock import VirtualClock
from repro.serving.observability import NULL_METRICS, NULL_TRACER
from repro.serving.queue import POLICIES, RequestQueue, WorkloadRequest
from repro.serving.refinement import DriftDetector, contention_factor
from repro.serving.resilience import NULL_FAULTS, FaultPlan, InjectedFault
from repro.serving.telemetry import (TelemetryLog, TelemetrySample,
                                     latency_stats, relative_error)

__all__ = ["TraceConfig", "generate_trace", "ServiceModel",
           "simulate_trace"]


# ---------------------------------------------------------------------------
# trace generation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Everything :func:`generate_trace` draws from, seed included."""

    n_requests: int = 100_000
    seed: int = 0
    #: "poisson" (stationary rate) or "bursty" (2-state MMPP)
    arrival: str = "poisson"
    rate_rps: float = 450.0
    #: bursty only: arrival rate inside a burst segment
    burst_rate_rps: float = 1400.0
    #: bursty only: mean quiet / burst segment lengths (exponential dwell)
    base_dwell_s: float = 1.5
    burst_dwell_s: float = 0.25
    #: workload names to draw from; None = the full registered suite
    workloads: Optional[tuple] = None
    #: Zipf exponent for workload popularity (rank r gets p ~ 1/r^s)
    zipf_s: float = 1.1
    tenants: tuple = ("acme", "globex", "initech", "umbrella")
    #: Zipf exponent for tenant skew — 1.4 gives the lead tenant ~45%
    tenant_zipf_s: float = 1.4
    priorities: tuple = (0, 1, 2)
    #: indices into each workload's ``datasets`` tuple (clamped per
    #: workload); the first is the initial "hot" scale
    scale_indices: tuple = (2, 3, 4)
    #: probability a request draws a uniformly random scale instead of
    #: the hot one — the steady trickle of off-bucket shapes
    churn_prob: float = 0.05
    #: rotate which scale is hot every N requests (None = n/len(scales),
    #: so every configured scale gets a hot phase; 0 disables rotation)
    churn_every: Optional[int] = None
    #: ((probability, slo_seconds), ...) deadline mix; None = no SLOs.
    #: The default mixes tight 30 ms deadlines into a slack majority —
    #: the spread EDF exploits and FIFO cannot.
    slo_choices: Optional[tuple] = ((0.30, 0.030), (0.70, 0.250))


def _zipf_probs(n: int, s: float) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** s
    return w / w.sum()


def _poisson_arrivals(rng: np.random.Generator, rate: float,
                      n: int) -> Iterator[float]:
    t = 0.0
    left = n
    while left > 0:
        m = min(left, 8192)
        ts = t + np.cumsum(rng.exponential(1.0 / rate, m))
        t = float(ts[-1])
        yield from ts.tolist()
        left -= m


def _bursty_arrivals(rng: np.random.Generator, cfg: TraceConfig,
                     n: int) -> Iterator[float]:
    """2-state MMPP: alternate exponential-dwell quiet/burst segments;
    within a segment, arrivals are a Poisson process at that segment's
    rate (drawn as count ~ Poisson(rate*dwell), times uniform-sorted —
    the exact conditional distribution)."""
    t = 0.0
    burst = False
    emitted = 0
    while emitted < n:
        dwell = rng.exponential(
            cfg.burst_dwell_s if burst else cfg.base_dwell_s)
        rate = cfg.burst_rate_rps if burst else cfg.rate_rps
        k = int(rng.poisson(rate * dwell))
        if k:
            ts = np.sort(rng.uniform(t, t + dwell, k))
            take = min(k, n - emitted)
            yield from ts[:take].tolist()
            emitted += take
        t += dwell
        burst = not burst


def generate_trace(cfg: TraceConfig) -> Iterator[WorkloadRequest]:
    """Yield ``cfg.n_requests`` requests in nondecreasing arrival order.

    Host data arrays are built once per (workload, scale) bucket and
    shared by reference across every request in that bucket, so a
    million-request trace costs bucket-count array allocations, not
    request-count.  Lazy: consume it straight into the simulator or
    ``list(...)`` it for inspection.
    """
    if cfg.arrival not in ("poisson", "bursty"):
        raise ValueError(f"unknown arrival process {cfg.arrival!r}")
    rng = np.random.default_rng(cfg.seed)
    names = tuple(cfg.workloads) if cfg.workloads else tuple(list_workloads())
    wl_probs = _zipf_probs(len(names), cfg.zipf_s)
    tn_probs = _zipf_probs(len(cfg.tenants), cfg.tenant_zipf_s)
    slo = cfg.slo_choices
    if slo is not None:
        slo_p = np.array([p for p, _ in slo], dtype=np.float64)
        slo_p = slo_p / slo_p.sum()
        slo_v = [float(v) for _, v in slo]
    n_scales = len(cfg.scale_indices)
    churn_every = cfg.churn_every
    if churn_every is None:
        churn_every = max(1, cfg.n_requests // max(1, n_scales))

    data_cache: dict[tuple, tuple] = {}

    def bucket_data(name: str, scale_pos: int) -> tuple:
        key = (name, scale_pos)
        hit = data_cache.get(key)
        if hit is None:
            wl = get_workload(name)
            idx = min(cfg.scale_indices[scale_pos], len(wl.datasets) - 1)
            data_rng = np.random.default_rng(
                [cfg.seed, zlib.crc32(name.encode()), idx])
            hit = wl.make_data(wl.datasets[idx], data_rng)
            data_cache[key] = hit
        return hit

    arrivals = (_poisson_arrivals(rng, cfg.rate_rps, cfg.n_requests)
                if cfg.arrival == "poisson"
                else _bursty_arrivals(rng, cfg, cfg.n_requests))
    # one vectorized draw batch at a time keeps rng call overhead off the
    # per-request path
    batch = 8192
    produced = 0
    while produced < cfg.n_requests:
        m = min(batch, cfg.n_requests - produced)
        wl_idx = rng.choice(len(names), size=m, p=wl_probs)
        tn_idx = rng.choice(len(cfg.tenants), size=m, p=tn_probs)
        pr_idx = rng.integers(0, len(cfg.priorities), size=m)
        churn_u = rng.random(m)
        churn_pick = rng.integers(0, n_scales, size=m)
        if slo is not None:
            slo_idx = rng.choice(len(slo_v), size=m, p=slo_p)
        for j in range(m):
            i = produced + j
            hot = ((i // churn_every) % n_scales) if churn_every else 0
            scale_pos = (int(churn_pick[j]) if churn_u[j] < cfg.churn_prob
                         else hot)
            name = names[int(wl_idx[j])]
            chunked, shared = bucket_data(name, scale_pos)
            t_arr = next(arrivals)
            deadline = (t_arr + slo_v[int(slo_idx[j])]
                        if slo is not None else None)
            yield WorkloadRequest(
                workload=name, chunked=chunked, shared=shared,
                tenant=cfg.tenants[int(tn_idx[j])],
                priority=int(cfg.priorities[int(pr_idx[j])]),
                arrival_s=float(t_arr), deadline_s=deadline)
        produced += m


# ---------------------------------------------------------------------------
# service-time model
# ---------------------------------------------------------------------------

class _NoiseStream:
    """Lazily extended array of standard-normal draws, indexed by arrival
    sequence number — so the noise a request experiences is a property of
    the *trace position*, not of the order a particular queue policy
    happened to dispatch in.  Policies replaying the same trace are then
    compared on identical service draws."""

    def __init__(self, seed):
        self._rng = np.random.default_rng(seed)
        self._z = np.empty(0)

    def __getitem__(self, i: int) -> float:
        while i >= len(self._z):
            self._z = np.concatenate(
                [self._z, self._rng.standard_normal(65536)])
        return float(self._z[i])


class ServiceModel:
    """Synthetic per-request service times.

    True cost is affine in the request's chunked row count with a fixed
    per-workload coefficient (seeded from the workload name, so it never
    depends on trace order); sampled cost multiplies in lognormal noise.
    :meth:`shift` scales a workload's true cost mid-trace — the drift
    injection: tuned predictions made before the shift keep the old
    truth, so the detector sees genuine sustained misprediction.
    """

    def __init__(self, seed: int = 0, *, t0_s: float = 5e-4,
                 per_row_s: float = 4e-6, noise_sigma: float = 0.05):
        self.seed = seed
        self.t0_s = t0_s
        self.per_row_s = per_row_s
        self.noise_sigma = noise_sigma
        self._coef: dict[str, float] = {}
        self._shift: dict[str, float] = {}

    def _coef_of(self, workload: str) -> float:
        c = self._coef.get(workload)
        if c is None:
            r = np.random.default_rng(
                [self.seed, zlib.crc32(workload.encode())])
            c = 0.5 + 1.2 * float(r.random())
            self._coef[workload] = c
        return c

    def true_time(self, workload: str, n_rows: int) -> float:
        return ((self.t0_s + self.per_row_s * n_rows)
                * self._coef_of(workload) * self._shift.get(workload, 1.0))

    def sample(self, workload: str, n_rows: int, z: float) -> float:
        return self.true_time(workload, n_rows) * \
            float(np.exp(self.noise_sigma * z))

    def shift(self, workload: str, factor: float) -> None:
        self._shift[workload] = self._shift.get(workload, 1.0) * factor


# ---------------------------------------------------------------------------
# discrete-event replay
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Inflight:
    req: WorkloadRequest
    key: str
    cache_hit: bool
    predicted_s: float
    service_s: float          # wall time incl. contention inflation
    load: float
    occupancy: int
    t_decide_s: float
    t_dispatch_s: float
    queue_depth: int


def simulate_trace(trace: Iterable[WorkloadRequest], *,
                   policy: str = "fifo", window: int = 8,
                   capacity: float = 1.6, workers: Optional[int] = None,
                   backend: str = "sim", model_tag: str = "sim",
                   decide_s: float = 2e-5, cold_tune_s: float = 2e-3,
                   refine_s: float = 2e-2,
                   drift: Optional[DriftDetector] = None,
                   service: Optional[ServiceModel] = None,
                   seed: int = 0, contention_sigma: float = 0.12,
                   drift_injections: Iterable[tuple] = (),
                   telemetry: Optional[TelemetryLog] = None,
                   tracer=None, metrics=None,
                   faults: Optional[FaultPlan] = None) -> dict:
    """Replay ``trace`` under ``policy`` on a virtual clock; return the
    tail-latency / SLO / queue-depth / drift report.

    Event loop: two event sources (next arrival from the lazily consumed
    trace, next completion from a min-heap) advance a shared
    :class:`VirtualClock`; after every event, free window slots are
    filled from the real :class:`RequestQueue` (``deadline`` sheds
    expired work here, in virtual time).  Placement decisions serialize
    on a coordinator timeline: each dispatch charges ``decide_s`` (warm
    hit) or ``cold_tune_s`` (first sight of a bucket), and a drift
    refinement charges ``refine_s`` — all of which delay subsequent
    decisions, exactly like the engine's quiesce points.

    ``drift_injections`` is ``(t_s, workload, factor)`` triples applied
    to the :class:`ServiceModel` when virtual time first reaches
    ``t_s``.  Pass ``telemetry`` to additionally record one full
    :class:`TelemetrySample` per retired request (keep it off for
    million-request runs; the report aggregates streamingly).

    ``tracer`` / ``metrics`` are the same observability objects the real
    schedulers take (:mod:`repro.serving.observability`): the tracer is
    bound to the harness's virtual clock and records one span per stage
    on the virtual timeline (warm decisions as ``decide``, cold ones as
    ``tune.cold``, plus ``dispatch`` / ``retire`` / ``refine``); the
    metrics registry counts the same families the schedulers do, so a
    seeded replay's ``snapshot()`` is deterministic.

    ``faults`` is the same :class:`~repro.serving.resilience.FaultPlan`
    the live schedulers take, evaluated at the ``decide`` /
    ``tune.cold`` / ``dispatch`` sites per dispatched request: an
    ``error`` fault fails the request individually (counted in the
    ``failed`` block and, when a deadline was carried, against the SLO
    like shed work); a ``latency`` fault's delay is charged to the
    request's virtual service time — the plan is bound with
    ``sleep=None`` so no real wall time passes.
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
    clock = VirtualClock()
    tracer = tracer if tracer is not None else NULL_TRACER
    if tracer.enabled and tracer.clock is None:
        tracer.clock = clock
    metrics = metrics if metrics is not None else NULL_METRICS
    m_requests = metrics.counter("serving.requests")
    m_hit = metrics.counter("serving.cache.hit", namespace="shared")
    m_miss = metrics.counter("serving.cache.miss", namespace="shared")
    m_drift = metrics.counter("serving.drift.fired")
    m_refine = metrics.counter("serving.refinements")
    m_slo = metrics.counter("serving.slo.violations")
    m_failed = metrics.counter("serving.requests.failed")
    faults = faults if faults is not None else NULL_FAULTS
    if faults.enabled:
        faults.bind(metrics=metrics, sleep=None)
    queue = RequestQueue(policy, clock=clock, metrics=metrics)
    drift = drift if drift is not None else DriftDetector(load_discount=0.5)
    service = service if service is not None else ServiceModel(seed)
    z_svc = _NoiseStream([seed, 1])
    z_load = _NoiseStream([seed, 2])
    injections = sorted(drift_injections)
    inj_i = 0

    tuned: dict[str, float] = {}          # bucket key -> predicted seconds
    key_cache: dict[tuple, tuple] = {}    # (workload, shapes) -> (key, rows)
    completions: list = []                # (t_finish, seq, _Inflight)
    inflight = 0
    busy_until = 0.0                      # coordinator timeline

    latencies: list[float] = []
    lat_by_tenant: dict[str, list] = {}   # tenant -> [count, sum]
    served_by_tenant: dict[str, int] = {}
    depth_hist: dict[int, int] = {}       # queue depth at arrival -> count
    n_arrived = 0
    n_deadline = 0
    violations = 0
    n_failed = 0
    failed_deadline = 0
    cold_misses = 0
    refinements = 0
    refined_keys: list[str] = []
    t_end = 0.0

    def bucket_of(req: WorkloadRequest) -> tuple:
        shapes = tuple(sorted(
            (k, tuple(v.shape)) for k, v in req.chunked.items()))
        ck = (req.workload, shapes)
        hit = key_cache.get(ck)
        if hit is None:
            key = TuningCache.key(req.workload, req.chunked, req.shared,
                                  backend, model_tag=model_tag)
            rows = next(iter(req.chunked.values())).shape[0]
            hit = (key, int(rows))
            key_cache[ck] = hit
        return hit

    def apply_injections(t: float) -> None:
        nonlocal inj_i
        while inj_i < len(injections) and injections[inj_i][0] <= t:
            _, wl, factor = injections[inj_i]
            service.shift(wl, factor)
            inj_i += 1

    def dispatch(req: WorkloadRequest) -> None:
        nonlocal inflight, busy_until, cold_misses, n_failed, \
            failed_deadline
        key, rows = bucket_of(req)
        t_decide = max(clock.now(), busy_until)
        fault_delay = 0.0
        if faults.enabled:
            try:
                fault_delay += faults.fire("decide")
                if key not in tuned:
                    fault_delay += faults.fire("tune.cold")
                fault_delay += faults.fire("dispatch")
            except InjectedFault as e:
                # individual failure: the request terminates here with
                # an error telemetry sample; the coordinator only pays
                # the decide overhead, the window slot stays free
                busy_until = t_decide + decide_s
                n_failed += 1
                m_failed.inc()
                m_requests.inc()
                viol = (req.deadline_s is not None
                        and busy_until > req.deadline_s)
                if req.deadline_s is not None:
                    failed_deadline += 1
                if telemetry is not None:
                    telemetry.append(TelemetrySample(
                        seq=req.seq, tenant=req.tenant,
                        workload=req.workload, key=key, backend=backend,
                        partitions=0, tasks=0, cache_hit=key in tuned,
                        predicted_s=None, measured_s=None, rel_error=None,
                        status="failed", error=f"InjectedFault: {e}",
                        t_enqueue_s=req.arrival_s, t_decide_s=t_decide,
                        t_retire_s=busy_until,
                        latency_s=busy_until - req.arrival_s,
                        deadline_s=req.deadline_s, slo_violation=viol,
                        queue_depth=len(queue), trace_id=req.trace_id))
                return
        if key in tuned:
            overhead = decide_s
            cache_hit = True
        else:
            # cold: profile the bucket — the entry predicts current truth
            tuned[key] = service.true_time(req.workload, rows)
            overhead = cold_tune_s
            cache_hit = False
            cold_misses += 1
        busy_until = t_decide + overhead
        inflight += 1
        occupancy = inflight
        load = contention_factor(occupancy, capacity, workers)
        sigma_eff = contention_sigma * (occupancy - 1) / max(1, window - 1)
        base = service.sample(req.workload, rows, z_svc[req.seq])
        wall = base * load * float(np.exp(sigma_eff * z_load[req.seq])) \
            + fault_delay
        sim = _Inflight(req=req, key=key, cache_hit=cache_hit,
                        predicted_s=tuned[key], service_s=wall,
                        load=load, occupancy=occupancy,
                        t_decide_s=t_decide, t_dispatch_s=busy_until,
                        queue_depth=len(queue))
        if tracer.enabled:
            # the coordinator timeline is track 0; execution slots land
            # on tracks 1..window (occupancy approximates the slot)
            tracer.record("decide" if cache_hit else "tune.cold",
                          t_decide, busy_until,
                          trace_id=req.trace_id, tid=0)
            tracer.record("dispatch", busy_until, busy_until + wall,
                          trace_id=req.trace_id, tid=occupancy)
        heapq.heappush(completions, (busy_until + wall, req.seq, sim))

    def retire(sim: _Inflight) -> None:
        nonlocal inflight, busy_until, refinements, violations, t_end
        inflight -= 1
        t_ret = clock.now()
        t_end = t_ret
        req = sim.req
        norm = sim.service_s / sim.load
        rel = relative_error(norm, sim.predicted_s)
        refined = False
        if drift.observe(sim.key, rel, load_factor=sim.load):
            drift.reset(sim.key)
            _, rows = bucket_of(req)
            tuned[sim.key] = service.true_time(req.workload, rows)
            refinements += 1
            refined_keys.append(sim.key)
            busy_until = max(busy_until, t_ret) + refine_s
            refined = True
            m_drift.inc()
            m_refine.inc()
            if tracer.enabled:
                tracer.record("refine", busy_until - refine_s, busy_until,
                              trace_id=req.trace_id, tid=0)
            # the engine runs refinements at pool-quiesce points, so no
            # request decided against the stale entry retires *after*
            # the refresh — mirror that by repointing still-inflight
            # same-key work at the refreshed prediction (<= window items)
            for _, _, other in completions:
                if other.key == sim.key:
                    other.predicted_s = tuned[sim.key]
        lat = t_ret - req.arrival_s
        latencies.append(lat)
        agg = lat_by_tenant.setdefault(req.tenant, [0, 0.0])
        agg[0] += 1
        agg[1] += lat
        served_by_tenant[req.tenant] = \
            served_by_tenant.get(req.tenant, 0) + 1
        viol = req.deadline_s is not None and t_ret > req.deadline_s
        if viol:
            violations += 1
            m_slo.inc()
        m_requests.inc()
        (m_hit if sim.cache_hit else m_miss).inc()
        if tracer.enabled:
            tracer.record("retire", t_ret, t_ret,
                          trace_id=req.trace_id, tid=0)
        if telemetry is not None:
            telemetry.append(TelemetrySample(
                seq=req.seq, tenant=req.tenant, workload=req.workload,
                key=sim.key, backend=backend, partitions=1, tasks=1,
                cache_hit=sim.cache_hit, predicted_s=sim.predicted_s,
                measured_s=sim.service_s, rel_error=rel, refined=refined,
                source="refined" if refined else "model",
                inflight=sim.occupancy, load_factor=sim.load,
                measured_norm_s=norm, t_enqueue_s=req.arrival_s,
                t_decide_s=sim.t_decide_s, t_dispatch_s=sim.t_dispatch_s,
                t_retire_s=t_ret, latency_s=lat, deadline_s=req.deadline_s,
                slo_violation=viol, queue_depth=sim.queue_depth,
                trace_id=req.trace_id))

    it = iter(trace)
    next_req = next(it, None)
    while next_req is not None or completions or len(queue):
        t_arr = next_req.arrival_s if next_req is not None else np.inf
        t_comp = completions[0][0] if completions else np.inf
        if t_arr <= t_comp:
            if next_req is None:
                break  # only unpoppable (all-expired) work remains
            apply_injections(t_arr)
            clock.advance_to(t_arr)
            queue.push(next_req)
            n_arrived += 1
            if next_req.deadline_s is not None:
                n_deadline += 1
            d = len(queue)
            depth_hist[d] = depth_hist.get(d, 0) + 1
            next_req = next(it, None)
        else:
            apply_injections(t_comp)
            clock.advance_to(t_comp)
            _, _, sim = heapq.heappop(completions)
            retire(sim)
        while inflight < window and len(queue):
            try:
                dispatch(queue.pop())
            except IndexError:
                break  # deadline policy shed everything poppable

    shed = len(queue.shed)
    if metrics.enabled:
        metrics.gauge("serving.drift.suppressed").set(drift.suppressed)
    depths = sorted(depth_hist)
    total_d = sum(depth_hist.values())
    depth_mean = (sum(d * c for d, c in depth_hist.items()) / total_d
                  if total_d else 0.0)

    def depth_pct(q: float) -> int:
        target = q * total_d
        seen = 0
        for d in depths:
            seen += depth_hist[d]
            if seen >= target:
                return d
        return depths[-1] if depths else 0

    slo_denom = n_deadline
    # shed work IS a missed SLO, and so is an individually failed
    # request that carried a deadline
    slo_misses = violations + shed + failed_deadline
    wall = t_end if t_end > 0 else clock.now()
    return {
        "policy": policy,
        "window": window,
        "capacity": capacity,
        "n_requests": n_arrived,
        "completed": len(latencies),
        "shed": shed,
        "failed": n_failed,
        "faults_injected": faults.fired if faults.enabled else 0,
        "cold_misses": cold_misses,
        "hit_rate": (1.0 - cold_misses / len(latencies)
                     if latencies else 0.0),
        "refinements": refinements,
        "refined_keys": refined_keys,
        "latency": latency_stats(latencies),
        "slo": {
            "with_deadline": slo_denom,
            "violations_retired": violations,
            "shed": shed,
            "failed": failed_deadline,
            "violation_rate": (slo_misses / slo_denom
                               if slo_denom else None),
        },
        "queue_depth": {
            "mean": depth_mean,
            "p95": depth_pct(0.95),
            "max": depths[-1] if depths else 0,
        },
        "per_tenant": {
            t: {"served": served_by_tenant.get(t, 0),
                "mean_latency_s": (agg[1] / agg[0]) if agg[0] else None}
            for t, agg in sorted(lat_by_tenant.items())},
        "virtual_wall_s": wall,
        "throughput_rps": (len(latencies) / wall) if wall > 0 else 0.0,
    }
