"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Consumes the profiled
sample cache (generated on first run; a cached run takes ~2-4 min, a cold
run also profiles the 39-program suite).

    PYTHONPATH=src python -m benchmarks.run [--programs a,b] [--datasets N]
    PYTHONPATH=src python -m benchmarks.run --quick    # tiny subset
    PYTHONPATH=src python -m benchmarks.run --compare-backends  # executor A/B

A dry-run roofline summary (from benchmarks/data/dryrun/*.json, produced
by benchmarks/dryrun_sweep.py) is appended when available.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

import numpy as np  # noqa: E402

from repro.core import dataset as ds  # noqa: E402
from repro.core.backends import list_backends  # noqa: E402
from repro.core.stream_config import StreamConfig  # noqa: E402
from repro.core.streams import StreamedRunner  # noqa: E402
from repro.core.workloads import get_workload  # noqa: E402

from benchmarks import paper_figures as pf  # noqa: E402

QUICK_PROGRAMS = ["vecadd", "binomial", "sgemm", "jacobi-1d", "mri-q",
                  "blackscholes", "dotprod", "fwt"]

COMPARE_PROGRAMS = ["vecadd", "sgemm", "blackscholes"]
COMPARE_CONFIGS = [StreamConfig(1, 8), StreamConfig(4, 8),
                   StreamConfig(8, 16)]


def compare_backends(programs=None, *, reps: int = 3) -> list[str]:
    """Executor-backend A/B: every runner backend on the same
    (workload, config) cells, vs the host-sync reference."""
    rows = []
    for prog in programs or COMPARE_PROGRAMS:
        wl = get_workload(prog)
        scale = wl.datasets[-1]
        chunked, shared = wl.make_data(scale, np.random.default_rng(0))
        runners = {name: StreamedRunner(wl, chunked, shared, backend=name)
                   for name in list_backends(kind="runner")}
        for cfg in COMPARE_CONFIGS:
            base = runners["host-sync"].run(cfg, reps=reps)
            for name, runner in runners.items():
                t = base if name == "host-sync" else runner.run(cfg,
                                                                reps=reps)
                rows.append(
                    f"backends.{prog}@{scale}.{cfg.partitions}x{cfg.tasks}"
                    f".{name},{t*1e6:.0f},vs_sync={base/t:.3f}x")
    return rows


def dryrun_summary() -> list[str]:
    rows = []
    for path in sorted(glob.glob(os.path.join(
            ROOT, "benchmarks", "data", "dryrun", "*.json"))):
        try:
            with open(path) as f:
                d = json.load(f)
        except Exception:
            continue
        if "roofline" not in d:
            continue
        r = d["roofline"]
        rows.append(
            f"dryrun.{d['arch']}.{d['shape']}."
            f"{'pod2' if 'pod' in d['mesh'] else 'pod1'},"
            f"{r['bound_s']*1e6:.0f},"
            f"dominant={r['dominant']},frac={r['roofline_fraction']:.4f}"
            if "bound_s" in r else
            f"dryrun.{d['arch']}.{d['shape']},"
            f"{max(r['compute_s'], r['memory_s'], r['collective_s'])*1e6:.0f},"
            f"dominant={r['dominant']},frac={r['roofline_fraction']:.4f}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--programs", default=None)
    ap.add_argument("--datasets", type=int, default=3)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--compare-backends", action="store_true",
                    help="A/B every runner backend; skips the paper figures")
    args = ap.parse_args()

    if args.compare_backends:
        print("name,us_per_call,derived")
        for row in compare_backends(
                args.programs.split(",") if args.programs else None,
                reps=max(args.reps, 3)):
            print(row)
        return

    if args.programs:
        programs = args.programs.split(",")
    elif args.quick:
        programs = QUICK_PROGRAMS
    else:
        programs = None  # all 39

    samples = ds.generate(programs, datasets_per_program=args.datasets,
                          reps=args.reps, verbose=True)
    print(f"# {len(samples)} profiled samples over "
          f"{len({s.program for s in samples})} programs")
    print("name,us_per_call,derived")

    for row in pf.fig2_heatmap(samples):
        print(row)
    fig9_rows, summary = pf.fig9_overall(samples)
    for row in fig9_rows:
        print(row)
    for row in pf.fig10_fixed(samples):
        print(row)
    for row in pf.fig12_analytical(samples):
        print(row)
    for row in pf.fig14_classifier(samples):
        print(row)
    for row in pf.table5_models(samples):
        print(row)
    for row in pf.search_overhead(samples):
        print(row)
    for row in dryrun_summary():
        print(row)
    print(f"# SUMMARY ours={summary['ours']:.3f}x "
          f"oracle={summary['oracle']:.3f}x "
          f"pct_of_oracle={summary['pct']:.1f}%")


if __name__ == "__main__":
    main()
