"""Optimizer + data pipeline + grad compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, PrefetchFeeder, SyntheticLM
from repro.optim import optimizer as opt_lib
from repro.optim.grad_compression import dequantize_int8, quantize_int8


def test_adamw_minimizes_quadratic():
    cfg = opt_lib.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                              weight_decay=0.0, clip_norm=0.0)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt_lib.init_state(params, cfg)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    for _ in range(100):
        grads = jax.grad(loss)(params)
        params, state, _ = opt_lib.apply_updates(params, grads, state, cfg)
    assert float(loss(params)) < 1e-2


def test_lr_schedule_shapes():
    cfg = opt_lib.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              schedule="cosine")
    lrs = [float(opt_lib.lr_at(cfg, jnp.int32(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6          # end of warmup
    assert lrs[-1] < 1e-3                     # decayed to ~0
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:-1], lrs[2:]))


def test_grad_clipping():
    cfg = opt_lib.AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=1,
                              total_steps=10)
    params = {"x": jnp.zeros(3)}
    state = opt_lib.init_state(params, cfg)
    huge = {"x": jnp.full(3, 1e6)}
    _, _, om = opt_lib.apply_updates(params, huge, state, cfg)
    assert float(om["grad_norm"]) > 1e5  # reported pre-clip


def test_bf16_optimizer_state():
    cfg = opt_lib.AdamWConfig(state_dtype=jnp.bfloat16, warmup_steps=1,
                              total_steps=10)
    params = {"x": jnp.ones(4)}
    state = opt_lib.init_state(params, cfg)
    assert state["m"]["x"].dtype == jnp.bfloat16
    grads = {"x": jnp.ones(4)}
    p2, s2, _ = opt_lib.apply_updates(params, grads, state, cfg)
    assert s2["v"]["x"].dtype == jnp.bfloat16
    assert bool(jnp.isfinite(p2["x"]).all())


def test_synthetic_data_restart_determinism():
    """Batch k is identical after a simulated restart (exactly-once feed)."""
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=4, seed=3)
    src = SyntheticLM(cfg)
    b5 = src.batch_at(5)
    b5_again = SyntheticLM(cfg).batch_at(5)
    np.testing.assert_array_equal(b5["tokens"], b5_again["tokens"])
    assert not np.array_equal(b5["tokens"], src.batch_at(6)["tokens"])


def test_prefetch_feeder_order():
    cfg = DataConfig(vocab_size=50, seq_len=4, global_batch=2, seed=0)
    feeder = PrefetchFeeder(SyntheticLM(cfg), depth=2, start_step=10)
    try:
        for expect in (10, 11, 12):
            step, batch = feeder.next()
            assert step == expect
            assert batch["tokens"].shape == (2, 4)
    finally:
        feeder.stop()


def test_quantize_roundtrip_zero():
    q, s = quantize_int8(jnp.zeros(8))
    assert float(jnp.abs(dequantize_int8(q, s)).max()) == 0.0
