"""Mesh backend — pod-scale temporal sharing via microbatched training.

``wrap_train_step`` splits the global batch into ``config.tasks``
microbatches with gradient accumulation, letting XLA's latency-hiding
scheduler overlap the DP reduce-scatter of microbatch i with the backward
of microbatch i+1 (the TPU-native analogue of the paper's
transfer/compute overlap).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.backends.base import StreamBackend


class MeshBackend(StreamBackend):
    name = "mesh"
    kind = "train-step"

    def wrap_train_step(self, loss_fn: Callable, config, *,
                        unroll: bool = True) -> Callable:
        """Wrap ``loss_fn(params, batch) -> (loss, metrics)`` into a
        grad-accumulating step over ``config.tasks`` microbatches.

        The value-and-grad of microbatch i+1 is independent of the
        gradient all-reduce of microbatch i, so the XLA scheduler can
        overlap collectives with compute.  ``unroll=True`` emits a python
        loop (exact cost_analysis / better overlap freedom); False uses
        lax.scan (small HLO).
        """
        n_micro = config.tasks

        def grad_step(params, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        if n_micro == 1:
            return grad_step

        def microbatched(params, batch):
            def reshape(x):
                b = x.shape[0]
                assert b % n_micro == 0, (b, n_micro)
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])

            mb = jax.tree.map(reshape, batch)

            if unroll:
                loss_sum = jnp.zeros((), jnp.float32)
                grads_sum = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                metrics = None
                for i in range(n_micro):
                    micro = jax.tree.map(lambda x: x[i], mb)
                    loss, metrics, grads = grad_step(params, micro)
                    loss_sum = loss_sum + loss
                    grads_sum = jax.tree.map(jnp.add, grads_sum, grads)
                grads = jax.tree.map(lambda g: g / n_micro, grads_sum)
                return loss_sum / n_micro, metrics, grads

            def body(carry, micro):
                loss_acc, grads_acc = carry
                loss, metrics, grads = grad_step(params, micro)
                grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
                return (loss_acc + loss, grads_acc), metrics

            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads_sum), metrics = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero_grads), mb)
            grads = jax.tree.map(lambda g: g / n_micro, grads_sum)
            last_metrics = jax.tree.map(lambda m: m[-1], metrics)
            return loss_sum / n_micro, last_metrics, grads

        return microbatched
