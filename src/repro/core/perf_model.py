"""Back-compat shim: the model code moved to
:mod:`repro.core.modeling` (perf_model / pipeline / learners).  Import
from there; this module re-exports the public names so existing callers
keep working."""
from repro.core.modeling.base import assemble_rows
from repro.core.modeling.learners import (ForestRegressor, KernelRidgeRBF,
                                          TreeRegressor)
from repro.core.modeling.perf_model import FeaturePipeline, PerformanceModel

__all__ = ["FeaturePipeline", "PerformanceModel", "TreeRegressor",
           "ForestRegressor", "KernelRidgeRBF", "assemble_rows"]
