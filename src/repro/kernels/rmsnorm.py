"""Pallas TPU fused RMSNorm kernel.

Row-blocked: each grid step normalizes a (row_block, d) tile held in VMEM —
one HBM read + one HBM write per element (the unfused XLA graph reads x
twice: once for the variance reduce, once for the scale multiply).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, scale: jax.Array, *, eps: float = 1e-5,
                   row_block: int = 256, interpret: bool = True) -> jax.Array:
    """x (..., d), scale (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    rb = min(row_block, n)
    pad = (-n) % rb
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    grid = (xf.shape[0] // rb,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, scale)
    if pad:
        out = out[:n]
    return out.reshape(orig_shape)
