"""Version-tolerant access to XLA's ``compiled.cost_analysis()``.

Across jax releases the return type has flipped between a dict and a
list-of-dicts (one per computation, entry 0 = the entry computation).
Every consumer in this repo goes through :func:`cost_analysis_dict` so the
difference is absorbed in exactly one place.
"""
from __future__ import annotations


def cost_analysis_dict(compiled) -> dict:
    """Return the entry-computation cost analysis as a plain dict.

    Returns ``{}`` when the backend has no cost analysis at all — callers
    fall back to their own estimates in that case.
    """
    try:
        cost = compiled.cost_analysis()
    # cost_analysis availability/shape is backend-specific; an
    # unsupported backend means "no estimate", not a crash
    except Exception:  # noqa: BLE001
        return {}
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    if not isinstance(cost, dict):
        return {}
    return dict(cost)
