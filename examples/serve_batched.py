"""Batched serving demo: prefill + KV-cached greedy decode over batched
request slots, for a dense LM and for the recurrent xLSTM (O(1) state).

    PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch.serve import serve

for arch in ("yi-9b", "xlstm-350m"):
    print(f"=== serving {arch} (reduced config) ===")
    res = serve(arch, n_requests=6, batch_slots=3, prompt_len=12,
                gen_len=8, verbose=True)
    print(f"{res.tokens_generated} tokens in {res.wall_s:.2f}s "
          f"({res.tokens_per_s:.0f} tok/s)\n")
