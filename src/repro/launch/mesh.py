"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (jax locks the device count on first backend
init, and only launch/dryrun.py is allowed to force 512 host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU tests (requires >= data*model host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
