"""The streamed executor — AUTOSTREAMER's runtime, as a JAX program
transform (host backend) plus a mesh backend for pod-scale training.

Host backend (CPU reproduction; mirrors Figure 8c of the paper):
  * the outer iteration space is split into ``tasks`` chunks;
  * each chunk's host->device transfer (``jax.device_put``) is issued
    asynchronously and overlaps the (async-dispatched) compute of earlier
    chunks — temporal sharing;
  * each chunk's kernel is dispatched as ``partitions`` sub-slices, which
    sets the kernel working-set granularity (cache blocking) and dispatch
    parallelism — the spatial-sharing analogue on a host backend;
  * shared (non-chunked) buffers are transferred once and tracked valid —
    the paper's buffer-validity optimization (§4.4.5);
  * results are read back after all dispatches (D2H of early chunks
    overlaps compute of late chunks).

Mesh backend (pod scale): ``streamify_train_step`` splits the global batch
into ``tasks`` microbatches with gradient accumulation, letting XLA's
latency-hiding scheduler overlap the DP reduce-scatter of microbatch i with
the backward of microbatch i+1.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stream_config import SINGLE_STREAM, StreamConfig
from repro.core.workloads import Workload


# ---------------------------------------------------------------------------
# Host backend
# ---------------------------------------------------------------------------


def _split(arrs: dict, n: int) -> list[dict]:
    """Split every array in the dict into n chunks along axis 0."""
    if n == 1:
        return [arrs]
    keys = list(arrs)
    pieces = {k: np.array_split(arrs[k], n) for k in keys}
    return [{k: pieces[k][i] for k in keys} for i in range(n)]


class StreamedRunner:
    """Executes one workload+dataset under arbitrary stream configs."""

    def __init__(self, wl: Workload, chunked: dict, shared: dict,
                 device=None):
        self.wl = wl
        self.chunked = chunked
        self.shared = shared
        self.device = device or jax.devices()[0]
        self._jit = jax.jit(wl.kernel)
        # buffer-validity tracking: shared buffers live on device across
        # tasks and across runs (transferred once).
        self._shared_dev = jax.device_put(shared, self.device)
        jax.block_until_ready(self._shared_dev)

    # -- execution -----------------------------------------------------------

    def _dispatch(self, config: StreamConfig):
        outs = []
        for task in _split(self.chunked, config.tasks):
            task_dev = jax.device_put(task, self.device)     # async H2D
            for part in _split(task_dev, config.partitions):
                outs.append(self._jit(part, self._shared_dev))
        return outs

    def warmup(self, config: StreamConfig) -> None:
        """Compile every sub-slice shape before timing."""
        outs = self._dispatch(config)
        jax.block_until_ready(outs)

    def run(self, config: StreamConfig, *, reps: int = 3,
            warmed: bool = False) -> float:
        """Wall-clock seconds (min over reps) incl. H2D, compute, D2H."""
        if not warmed:
            self.warmup(config)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            outs = self._dispatch(config)
            # read back (paper Fig 8c: results transferred to host)
            for o in outs:
                jax.block_until_ready(o)
            _ = [np.asarray(jax.tree.leaves(o)[0], copy=False) for o in outs]
            best = min(best, time.perf_counter() - t0)
        return best

    def run_single_stream(self, *, reps: int = 3) -> float:
        return self.run(SINGLE_STREAM, reps=reps)

    # -- profiling hooks used by feature extraction ---------------------------

    def measure_transfer(self, *, reps: int = 3) -> float:
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            dev = jax.device_put(self.chunked, self.device)
            jax.block_until_ready(dev)
            best = min(best, time.perf_counter() - t0)
        return best

    def measure_compute(self, *, reps: int = 3) -> float:
        dev = jax.device_put(self.chunked, self.device)
        jax.block_until_ready(dev)
        self.warmup(SINGLE_STREAM)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            out = self._jit(dev, self._shared_dev)
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        return best

    def lowered_kernel(self):
        """Lowered+compiled single-chunk kernel for static features."""
        shapes = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), self.chunked)
        sshapes = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), self.shared)
        return jax.jit(self.wl.kernel).lower(shapes, sshapes)


def profile_config_grid(runner: StreamedRunner, configs, *, reps: int = 3,
                        verbose: bool = False) -> dict[StreamConfig, float]:
    """Exhaustive profiling of a config grid (paper §3.1.2)."""
    out = {}
    for cfg in configs:
        out[cfg] = runner.run(cfg, reps=reps)
        if verbose:
            print(f"  {cfg.partitions:3d}x{cfg.tasks:<3d} {out[cfg]*1e3:8.3f} ms")
    return out


# ---------------------------------------------------------------------------
# Mesh backend — microbatched training step (pod-scale temporal sharing)
# ---------------------------------------------------------------------------


def streamify_train_step(
    loss_fn: Callable,
    config: StreamConfig,
    *,
    unroll: bool = True,
) -> Callable:
    """Wrap ``loss_fn(params, batch) -> (loss, metrics)`` into a
    grad-accumulating step over ``config.tasks`` microbatches.

    The value-and-grad of microbatch i+1 is independent of the gradient
    all-reduce of microbatch i, so the XLA scheduler can overlap collectives
    with compute — the pod-scale temporal-sharing analogue.  ``unroll=True``
    emits a python loop (exact cost_analysis / better overlap freedom);
    False uses lax.scan (small HLO).
    """
    n_micro = config.tasks

    def grad_step(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    if n_micro == 1:
        return grad_step

    def microbatched(params, batch):
        def reshape(x):
            b = x.shape[0]
            assert b % n_micro == 0, (b, n_micro)
            return x.reshape(n_micro, b // n_micro, *x.shape[1:])

        mb = jax.tree.map(reshape, batch)

        if unroll:
            loss_sum = jnp.zeros((), jnp.float32)
            grads_sum = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            metrics = None
            for i in range(n_micro):
                micro = jax.tree.map(lambda x: x[i], mb)
                loss, metrics, grads = grad_step(params, micro)
                loss_sum = loss_sum + loss
                grads_sum = jax.tree.map(jnp.add, grads_sum, grads)
            grads = jax.tree.map(lambda g: g / n_micro, grads_sum)
            return loss_sum / n_micro, metrics, grads

        def body(carry, micro):
            loss_acc, grads_acc = carry
            loss, metrics, grads = grad_step(params, micro)
            grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
            return (loss_acc + loss, grads_acc), metrics

        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads_sum), metrics = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zero_grads), mb)
        grads = jax.tree.map(lambda g: g / n_micro, grads_sum)
        last_metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / n_micro, last_metrics, grads

    return microbatched
