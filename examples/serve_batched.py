"""Serving demos.

Part 1 — batched LM serving: prefill + KV-cached greedy decode over
batched request slots, for a dense LM and for the recurrent xLSTM
(O(1) state).

Part 2 — the TuningCache warm-start flow (the serving deployment story):
the first tune of a (workload, shape-bucket) profiles and searches; every
later request in the same bucket is a cache hit that skips both.  Prints
cold vs. warm tuning latency side by side.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import numpy as np

from repro.core.autotuner import AutoTuner, TuningCache
from repro.core.workloads import get_workload
from repro.launch.serve import serve
from repro.serving import OverlapHeuristicModel

for arch in ("yi-9b", "xlstm-350m"):
    print(f"=== serving {arch} (reduced config) ===")
    res = serve(arch, n_requests=6, batch_slots=3, prompt_len=12,
                gen_len=8, verbose=True)
    print(f"{res.tokens_generated} tokens in {res.wall_s:.2f}s "
          f"({res.tokens_per_s:.0f} tok/s)\n")

print("=== TuningCache warm-start (cold vs warm tuning latency) ===")
cache = TuningCache()                     # pass a path to persist across boots
tuner = AutoTuner(OverlapHeuristicModel(), cache=cache)
rng = np.random.default_rng(0)
for name in ("vecadd", "dotprod", "mvmult"):
    wl = get_workload(name)
    chunked, shared = wl.make_data(wl.datasets[1], rng)
    t0 = time.perf_counter()
    cold = tuner.tune(wl, chunked, shared)
    t_cold = time.perf_counter() - t0
    # same shape bucket, fresh data — the serving steady state
    chunked2, shared2 = wl.make_data(wl.datasets[1], rng)
    t0 = time.perf_counter()
    warm = tuner.tune(wl, chunked2, shared2)
    t_warm = time.perf_counter() - t0
    assert warm.cached and warm.config == cold.config
    print(f"{name:10s} config={cold.config.partitions}x{cold.config.tasks}"
          f"  cold={t_cold*1e3:8.2f}ms  warm={t_warm*1e6:6.1f}us"
          f"  ({t_cold/max(t_warm, 1e-9):7.0f}x faster)")
print(f"cache: {cache.hits} hits / {cache.misses} misses "
      f"({len(cache)} entries)")
