"""Feature extraction (paper §3.2, Tables 1-2) — TPU/JAX adaptation.

The paper uses static code features + hardware performance counters.  On a
JAX stack the compiled HLO *is* the program, so static features come from
the lowered/compiled kernel (op mix, FLOPs, memory traffic) and dynamic
features from profiling the first iterations of the single-stream version
(paper §3.3: "profiling the program without partitioning for a few loop
iterations").  No hardware counters needed — see DESIGN.md §2.

22 raw features are defined; the model pipeline (perf_model.FeaturePipeline)
applies Z-score standardization, |rho|>0.7 correlation pruning and PCA —
exactly the paper's §3.2.1-§3.2.2 recipe.
"""
from __future__ import annotations

import dataclasses
import math
import re

import numpy as np

from repro.core.streams import StreamedRunner
from repro.core.workloads import Workload
from repro.core.xla_cost import cost_analysis_dict

RAW_FEATURE_NAMES = [
    # --- static: iteration space / transfer structure (paper Table 1) ---
    "loop_nest",            # rank of the widest chunked array
    "loop_count",           # outer iteration count (rows)
    "n_xfer_mem",           # # of host-device transferred buffers
    "dts",                  # total host-device transfer size (bytes)
    "redundant_transfer",   # shared-buffer bytes (re-usable across tasks)
    "max_blocks",           # max #tasks (= loop_count)
    "min_task_unit",        # bytes per iteration row
    "out_bytes",            # device->host result size
    # --- static: compiled-kernel op mix (counter analogues) ---
    "hlo_ops",              # # instructions (paper: # instructions)
    "flops",                # FLOPs of one full pass
    "bytes_accessed",       # memory traffic estimate
    "arith_intensity",      # flops / bytes
    "frac_dot",             # fraction of dot/conv ops
    "frac_elementwise",
    "frac_reduce",
    "n_transcendental",     # exp/log/erf/sin/cos ops (paper: ALU mix)
    "n_gather_scatter",     # irregular access (paper: cache-miss proxy)
    "sequential_inner",     # has inner sequential scan (paper: loop nest)
    # --- dynamic: first-iterations profile ---
    "t_single_us",          # single-stream time (few iterations)
    "t_transfer_us",        # H2D time
    "t_compute_us",         # kernel time
    "comp_comm_ratio",      # log(t_compute / t_transfer) (paper Fig 17)
]

_TRANSCENDENTAL = re.compile(
    r"\b(exponential|log|power|tanh|erf|sine|cosine|rsqrt|sqrt|exp)\b")
_DOT = re.compile(r"\b(dot|dot-general|convolution)\b")
_REDUCE = re.compile(r"\breduce\b")
_GATHER = re.compile(r"\b(gather|scatter|dynamic-slice|dynamic-update-slice)\b")
_ELEMENTWISE = re.compile(
    r"\b(add|subtract|multiply|divide|maximum|minimum|select|compare|and|or|xor)\b")


def _tree_bytes(d: dict) -> int:
    return int(sum(a.nbytes for a in d.values()))


def _tree_count(d: dict) -> int:
    return len(d)


@dataclasses.dataclass
class RawFeatures:
    values: np.ndarray  # (22,)

    def as_dict(self) -> dict:
        return dict(zip(RAW_FEATURE_NAMES, self.values))


def extract_features(runner: StreamedRunner, *, profile: bool = True,
                     profile_reps: int = 2) -> RawFeatures:
    wl, chunked, shared = runner.wl, runner.chunked, runner.shared
    rows = next(iter(chunked.values())).shape[0]
    loop_nest = max(a.ndim for a in chunked.values())
    dts = _tree_bytes(chunked) + _tree_bytes(shared)
    red = _tree_bytes(shared)

    lowered = runner.lowered_kernel()
    compiled = lowered.compile()
    cost = cost_analysis_dict(compiled)  # {} on backends without analysis
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0)) or float(dts)

    hlo = compiled.as_text()
    op_lines = [ln for ln in hlo.splitlines()
                if "=" in ln and not ln.strip().startswith(("HloModule", "ENTRY", "%", "ROOT %"))]
    n_ops = max(len(op_lines), 1)
    joined = "\n".join(op_lines)
    n_dot = len(_DOT.findall(joined))
    n_red = len(_REDUCE.findall(joined))
    n_elem = len(_ELEMENTWISE.findall(joined))
    n_trans = len(_TRANSCENDENTAL.findall(joined))
    n_gs = len(_GATHER.findall(joined))

    out_shapes = _output_bytes(wl, chunked, shared)
    if profile:
        t_xfer = runner.measure_transfer(reps=profile_reps)
        t_comp = runner.measure_compute(reps=profile_reps)
        t_single = runner.run_single_stream(reps=profile_reps)
    else:
        t_xfer = t_comp = t_single = 0.0
    ratio = math.log(max(t_comp, 1e-9) / max(t_xfer, 1e-9))

    vals = np.array([
        loop_nest,
        rows,
        _tree_count(chunked) + _tree_count(shared),
        dts,
        red,
        rows,
        dts / max(rows, 1),
        out_shapes,
        n_ops,
        flops,
        bytes_acc,
        flops / max(bytes_acc, 1.0),
        n_dot / n_ops,
        n_elem / n_ops,
        n_red / n_ops,
        n_trans,
        n_gs,
        1.0 if wl.sequential_inner else 0.0,
        t_single * 1e6,
        t_xfer * 1e6,
        t_comp * 1e6,
        ratio,
    ], dtype=np.float64)
    return RawFeatures(vals)


def _output_bytes(wl: Workload, chunked: dict, shared: dict) -> float:
    import jax

    spec = lambda d: {k: jax.ShapeDtypeStruct(a.shape, a.dtype)
                      for k, a in d.items()}
    out = jax.eval_shape(wl.kernel, spec(chunked), spec(shared))
    return float(sum(np.prod(s.shape) * s.dtype.itemsize
                     for s in jax.tree.leaves(out)))


def config_features(partitions: int, tasks: int) -> np.ndarray:
    """Configuration encoding appended to the program features (§3.1.3)."""
    return np.array([
        math.log2(partitions),
        math.log2(tasks),
        math.log2(tasks / partitions) if tasks >= partitions else -1.0,
    ], dtype=np.float64)


# Candidate grids are immutable per scheduler/tuner, so their encodings —
# and the raw (partitions, tasks) columns the vectorized heuristic model
# scores — are memoized by the grid's value.  Coordinator-thread only:
# decide/tune never runs on pool workers.
_CONFIG_MATRIX_CACHE: dict = {}
_CONFIG_MATRIX_CACHE_MAX = 64


def _config_memo(kind: str, configs, build):
    key = (kind, tuple((c.partitions, c.tasks) for c in configs))
    hit = _CONFIG_MATRIX_CACHE.get(key)
    if hit is None:
        while len(_CONFIG_MATRIX_CACHE) >= _CONFIG_MATRIX_CACHE_MAX:
            _CONFIG_MATRIX_CACHE.pop(next(iter(_CONFIG_MATRIX_CACHE)))
        hit = _CONFIG_MATRIX_CACHE[key] = build()
    return hit


def config_feature_matrix(configs) -> np.ndarray:
    """(C, N_CONFIG_FEATURES) encoding of a candidate grid, memoized."""
    return _config_memo("enc", configs, lambda: np.stack(
        [config_features(c.partitions, c.tasks) for c in configs]))


def config_pt_arrays(configs) -> tuple[np.ndarray, np.ndarray]:
    """The (partitions, tasks) columns of a candidate grid as float
    arrays, memoized — the vectorized overlap heuristic scores the whole
    grid with these instead of a Python loop."""
    return _config_memo("pt", configs, lambda: (
        np.array([c.partitions for c in configs], dtype=np.float64),
        np.array([c.tasks for c in configs], dtype=np.float64)))


N_CONFIG_FEATURES = 3
