"""The learned performance model (paper §3) in pure JAX + numpy.

Pipeline (faithful to §3.2.1-§3.2.2, §6.6.2-§6.6.3): the shared
:class:`~repro.core.modeling.pipeline.FeaturePipeline` front end, then an
MLP regression — 3 hidden layers x 9 neurons, tanh, adam — over
(program features ++ config encoding) -> standardized speedup.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.modeling.base import (EstimatorBase, assemble_rows,
                                      register_estimator)
from repro.core.modeling.pipeline import FeaturePipeline

__all__ = ["PerformanceModel", "FeaturePipeline", "assemble_rows"]


# ---------------------------------------------------------------------------
# MLP (pure JAX)
# ---------------------------------------------------------------------------


def _init_mlp(key, in_dim: int, hidden: Sequence[int] = (9, 9, 9)):
    dims = [in_dim, *hidden, 1]
    params = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, k = jax.random.split(key)
        w = jax.random.normal(k, (a, b)) * np.sqrt(2.0 / a)
        params.append({"w": w, "b": jnp.zeros((b,))})
    return params


def _mlp_forward(params, x):
    h = x
    for layer in params[:-1]:
        h = jnp.tanh(h @ layer["w"] + layer["b"])
    out = h @ params[-1]["w"] + params[-1]["b"]
    return out[..., 0]


@jax.jit
def _mse(params, X, y):
    pred = _mlp_forward(params, X)
    return jnp.mean((pred - y) ** 2)


def _adam_train(params, X, y, *, lr=1e-2, epochs=600, seed=0):
    opt_m = jax.tree.map(jnp.zeros_like, params)
    opt_v = jax.tree.map(jnp.zeros_like, params)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)

    @jax.jit
    def step(i, params, m, v):
        loss, g = jax.value_and_grad(_mse)(params, Xj, yj)
        m = jax.tree.map(lambda m_, g_: 0.9 * m_ + 0.1 * g_, m, g)
        v = jax.tree.map(lambda v_, g_: 0.999 * v_ + 0.001 * g_**2, v, g)
        mh = jax.tree.map(lambda m_: m_ / (1 - 0.9 ** (i + 1)), m)
        vh = jax.tree.map(lambda v_: v_ / (1 - 0.999 ** (i + 1)), v)
        params = jax.tree.map(
            lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + 1e-8),
            params, mh, vh)
        return loss, params, m, v

    loss = None
    for i in range(epochs):
        loss, params, opt_m, opt_v = step(i, params, opt_m, opt_v)
    return params, float(loss)


# ---------------------------------------------------------------------------
# The regression performance model (ours)
# ---------------------------------------------------------------------------


@register_estimator
@dataclasses.dataclass
class PerformanceModel(EstimatorBase):
    pipeline: FeaturePipeline
    mlp_params: list
    hidden: tuple = (9, 9, 9)

    kind = "mlp"

    @staticmethod
    def train(X_raw: np.ndarray, y_speedup: np.ndarray, *,
              hidden=(9, 9, 9), n_components: int = 9, epochs: int = 600,
              lr: float = 1e-2, seed: int = 0) -> "PerformanceModel":
        """X_raw rows = program features ++ config encoding; y = speedup."""
        pipe = FeaturePipeline.fit(X_raw, y_speedup, n_components=n_components)
        X = pipe.transform(X_raw)
        y = pipe.transform_y(y_speedup)
        params = _init_mlp(jax.random.key(seed), X.shape[1], hidden)
        params, _ = _adam_train(params, X, y, lr=lr, epochs=epochs, seed=seed)
        return PerformanceModel(pipe, params, tuple(hidden))

    def predict(self, X_raw: np.ndarray) -> np.ndarray:
        X = self.pipeline.transform(np.atleast_2d(X_raw))
        yn = np.asarray(_mlp_forward(self.mlp_params, jnp.asarray(X)))
        return self.pipeline.inverse_y(yn)

    def refit(self, X_raw: np.ndarray, y_speedup: np.ndarray, *,
              epochs: int = 150, lr: float = 3e-3) -> float:
        """Incremental online refit: continue adam from the current
        parameters on freshly *measured* (features ++ config, speedup)
        rows.  The feature pipeline stays frozen so the input space is
        stable across refits; only the MLP moves.  This is the serving
        drift-correction hook — a few hundred cheap steps on a handful of
        rows, not a retrain.  Returns the final training loss."""
        X = self.pipeline.transform(np.atleast_2d(np.asarray(X_raw, float)))
        yn = self.pipeline.transform_y(
            np.asarray(y_speedup, float).reshape(-1))
        self.mlp_params, loss = _adam_train(self.mlp_params, X, yn,
                                            lr=lr, epochs=epochs)
        return float(loss)

    def fork(self) -> "PerformanceModel":
        """A refit-isolated copy sharing the frozen feature pipeline.

        ``refit`` rebinds ``mlp_params`` to freshly built trees (adam
        never mutates arrays in place), so copying the layer containers
        is enough: the fork and the original diverge from the first
        refit on either side.  This is the serving tenancy hook — every
        tenant refits its own fork of the shared read-only base model."""
        return PerformanceModel(self.pipeline,
                                [dict(layer) for layer in self.mlp_params],
                                self.hidden)

    # -- artifact serialization ----------------------------------------------

    def to_state(self) -> tuple[dict, dict]:
        arrays = self.pipeline.to_arrays()
        for i, layer in enumerate(self.mlp_params):
            arrays[f"mlp.{i}.w"] = np.asarray(layer["w"])
            arrays[f"mlp.{i}.b"] = np.asarray(layer["b"])
        return arrays, {"hidden": list(self.hidden),
                        "n_layers": len(self.mlp_params)}

    @classmethod
    def from_state(cls, arrays: dict, extras: dict) -> "PerformanceModel":
        pipe = FeaturePipeline.from_arrays(arrays)
        params = [{"w": jnp.asarray(arrays[f"mlp.{i}.w"]),
                   "b": jnp.asarray(arrays[f"mlp.{i}.b"])}
                  for i in range(int(extras["n_layers"]))]
        return cls(pipe, params, tuple(extras["hidden"]))
