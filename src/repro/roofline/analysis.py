"""Three-term roofline analysis from the compiled dry-run artifact.

    compute term    = HLO_FLOPs_per_chip    / peak_FLOP/s
    memory term     = HLO_bytes_per_chip    / HBM_bw
    collective term = collective_bytes_per_chip / link_bw

``compiled.cost_analysis()`` describes the post-SPMD *per-device* module,
so the per-chip convention is used throughout (equivalent to the global
formula HLO_FLOPs / (chips x peak)).  collective_bytes is not in
cost_analysis: we regex the post-SPMD HLO text and sum the operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction (the mandated convention; ring-traffic
refinements are reported alongside in EXPERIMENTS.md).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.core.xla_cost import cost_analysis_dict

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# shape tokens like  bf16[16,1024]{1,0}  or  f32[]  appearing in operand lists
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?[a-z0-9\[\],{}\- ]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)|"
    r"while\(.*?\).*?body=%?([\w\.\-]+).*?condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """Computation name -> its instruction lines.  Computation headers sit
    at column 0 and end with '{'; instructions are indented."""
    comps: dict[str, list[str]] = {}
    cur: Optional[str] = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            head = line.strip()
            if head.startswith("ENTRY "):
                head = head[len("ENTRY "):]
            head = head.lstrip("%")
            name = re.split(r"[\s(]", head, 1)[0]
            cur = name
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps


def _def_bytes_map(hlo_text: str) -> dict[str, int]:
    """Instruction name -> bytes of its result (tuples summed)."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # result type = shape tokens before the op name; take tokens up to
        # the first alphabetic op word by scanning leading shape tokens
        nbytes = 0
        pos = 0
        rhs = rhs.lstrip("(")
        while True:
            sm = _SHAPE_RE.match(rhs[pos:].lstrip(" ,"))
            if not sm:
                break
            skip = len(rhs[pos:]) - len(rhs[pos:].lstrip(" ,"))
            nbytes += _shape_bytes(sm.group(1), sm.group(2))
            pos += skip + sm.end()
            # skip layout annotation {1,0} if present
            rest = rhs[pos:]
            if rest.startswith("{"):
                close = rest.find("}")
                pos += close + 1
            if rhs[pos:].lstrip(" ,").startswith(")"):
                break
        out[name] = nbytes
    return out


def _loop_trip_count(cond_lines: list[str]) -> int:
    """jax scans lower to while(cond: compare(i, constant(R)))."""
    best = 1
    for ln in cond_lines:
        for c in _CONST_RE.findall(ln):
            best = max(best, int(c))
    return best


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op, by type — multiplying
    collectives inside while-loop bodies by the loop trip count (HLO cost
    conventions count a loop body once; a scanned-layers model would
    otherwise under-report its per-step collective traffic)."""
    comps = _split_computations(hlo_text)
    def_bytes = _def_bytes_map(hlo_text)

    # find loop body multipliers: body computation name -> trip count
    multiplier: dict[str, int] = {}
    for cname, lines in comps.items():
        for ln in lines:
            if "while(" not in ln:
                continue
            m = _WHILE_RE.search(ln)
            if m:
                cond = m.group(1) or m.group(4)
                body = m.group(2) or m.group(3)
                trips = _loop_trip_count(comps.get(cond, []))
                multiplier[body] = max(multiplier.get(body, 1), trips)

    # effective multiplier per computation = product of trip counts of all
    # loop bodies along the call path from entry (fixpoint over call edges)
    call_edges: dict[str, set] = {c: set() for c in comps}
    name_set = set(comps)
    for cname, lines in comps.items():
        for ln in lines:
            for callee in re.findall(r"%([\w\.\-]+)", ln):
                if callee in name_set and callee != cname:
                    call_edges[cname].add(callee)

    eff_mult: dict[str, int] = {c: 1 for c in comps}
    for _ in range(50):
        changed = False
        for cname, callees in call_edges.items():
            for callee in callees:
                m = eff_mult[cname] * multiplier.get(callee, 1)
                if m > eff_mult[callee]:
                    eff_mult[callee] = m
                    changed = True
        if not changed:
            break

    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    op_re = re.compile(
        r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)(-start)?\(([^)]*)\)")
    for cname, lines in comps.items():
        mult = eff_mult[cname]
        for line in lines:
            if "-done(" in line:
                continue  # counted at -start
            m = op_re.search(line)
            if not m:
                continue
            kind, _, operands = m.group(1), m.group(2), m.group(3)
            nbytes = 0
            for opname in re.findall(r"%([\w\.\-]+)", operands):
                nbytes += def_bytes.get(opname, 0)
            if nbytes == 0:
                # fallback: result shape from the def line itself
                dm = _DEF_RE.match(line)
                if dm:
                    nbytes = def_bytes.get(dm.group(1), 0)
            out[kind] += nbytes * mult
            counts[kind] += mult
    out["total"] = sum(out[k] for k in COLLECTIVES)
    out["counts"] = counts
    return out


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    model_flops: float = 0.0
    n_chips: int = 1

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (global HLO flops): remat/redundancy waste."""
        total = self.flops_per_chip * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / achievable makespan bound: the score."""
        ideal = self.model_flops / (self.n_chips * PEAK_FLOPS)
        return ideal / self.bound_s if self.bound_s else 0.0

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "model_flops": self.model_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
            "n_chips": self.n_chips,
        }


def roofline_from_compiled(compiled, *, n_chips: int,
                           model_flops: float = 0.0,
                           hlo_text: Optional[str] = None) -> RooflineTerms:
    cost = cost_analysis_dict(compiled)
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll["total"] / ICI_BW,
        flops_per_chip=flops,
        bytes_per_chip=byts,
        coll_bytes_per_chip=float(coll["total"]),
        model_flops=model_flops,
        n_chips=n_chips,
    )
