"""The :class:`ModelRegistry`: an artifact directory with ``latest``
pinning and hot-swap — where offline training publishes and serving
loads.

Layout under the registry root (``REPRO_MODEL_DIR`` or ``<repo>/models``)::

    models/
      latest                     <- text file naming the pinned artifact
      mlp-v001/                  <- fleet-wide artifact
        manifest.json
        weights.npz
      mlp-tenant-a-v001/         <- tenant-tagged fork (never auto-pinned)
        ...

``publish`` allocates the next version for the (kind, tenant) lineage,
writes the artifact, and — for fleet-wide (non-tenant) artifacts —
repoints ``latest``.  ``load("latest")`` follows the pointer;
``refresh(current_id)`` is the serving hot-swap hook: it reloads only
when the pointer has moved since the caller last loaded.
"""
from __future__ import annotations

import errno
import os
import re
import shutil
import warnings
from pathlib import Path
from typing import Optional

from repro.core import REPO_ROOT
from repro.core.modeling.artifacts import (is_artifact_dir, load_artifact,
                                           read_manifest, save_artifact)

LATEST_NAME = "latest"


def default_model_dir() -> Path:
    env = os.environ.get("REPRO_MODEL_DIR")
    return Path(env) if env else (REPO_ROOT / "models")


class ModelRegistry:
    def __init__(self, root: "str | Path | None" = None, *, metrics=None):
        self.root = Path(root) if root else default_model_dir()
        # duck-typed MetricsRegistry (kept optional so core never
        # imports serving): counts dangling-latest fallbacks
        self.metrics = metrics

    # -- enumeration ---------------------------------------------------------

    def list(self) -> list[str]:
        """Artifact ids present in the registry, sorted.  Hidden
        ``.stage-*`` directories (in-flight publishes, or orphans from a
        publisher that crashed mid-stage) are not artifacts."""
        if not self.root.is_dir():
            return []
        return sorted(p.name for p in self.root.iterdir()
                      if p.is_dir() and not p.name.startswith(".")
                      and is_artifact_dir(p))

    def _fallback_for(self, dangling_id: str) -> Optional[str]:
        """Newest resolvable artifact to stand in for a dangling
        ``latest`` pointer: same lineage as the dangling id when any
        version of it survives, else the lexically-newest artifact
        overall (zero-padded ``v%03d`` makes lexical order = version
        order).  None when the registry holds nothing resolvable."""
        known = self.list()
        if not known:
            return None
        stem = re.sub(r"-v\d+$", "", dangling_id)
        same_lineage = [a for a in known
                        if re.fullmatch(re.escape(stem) + r"-v\d+", a)]
        return max(same_lineage) if same_lineage else max(known)

    def _next_version(self, kind: str, tenant: str) -> int:
        stem = "-".join(filter(None, [kind, tenant]))
        pat = re.compile(re.escape(stem) + r"-v(\d+)$")
        versions = [int(m.group(1)) for name in self.list()
                    if (m := pat.match(name))]
        return max(versions, default=0) + 1

    # -- publish / pin -------------------------------------------------------

    def publish(self, model, *, corpus: str = "", cv: Optional[dict] = None,
                tag: str = "", tenant: str = "",
                pin_latest: Optional[bool] = None) -> str:
        """Write ``model`` as the next artifact version of its (kind,
        tenant) lineage; fleet-wide publishes repoint ``latest`` unless
        ``pin_latest=False``.  Tenant-tagged artifacts (refined serving
        forks persisted back) never auto-pin: a single tenant's drift
        correction must not become the fleet default.

        Concurrency-safe: the artifact is staged into a hidden temp
        directory and renamed into place, so a reader never sees a
        half-written weights file, and two publishers racing for the
        same version number collide on the rename — the loser
        re-allocates the next version instead of overwriting."""
        self.root.mkdir(parents=True, exist_ok=True)
        stage = self.root / f".stage-{os.getpid()}-{id(model):x}"
        save_artifact(model, stage, corpus=corpus, cv=cv, tag=tag,
                      tenant=tenant)
        try:
            last_err = None
            for _ in range(50):
                artifact_id = "-".join(filter(None, [
                    model.kind, tenant,
                    f"v{self._next_version(model.kind, tenant):03d}"]))
                try:
                    stage.rename(self.root / artifact_id)
                    break
                except OSError as e:
                    # only an exists-collision means a concurrent
                    # publisher won this version number; anything else
                    # (EACCES, EXDEV, ...) is a real failure
                    if e.errno not in (errno.EEXIST, errno.ENOTEMPTY):
                        raise
                    last_err = e
            else:
                raise RuntimeError(
                    f"could not allocate an artifact version under "
                    f"{self.root} after 50 attempts") from last_err
        finally:
            if stage.exists():
                shutil.rmtree(stage, ignore_errors=True)
        if pin_latest if pin_latest is not None else not tenant:
            self.pin(artifact_id)
        return artifact_id

    def pin(self, artifact_id: str) -> None:
        """Atomically repoint ``latest`` (the hot-swap publication).
        The temp name is per-process: concurrent publishers must not
        clobber (or delete) each other's staging file mid-replace."""
        if not is_artifact_dir(self.root / artifact_id):
            raise FileNotFoundError(
                f"cannot pin {artifact_id!r}: no artifact at "
                f"{self.root / artifact_id}")
        tmp = self.root / f".{LATEST_NAME}.tmp-{os.getpid()}"
        tmp.write_text(artifact_id + "\n")
        tmp.replace(self.root / LATEST_NAME)

    def latest_id(self) -> Optional[str]:
        ptr = self.root / LATEST_NAME
        if not ptr.exists():
            return None
        artifact_id = ptr.read_text().strip()
        return artifact_id or None

    # -- resolve / load ------------------------------------------------------

    def resolve(self, spec: str = "latest") -> Path:
        """``spec`` is ``"latest"``, an artifact id, or a filesystem path
        to an artifact directory."""
        if spec == "latest":
            artifact_id = self.latest_id()
            if artifact_id is None:
                raise FileNotFoundError(
                    f"registry {self.root} has no 'latest' artifact "
                    f"(publish one with launch/train_model.py)")
            path = self.root / artifact_id
            if not is_artifact_dir(path):
                # dangling pointer = registry corruption.  With other
                # resolvable versions present, serving falls back to the
                # newest one (same lineage preferred) with a warning —
                # a deleted artifact must not take the fleet down.  With
                # NOTHING resolvable left this stays a hard RuntimeError
                # (NOT FileNotFoundError: the empty-registry bootstrap
                # must not silently paper over corruption with a fresh
                # model).
                fallback = self._fallback_for(artifact_id)
                if fallback is None:
                    raise RuntimeError(
                        f"registry {self.root}: 'latest' points at "
                        f"{artifact_id!r} but no artifact exists there")
                warnings.warn(
                    f"registry {self.root}: 'latest' points at "
                    f"{artifact_id!r} which no longer exists; falling "
                    f"back to newest resolvable version {fallback!r}")
                if self.metrics is not None:
                    self.metrics.counter(
                        "serving.registry.latest_fallback").inc()
                return self.root / fallback
            return path
        if is_artifact_dir(self.root / spec):
            return self.root / spec
        if is_artifact_dir(spec):
            return Path(spec)
        raise FileNotFoundError(
            f"no artifact {spec!r} in registry {self.root} "
            f"(known: {self.list() or 'none'})")

    def load(self, spec: str = "latest"):
        """Load ``(model, manifest)``; the manifest gains an
        ``artifact_id`` field naming what was actually resolved."""
        path = self.resolve(spec)
        model, manifest = load_artifact(path)
        manifest["artifact_id"] = path.name
        return model, manifest

    def manifest(self, spec: str = "latest") -> dict:
        path = self.resolve(spec)
        manifest = read_manifest(path)
        manifest["artifact_id"] = path.name
        return manifest

    def refresh(self, current_id: Optional[str]):
        """Hot-swap poll: when ``latest`` points somewhere new, load and
        return ``(model, manifest)``; ``None`` while unchanged.  This is
        the serving driver's hook — a long-lived deployment polls it
        between traces and feeds a non-``None`` result to
        :meth:`AdaptiveScheduler.swap_model`; the shipped one-trace CLI
        (``serve.py --adaptive``) instead picks up the new ``latest`` on
        its next launch."""
        latest = self.latest_id()
        if latest is None or latest == current_id:
            return None
        return self.load(latest)
