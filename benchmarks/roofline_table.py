"""Assemble the EXPERIMENTS.md roofline table from the dry-run records.

    python benchmarks/roofline_table.py [--pod pod1|pod2|both] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))


def load(pattern="*"):
    recs = []
    for path in sorted(glob.glob(os.path.join(
            ROOT, "benchmarks", "data", "dryrun", pattern + ".json"))):
        with open(path) as f:
            d = json.load(f)
        d["_file"] = os.path.basename(path)
        recs.append(d)
    return recs


def fmt_row(d, md=False):
    r = d["roofline"]
    mesh = "2x16x16" if "pod" in d["mesh"] else "16x16"
    mem = d.get("memory_analysis", {})
    arg_gb = mem.get("argument_size_in_bytes", 0) / 2**30
    tmp_gb = mem.get("temp_size_in_bytes", 0) / 2**30
    cells = [
        d["arch"], d["shape"], mesh, d.get("options", ""),
        f"{r['compute_s']*1e3:9.2f}", f"{r['memory_s']*1e3:9.2f}",
        f"{r['collective_s']*1e3:9.2f}", r["dominant"][:4],
        f"{r['model_flops']:.2e}", f"{r['useful_flops_fraction']:.2f}",
        f"{r['roofline_fraction']:.4f}",
        f"{arg_gb:6.1f}", f"{tmp_gb:7.1f}",
    ]
    sep = " | " if md else ","
    return sep.join(str(c) for c in cells)


HEADER = ["arch", "shape", "mesh", "opts", "compute_ms", "memory_ms",
          "collective_ms", "dom", "model_flops", "useful_frac",
          "roofline_frac", "args_GB/dev", "temp_GB/dev"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--pattern", default="*")
    args = ap.parse_args()
    recs = load(args.pattern)
    sep = " | " if args.md else ","
    print(sep.join(HEADER))
    if args.md:
        print(" | ".join("---" for _ in HEADER))
    for d in recs:
        if "roofline" in d:
            print(fmt_row(d, args.md))


if __name__ == "__main__":
    main()
