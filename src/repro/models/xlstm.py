"""xLSTM blocks (arXiv:2405.04517): sLSTM (scalar memory, exponential
gating, head-block-diagonal recurrence) and mLSTM (matrix memory,
attention-like key/value outer products).

Both are implemented as exact recurrences via ``jax.lax.scan`` over time
(train/prefill) and a single fused step for decode — the recurrent form is
the oracle; a chunkwise-parallel mLSTM is a candidate §Perf optimization.

Simplifications vs. the reference implementation (documented in DESIGN.md):
no causal-conv preprocessing on the q/k path, GroupNorm replaced by RMSNorm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import XLSTMConfig
from repro.models import layers
from repro.parallel.sharding_rules import AxisRules


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def _round64(x: float) -> int:
    """Round projection widths up to a multiple of 64 — MXU/lane alignment
    and mesh divisibility (1024*4/3 = 1365 would shard nowhere)."""
    return max(64, int(-(-x // 64)) * 64)


def slstm_init(key, d_model: int, num_heads: int, cfg: XLSTMConfig,
               dtype=jnp.float32) -> dict:
    dh = d_model // num_heads
    E = _round64(cfg.proj_factor_slstm * d_model)
    ks = jax.random.split(key, 4)
    return {
        # i, f, z, o stacked on last dim
        "W": layers.dense_init(ks[0], (d_model, 4 * d_model), ("embed", "inner"), dtype),
        "R": layers.dense_init(ks[1], (num_heads, dh, 4 * dh), ("heads", None, None),
                               dtype, fan_in=dh),
        "b": layers.zeros_init((4 * d_model,), ("inner",), dtype),
        "up": layers.dense_init(ks[2], (d_model, E), ("embed", "inner"), dtype),
        "down": layers.dense_init(ks[3], (E, d_model), ("inner", "embed"), dtype,
                                  fan_in=E),
    }


def _slstm_cell(params, wx_t, state, num_heads: int):
    """One sLSTM step. wx_t (B, 4D) precomputed W@x; state dict of (B, D)."""
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    B, D = h.shape
    dh = D // num_heads
    hh = h.reshape(B, num_heads, dh)
    rh = jnp.einsum("bhd,hde->bhe", hh, params["R"]).reshape(B, 4 * D)
    pre = (wx_t + rh).astype(jnp.float32)
    i_t, f_t, z_t, o_t = jnp.split(pre, 4, axis=-1)
    m_new = jnp.maximum(f_t + m, i_t)
    i_g = jnp.exp(i_t - m_new)
    f_g = jnp.exp(f_t + m - m_new)
    c_new = f_g * c + i_g * jnp.tanh(z_t)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_apply(params: dict, x: jax.Array, num_heads: int, rules: AxisRules,
                *, state=None, return_state: bool = False):
    B, S, D = x.shape
    wx = jnp.einsum("bsd,de->bse", x, params["W"]) + params["b"]  # (B,S,4D)
    if state is None:
        state = slstm_init_state(B, D)

    def step(st, wx_t):
        st = _slstm_cell(params, wx_t, st, num_heads)
        return st, st["h"]

    st, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)                    # (B,S,D)
    u = jax.nn.gelu(jnp.einsum("bsd,de->bse", h, params["up"]))
    u = rules.constrain(u, "batch", "seq", "inner")
    out = jnp.einsum("bse,ed->bsd", u, params["down"])
    out = rules.constrain(out, "batch", "seq", "embed_act")
    if return_state:
        return out, st
    return out


def slstm_init_state(batch: int, d_model: int):
    z = lambda: jnp.zeros((batch, d_model), jnp.float32)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.full((batch, d_model), -1e30, jnp.float32)}


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, d_model: int, num_heads: int, cfg: XLSTMConfig,
               dtype=jnp.float32) -> dict:
    E = _round64(cfg.proj_factor_mlstm * d_model)
    dh = E // num_heads
    ks = jax.random.split(key, 6)
    return {
        "in_proj": layers.dense_init(ks[0], (d_model, 2 * E), ("embed", "inner"), dtype),
        "Wq": layers.dense_init(ks[1], (num_heads, dh, dh), ("heads", None, None),
                                dtype, fan_in=dh),
        "Wk": layers.dense_init(ks[2], (num_heads, dh, dh), ("heads", None, None),
                                dtype, fan_in=dh),
        "Wv": layers.dense_init(ks[3], (num_heads, dh, dh), ("heads", None, None),
                                dtype, fan_in=dh),
        "w_if": layers.dense_init(ks[4], (E, 2 * num_heads), ("inner", None), dtype),
        "out_proj": layers.dense_init(ks[5], (E, d_model), ("inner", "embed"),
                                      dtype, fan_in=E),
    }


def mlstm_apply(params: dict, x: jax.Array, num_heads: int, cfg: XLSTMConfig,
                rules: AxisRules, *, state=None, return_state: bool = False):
    B, S, D = x.shape
    E = _round64(cfg.proj_factor_mlstm * D)
    H, dh = num_heads, E // num_heads

    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    xz = rules.constrain(xz, "batch", "seq", "inner")
    xi, z = jnp.split(xz, 2, axis=-1)
    xih = xi.reshape(B, S, H, dh)
    q = jnp.einsum("bshd,hde->bshe", xih, params["Wq"])
    k = jnp.einsum("bshd,hde->bshe", xih, params["Wk"]) / (dh ** 0.5)
    v = jnp.einsum("bshd,hde->bshe", xih, params["Wv"])
    gates = jnp.einsum("bse,eg->bsg", xi, params["w_if"]).astype(jnp.float32)
    i_t, f_t = jnp.split(gates, 2, axis=-1)                       # (B,S,H)
    f_t = -jax.nn.softplus(-f_t)  # log sigmoid: stable forget in log space

    if state is None:
        state = mlstm_init_state(B, H, dh)

    def step(st, inp):
        C, n, m = st["C"], st["n"], st["m"]
        q_t, k_t, v_t, i_tt, f_tt = inp
        q_t, k_t, v_t = (a.astype(jnp.float32) for a in (q_t, k_t, v_t))
        m_new = jnp.maximum(f_tt + m, i_tt)                       # (B,H)
        i_g = jnp.exp(i_tt - m_new)
        f_g = jnp.exp(f_tt + m - m_new)
        C_new = f_g[..., None, None] * C + i_g[..., None, None] * (
            v_t[..., :, None] * k_t[..., None, :])                # (B,H,dh,dh)
        n_new = f_g[..., None] * n + i_g[..., None] * k_t         # (B,H,dh)
        num = jnp.einsum("bhve,bhe->bhv", C_new, q_t)
        den = jnp.abs(jnp.einsum("bhe,bhe->bh", n_new, q_t))
        den = jnp.maximum(den, jnp.exp(-m_new))
        h_t = num / den[..., None]                                # (B,H,dh)
        return {"C": C_new, "n": n_new, "m": m_new}, h_t

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, i_t, f_t))
    st, hs = jax.lax.scan(step, state, xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, E).astype(x.dtype)
    h = h * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", h, params["out_proj"])
    out = rules.constrain(out, "batch", "seq", "embed_act")
    if return_state:
        return out, st
    return out


def mlstm_init_state(batch: int, num_heads: int, dh: int):
    return {
        "C": jnp.zeros((batch, num_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, num_heads, dh), jnp.float32),
        "m": jnp.full((batch, num_heads), -1e30, jnp.float32),
    }
