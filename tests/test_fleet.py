"""Fleet serving: the tenant-sharding router over N spawn-isolated
worker processes.  Pure tests cover the sharding function and the
cross-process telemetry/metrics merge (worker labels, deterministic
ordering, no input mutation); real-process tests cover end-to-end
serving with tenant→worker consistency, model refresh acks, SIGKILL →
respawn → requeue, and shutdown (drain, idempotent close, no orphans)."""
import multiprocessing
import os
import signal

import pytest

from repro.launch.stats import render
from repro.serving import (FleetRouter, WorkerConfig, fleet_summary,
                           make_trace, merge_metrics, merge_samples,
                           shard_for)
from repro.serving.telemetry import TelemetrySample


def _sample(seq, worker=None, tenant="tenant-0", retire=None, status="ok",
            cache_hit=False, refined=False):
    return TelemetrySample(
        seq=seq, tenant=tenant, workload="vecadd", key="vecadd",
        backend="host-sync", partitions=1, tasks=1, cache_hit=cache_hit,
        predicted_s=None, measured_s=0.01, rel_error=None, status=status,
        refined=refined, t_retire_s=retire, worker=worker)


def _fleet_children():
    return [p for p in multiprocessing.active_children()
            if p.name.startswith("fleet-")]


# -- sharding -----------------------------------------------------------------


def test_shard_for_is_stable_and_in_range():
    for n in (1, 2, 3, 4, 7):
        for i in range(16):
            tenant = f"tenant-{i}"
            slot = shard_for(tenant, n)
            assert 0 <= slot < n
            # CRC32, not hash(): the mapping must agree between router,
            # respawned workers, and a fresh interpreter
            assert slot == shard_for(tenant, n)
    # the 8-tenant default exists because it actually uses both slots
    assert {shard_for(f"tenant-{i}", 2) for i in range(8)} == {0, 1}


# -- telemetry merge ----------------------------------------------------------


def test_merge_samples_labels_orders_and_never_mutates():
    w0 = [_sample(0, retire=2.0), _sample(1, retire=None)]
    w1 = [_sample(0, retire=1.0), _sample(1, retire=2.0)]
    merged = merge_samples({"w0": w0, "w1": w1})

    assert [s.worker for s in merged].count("w0") == 2
    assert all(s.worker in ("w0", "w1") for s in merged)
    # inputs keep their unset worker field — merge copies, never mutates
    assert all(s.worker is None for s in w0 + w1)

    # retire-time order, worker label breaking the 2.0 tie, and the
    # never-retired sample (failed before dispatch) sorting last
    assert [(s.worker, s.seq) for s in merged] == [
        ("w1", 0), ("w0", 0), ("w1", 1), ("w0", 1)]

    # deterministic regardless of dict insertion order
    again = merge_samples({"w1": w1, "w0": w0})
    assert [(s.worker, s.seq, s.t_retire_s) for s in again] \
        == [(s.worker, s.seq, s.t_retire_s) for s in merged]


def test_worker_field_roundtrips_and_stays_backwards_compatible():
    s = _sample(3, worker="w2")
    assert TelemetrySample.from_json(s.to_json()).worker == "w2"
    # pre-fleet JSONL (no worker key) still loads; unknown keys filter
    legacy = {k: v for k, v in s.to_json().items() if k != "worker"}
    legacy["some_future_field"] = 1
    assert TelemetrySample.from_json(legacy).worker is None


def test_merge_metrics_labels_series_and_sorts_deterministically():
    fam = {"type": "counter",
           "values": [{"labels": {"namespace": "shared"}, "value": 2}]}
    merged = merge_metrics({"w1": {"serving.cache.hit": fam},
                            "w0": {"serving.cache.hit": fam},
                            "w2": None})        # died before the goodbye
    series = merged["serving.cache.hit"]["values"]
    assert [e["labels"] for e in series] == [
        {"namespace": "shared", "worker": "w0"},
        {"namespace": "shared", "worker": "w1"}]
    assert merged["serving.cache.hit"]["type"] == "counter"

    # the stats renderer consumes the merged snapshot unchanged
    report = render([_sample(0, worker="w0", retire=1.0)], merged)
    assert "worker=w0" in report

    with pytest.raises(ValueError, match="conflicting types"):
        merge_metrics({"w0": {"m": {"type": "counter", "values": []}},
                       "w1": {"m": {"type": "gauge", "values": []}}})


def test_fleet_summary_breaks_down_per_worker():
    samples = merge_samples({
        "w0": [_sample(0, retire=1.0, cache_hit=True),
               _sample(1, retire=2.0, status="failed")],
        "w1": [_sample(0, retire=1.5, refined=True)]})
    s = fleet_summary(samples)
    assert s["requests"] == 3
    assert s["per_worker"] == {
        "w0": {"requests": 2, "cache_hits": 1, "refinements": 0,
               "failed": 1},
        "w1": {"requests": 1, "cache_hits": 0, "refinements": 1,
               "failed": 0}}


# -- real worker processes ----------------------------------------------------


def test_fleet_end_to_end_shards_refreshes_and_shuts_down(tmp_path):
    """2 real workers, 8 requests over 8 tenants: every result terminal
    and served by the worker its tenant hashes to; refresh acks from
    every worker; close() drains the goodbye metrics, is idempotent, and
    leaves no child processes behind."""
    reqs = make_trace(["vecadd"], occurrences=8, tenants=8, scale_index=0)
    jsonl = tmp_path / "fleet.jsonl"
    with FleetRouter(2, worker=WorkerConfig(model="heuristic"),
                     telemetry_path=str(jsonl)) as fr:
        fr.submit_all(reqs)
        results = fr.run()

        assert len(results) == len(reqs)
        workers_used = set()
        for r in results:
            assert r["status"] in ("served", "degraded")
            s = TelemetrySample.from_json(r["sample"])
            assert s.worker == f"w{shard_for(s.tenant, 2)}"
            workers_used.add(s.worker)
        assert workers_used == {"w0", "w1"}

        tags = fr.refresh_model("heuristic")
        assert set(tags) == {"w0", "w1"}
        assert all(tag == "heuristic" for tag in tags.values())

    assert fr.closed
    fr.close()                                   # idempotent
    assert _fleet_children() == []

    summary = fr.summary()
    assert summary["requests"] == len(reqs)
    assert summary["worker_deaths"] == 0
    assert set(summary["per_worker"]) == {"w0", "w1"}

    # goodbye handshake shipped every worker's metrics; the merge stamps
    # each series with its worker label
    snap = fr.metrics_snapshot()
    assert snap
    for fam in snap.values():
        assert all(e["labels"]["worker"] in ("w0", "w1")
                   for e in fam["values"])

    # the merged fleet JSONL landed on disk, one line per request
    assert sum(1 for _ in open(jsonl)) == len(reqs)


def test_fleet_sigkill_respawns_and_every_request_terminates():
    """SIGKILL a worker between batches: the next run() detects the
    death, respawns the slot, requeues its un-acked work, and every
    admitted request still reaches a terminal status."""
    first = make_trace(["vecadd"], occurrences=4, tenants=8, scale_index=0)
    second = make_trace(["vecadd"], occurrences=8, tenants=8,
                        scale_index=0, seed=1)
    with FleetRouter(2, worker=WorkerConfig(model="heuristic")) as fr:
        fr.submit_all(first)
        assert len(fr.run()) == len(first)

        victim = fr._slots[fr.shard_for("tenant-0")]
        os.kill(victim.pid, signal.SIGKILL)
        victim.proc.join(10)
        assert not victim.proc.is_alive()

        fr.submit_all(second)
        results = fr.run()

        assert len(results) == len(second)
        assert all(r["status"] in ("served", "degraded") for r in results)
        assert fr.stats["worker_deaths"] == 1
        assert fr.stats["worker_respawns"] == 1
        assert fr.stats["requeued_requests"] >= 1
    assert _fleet_children() == []
    assert fr.summary()["requests"] == len(first) + len(second)


def test_fleet_respawn_budget_exhaustion_fails_terminally_and_closes():
    """max_respawns=0: a SIGKILL'd slot is abandoned instead of
    respawned and its un-acked work fails terminally with synthetic
    samples, while the healthy slot keeps serving its own requests.
    The abandoned slot's queues are close()d, so the remaining collect
    iterations and close() must tolerate them (the closed-Queue
    ValueError path) — every admitted request still reaches a terminal
    status and shutdown leaves no orphans."""
    reqs = make_trace(["vecadd"], occurrences=8, tenants=8, scale_index=0)
    with FleetRouter(2, worker=WorkerConfig(model="heuristic"),
                     max_respawns=0) as fr:
        victim_i = fr.shard_for("tenant-0")
        victim = fr._slots[victim_i]
        os.kill(victim.pid, signal.SIGKILL)
        victim.proc.join(10)
        assert not victim.proc.is_alive()

        fr.submit_all(reqs)
        results = fr.run()

        assert len(results) == len(reqs)
        failed, served = [], []
        for r in results:
            s = TelemetrySample.from_json(r["sample"])
            if shard_for(s.tenant, 2) == victim_i:
                failed.append(r)
                assert r["status"] == "failed"
                assert "respawn budget" in r["error"]
                assert s.status == "failed"
                assert s.worker == f"w{victim_i}"
            else:
                served.append(r)
                assert r["status"] in ("served", "degraded")
        assert failed and served     # both slots actually had work
        assert fr.stats["worker_deaths"] == 1
        assert fr.stats["abandoned_slots"] == 1
        assert fr.stats["worker_respawns"] == 0

        # new work for an abandoned seat fails at admission, healthy
        # tenants are unaffected
        more = make_trace(["vecadd"], occurrences=4, tenants=8,
                          scale_index=0, seed=1)
        fr.submit_all(more)
        again = fr.run()
        assert len(again) == len(more)
        for r in again:
            s = TelemetrySample.from_json(r["sample"])
            if shard_for(s.tenant, 2) == victim_i:
                assert r["status"] == "failed"
            else:
                assert r["status"] in ("served", "degraded")
    assert fr.closed
    fr.close()                                   # idempotent
    assert _fleet_children() == []
    assert fr.summary()["requests"] == len(reqs) + len(more)
