"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].

The EnCodec frontend is a STUB per assignment: ``input_specs()`` provides
precomputed frame embeddings (B, S, frontend_dim) — the sum of codebook
embeddings in the real system.  vocab_size=2048 is the codebook size the
output head predicts over.
"""
from repro.configs.base import ArchConfig, register

MUSICGEN_MEDIUM = register(
    ArchConfig(
        name="musicgen-medium",
        family="audio",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        gated_mlp=False,
        frontend="audio_frames",
        frontend_dim=1536,
        source="arXiv:2306.05284",
    )
)
