"""Analytical baselines (Liu et al., Werkhoven et al.) + classifier."""
import numpy as np

from repro.core.analytical import (ProgramProbe, liu_config, probe_from_features,
                                   werkhoven_config)
from repro.core.classifier import KNNClassifier, merge_labels
from repro.core.features import RAW_FEATURE_NAMES
from repro.core.stream_config import StreamConfig


def test_liu_transfer_dominated_gives_two_tasks():
    probe = ProgramProbe(n_rows=1024, bytes_h2d=1e8, bytes_d2h=1e6,
                         t_transfer=10e-3, t_kernel=1e-3)
    cfg = liu_config(probe)
    assert cfg.tasks == 2  # paper: m = N/2 for transfer-dominated


def test_liu_kernel_dominated_scales_with_overhead():
    probe = ProgramProbe(n_rows=4096, bytes_h2d=1e7, bytes_d2h=1e5,
                         t_transfer=1e-3, t_kernel=50e-3)
    cfg = liu_config(probe)
    assert 1 <= cfg.tasks <= 64
    assert cfg.partitions == cfg.tasks  # XeonPhi convention (paper §5.2)


def test_werkhoven_returns_valid_config():
    probe = ProgramProbe(n_rows=2048, bytes_h2d=5e7, bytes_d2h=5e7,
                         t_transfer=5e-3, t_kernel=5e-3)
    cfg = werkhoven_config(probe)
    assert cfg.tasks >= 1 and cfg.partitions == cfg.tasks


def test_werkhoven_prefers_more_tasks_when_overlappable():
    # kernel ~ transfer => pipelining helps => more than one task
    probe = ProgramProbe(n_rows=2048, bytes_h2d=1e8, bytes_d2h=1e8,
                         t_transfer=20e-3, t_kernel=20e-3)
    assert werkhoven_config(probe).tasks > 1


def test_probe_from_features_roundtrip():
    feats = dict(zip(RAW_FEATURE_NAMES, np.arange(len(RAW_FEATURE_NAMES),
                                                  dtype=float)))
    feats["loop_count"] = 128
    feats["dts"] = 1e6
    feats["out_bytes"] = 1e5
    feats["t_transfer_us"] = 100.0
    feats["t_compute_us"] = 900.0
    p = probe_from_features(feats)
    assert p.n_rows == 128 and p.t_kernel == 900e-6


def test_label_merging_removes_rare_labels():
    labels = [StreamConfig(1, 8)] * 5 + [StreamConfig(16, 64)]  # one rare
    merged = merge_labels(labels, min_count=2)
    assert merged.count(StreamConfig(1, 8)) == 6


def test_knn_classifier_predicts_seen_label():
    rng = np.random.default_rng(0)
    X = np.concatenate([rng.normal(0, 0.1, (10, 5)),
                        rng.normal(5, 0.1, (10, 5))])
    labels = [StreamConfig(1, 4)] * 10 + [StreamConfig(8, 32)] * 10
    clf = KNNClassifier.train(X, labels, k=3)
    assert clf.predict(np.zeros(5)) == StreamConfig(1, 4)
    assert clf.predict(np.full(5, 5.0)) == StreamConfig(8, 32)
