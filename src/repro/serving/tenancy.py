"""Per-tenant serving isolation.

The pre-tenancy serving stack shared ONE tuning cache, ONE performance
model, and ONE set of drift windows across every tenant on the box.
That sharing contaminates statistics in both directions: tenant A's
drifted workload triggers a refinement that rewrites the cache entry and
refits the model tenant B is being served from, and B's perfectly
healthy samples dilute A's drift window so real drift fires late.  The
companion tuning work (Zhang et al., arXiv:1802.02760) evaluates
per-program configurations against per-program oracles, and Memeti &
Pllana (arXiv:2106.01441) show performance-aware scheduling must account
for co-running load — both argue for the same split implemented here:

  :class:`TenantContext`   one tenant's private serving state — a
      tuning-cache *namespace* (tenant-prefixed keys in the shared
      cache, so one persisted file still holds the fleet), its own
      :class:`~repro.serving.refinement.DriftDetector` windows, and a
      lazily forked performance model;
  :class:`TenantRegistry`  resolves request tenant → context.  With
      ``isolate=False`` (the default everywhere) every tenant maps to
      one shared context with an empty namespace — byte-identical
      behavior, keys, and persisted caches to the pre-tenancy stack.

Model forking is copy-on-refit: all tenants serve from the shared
read-only base model until their first drift refinement, at which point
the refitting tenant gets a private fork
(:meth:`~repro.core.perf_model.PerformanceModel.fork`) and only that
fork moves.  Models without a ``refit`` hook (e.g. the zero-training
heuristic) are never forked — there is no mutable state to isolate.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

from repro.serving.refinement import DriftDetector


def fork_model(model):
    """Refit-isolated copy of ``model``; the model itself when it has no
    refit hook (immutable under serving, so sharing is safe)."""
    if not hasattr(model, "refit"):
        return model
    if hasattr(model, "fork"):
        return model.fork()
    import copy
    return copy.deepcopy(model)


@dataclasses.dataclass
class TenantContext:
    """One tenant's private serving state.

    ``namespace`` prefixes this tenant's tuning-cache keys (empty for
    the shared non-isolated context); ``drift`` holds this tenant's
    rolling per-bucket error windows; ``model`` is ``None`` until the
    first refinement needs to refit, then a private fork of
    ``base_model``."""

    name: str
    base_model: object
    drift: DriftDetector
    namespace: str = ""
    model: Optional[object] = None
    refinements: int = 0
    served: int = 0
    #: False only for the registry's shared non-isolated context: refits
    #: then land on ``base_model`` IN PLACE — the pre-tenancy contract,
    #: where the caller's model object receives every online refit
    isolated: bool = True

    @property
    def active_model(self):
        """The model this tenant's searches and refinements use: the
        shared base until the tenant has forked, its own fork after."""
        return self.model if self.model is not None else self.base_model

    @property
    def forked(self) -> bool:
        return self.model is not None

    def fork_for_refit(self):
        """Copy-on-refit: the first refit forks the base model so the
        tenant's measured feedback never leaks into other tenants (or
        the read-only base).  Idempotent.  The shared non-isolated
        context never forks — there is only one tenant population, and
        the caller handed us its model expecting in-place refits."""
        if not self.isolated:
            return self.base_model
        if self.model is None:
            forked = fork_model(self.base_model)
            # a model with no refit hook forks to itself — leave
            # ``model`` unset so ``forked`` stays honest
            if forked is not self.base_model:
                self.model = forked
        return self.active_model


class TenantRegistry:
    """Maps request tenants to :class:`TenantContext`\\ s.

    ``isolate=False``: one shared context (empty cache namespace, the
    scheduler's own drift detector) serves every tenant — the exact
    pre-tenancy behavior.  ``isolate=True``: each tenant lazily gets a
    context with its own namespace and a fresh clone of the drift
    detector template."""

    def __init__(self, base_model, shared_drift: DriftDetector, *,
                 isolate: bool = False):
        self.isolate = isolate
        self.base_model = base_model
        self._template = shared_drift
        self._shared = TenantContext("*", base_model, shared_drift,
                                     isolated=False)
        self._contexts: dict[str, TenantContext] = {}
        #: artifact id the base model was loaded from; None when the
        #: caller handed us a model object directly
        self.base_artifact_id: Optional[str] = None

    @classmethod
    def from_model_registry(cls, registry, shared_drift: DriftDetector, *,
                            spec: str = "latest", isolate: bool = False
                            ) -> "TenantRegistry":
        """Draw the shared read-only base model from a
        :class:`~repro.core.modeling.registry.ModelRegistry` artifact —
        per-tenant copy-on-refit forks then descend from a real trained
        model, not a heuristic stand-in.  The loaded artifact id lands on
        ``.base_artifact_id`` so the caller can key caches / hot-swap
        polls off it."""
        model, manifest = registry.load(spec)
        reg = cls(model, shared_drift, isolate=isolate)
        reg.base_artifact_id = manifest.get("artifact_id")
        return reg

    def get(self, tenant: str) -> TenantContext:
        if not self.isolate:
            return self._shared
        ctx = self._contexts.get(tenant)
        if ctx is None:
            ctx = TenantContext(tenant, self.base_model,
                                self._template.clone(), namespace=tenant)
            self._contexts[tenant] = ctx
        return ctx

    def namespace(self, tenant: str) -> str:
        return tenant if self.isolate else ""

    # -- model lifecycle ------------------------------------------------------

    def hot_swap(self, base_model) -> int:
        """Swap the shared read-only base model (a newly published
        registry artifact).  Every context still serving from the base —
        including the shared non-isolated one — follows immediately;
        tenants that already forked keep their fork, whose measured
        online corrections are newer than any offline retrain.  Returns
        how many contexts now serve the new base."""
        self.base_model = base_model
        swapped = 0
        for ctx in [self._shared, *self._contexts.values()]:
            ctx.base_model = base_model
            if ctx.model is None:
                swapped += 1
        return swapped

    def persist_forks(self, model_registry, **meta) -> dict[str, str]:
        """Publish every tenant's refined fork back into a
        :class:`~repro.core.modeling.registry.ModelRegistry` as a
        tenant-tagged artifact (never auto-pinned as ``latest``).
        Returns tenant name -> published artifact id.  A fork with no
        artifact serialization support is skipped — there is nothing
        durable to persist."""
        published: dict[str, str] = {}
        for ctx in self._contexts.values():
            if ctx.model is None or not hasattr(ctx.model, "to_state"):
                continue
            published[ctx.name] = model_registry.publish(
                ctx.model, tenant=ctx.name, **meta)
        return published

    @property
    def contexts(self) -> dict[str, TenantContext]:
        """Materialized per-tenant contexts (empty when not isolating)."""
        return dict(self._contexts)

    def __iter__(self) -> Iterator[TenantContext]:
        return iter(self._contexts.values())

    def __len__(self) -> int:
        return len(self._contexts)
