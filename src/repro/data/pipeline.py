"""Synthetic sharded data pipeline with double-buffered host prefetch.

The host->device feed is the pod-scale face of the paper's host-device
transfer stage: batches are staged on host threads and ``device_put`` with
the global batch sharding one step ahead of consumption, so the H2D copy of
step i+1 overlaps compute of step i (temporal sharing).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_dim: int = 0  # >0 => also emit stub frontend embeddings


class SyntheticLM:
    """Deterministic synthetic token stream (seeded; reproducible across
    restarts — a restart at step k regenerates the identical batch k)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        tokens = rng.integers(
            0, cfg.vocab_size, (cfg.global_batch, cfg.seq_len + 1),
            dtype=np.int32)
        out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        if cfg.frontend_dim:
            out["embeds"] = rng.standard_normal(
                (cfg.global_batch, cfg.seq_len, cfg.frontend_dim)
            ).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchFeeder:
    """Stages batches onto device(s) ``depth`` steps ahead on a host thread."""

    def __init__(self, source: SyntheticLM, sharding=None, *,
                 depth: int = 2, start_step: int = 0):
        self.source = source
        self.sharding = sharding
        self.depth = depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            host = self.source.batch_at(step)
            if self.sharding is not None:
                dev = jax.device_put(host, self.sharding)
            else:
                dev = jax.device_put(host)
            try:
                self._q.put((step, dev), timeout=1.0)
            except queue.Full:
                if self._stop.is_set():
                    return
                continue
            step += 1

    def next(self):
        step, batch = self._q.get()
        return step, batch

    def stop(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
