"""Thread-pool host backend: tasks issued from worker threads with a
bounded in-flight window.

Where ``host-pipelined`` overlaps H2D and compute by interleaving async
dispatches from one host thread, this backend overlaps them by issuing
each task (transfer + kernel dispatch + retire) from a pool thread — the
host-side analogue of multiple hardware queues.  JAX dispatch is
thread-safe; concurrent tracing of the same shape serializes on JAX's own
compilation lock, so the first dispatch per shape costs the same as the
single-threaded backends.

Ordering contract: outputs are collected into a task-indexed slot table,
so the returned list is task-major, partition-minor regardless of the
completion order of the workers.

The in-flight ``window`` bounds how many tasks can be submitted but not
yet retired — the same live-buffer bound the pipelined backend gets from
its depth-``d`` deque, enforced here by blocking the submitting thread on
the oldest outstanding future.
"""
from __future__ import annotations

import collections
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import jax

from repro.core.backends.base import ExecutionContext, StreamBackend, \
    split_arrays


class ThreadedHostBackend(StreamBackend):
    name = "host-threads"
    kind = "runner"

    def __init__(self, workers: int = 4, window: int = 8):
        assert workers >= 1 and window >= 1, (workers, window)
        self.workers = workers
        self.window = window
        self._pool: Optional[ThreadPoolExecutor] = None

    def _executor(self) -> ThreadPoolExecutor:
        # lazy: module import registers the instance, and spawning threads
        # at import time would cost every process that never dispatches
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self.workers,
                thread_name_prefix="host-threads")
        return self._pool

    def dispatch(self, ctx: ExecutionContext, config) -> list:
        plans = [split_arrays(task, config.partitions)
                 for task in split_arrays(ctx.chunked, config.tasks)]

        def issue(parts):
            devs = [jax.device_put(p, ctx.device) for p in parts]
            outs = [ctx.jit_kernel(pd, ctx.shared_dev) for pd in devs]
            # retire inside the worker: a completed future means the
            # task's buffers are no longer accumulating in flight
            jax.block_until_ready(outs)
            return outs

        pool = self._executor()
        results: list = [None] * len(plans)
        inflight: collections.deque = collections.deque()
        for i, parts in enumerate(plans):
            while len(inflight) >= self.window:
                j, fut = inflight.popleft()
                results[j] = fut.result()
            inflight.append((i, pool.submit(issue, parts)))
        while inflight:
            j, fut = inflight.popleft()
            results[j] = fut.result()
        return [o for task_outs in results for o in task_outs]
