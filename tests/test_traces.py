"""Trace generator + virtual-time replay harness: determinism, arrival
processes, Zipf/tenant skew, shape churn, the four queue policies under
generated traffic (fair rotation, priority tie-breaks, deadline
boost/shed), and the harness-level acceptance bars — deadline beats
fifo on SLO violations over the same bursty trace, a stationary trace
produces zero drift refinements at window=8, and an injected drift
fires exactly one."""
import pytest

from repro.serving import (POLICIES, RequestQueue, WorkloadRequest,
                           contention_factor)
from repro.serving.clock import VirtualClock
from repro.serving.telemetry import TelemetryLog, percentile
from repro.serving.traces import (ServiceModel, TraceConfig,
                                  generate_trace, simulate_trace)

# small but bursty enough to overload: deadline must both shed and beat
# fifo on violations (calibrated against the default ServiceModel)
BURSTY = TraceConfig(n_requests=6000, seed=11, arrival="bursty",
                     burst_rate_rps=2600.0)


def _field_view(req):
    return (req.workload, req.tenant, req.priority,
            req.arrival_s, req.deadline_s)


# -- clock -------------------------------------------------------------------


def test_virtual_clock_semantics():
    c = VirtualClock()
    assert c.now() == 0.0
    assert c.advance(1.5) == 1.5
    assert c.advance_to(1.0) == 1.5          # monotone: no going back
    assert c.advance_to(4.0) == 4.0
    with pytest.raises(ValueError):
        c.advance(-0.1)


def test_percentile_interpolates():
    with pytest.raises(ValueError):
        percentile([], 0.5)
    assert percentile([7.0], 0.99) == 7.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 0.5) == pytest.approx(2.5)
    assert percentile([1.0, 2.0, 3.0, 4.0], 1.0) == 4.0


# -- contention_factor edge cases --------------------------------------------


def test_contention_factor_zero_workers_is_serial():
    # degenerate pool: nothing overlaps, so no deflation — regression
    # guard for the falsy-check bug where 0 meant "uncapped"
    assert contention_factor(8, 1.6, workers=0) == 1.0
    assert contention_factor(1, 1.6, workers=0) == 1.0


def test_contention_factor_negative_workers_rejected():
    with pytest.raises(ValueError):
        contention_factor(4, 1.6, workers=-1)


def test_contention_factor_none_workers_uncapped():
    assert contention_factor(8, 1.6, workers=None) == pytest.approx(5.0)
    assert contention_factor(8, 1.6, workers=2) == pytest.approx(1.25)
    assert contention_factor(1, None, workers=4) == 1.0


# -- generator ---------------------------------------------------------------


def test_generate_trace_deterministic_and_sorted():
    cfg = TraceConfig(n_requests=800, seed=3, arrival="bursty")
    a = [_field_view(r) for r in generate_trace(cfg)]
    b = [_field_view(r) for r in generate_trace(cfg)]
    assert a == b
    assert len(a) == 800
    arrivals = [v[3] for v in a]
    assert arrivals == sorted(arrivals)
    # a different seed yields a different trace
    c = [_field_view(r) for r in
         generate_trace(TraceConfig(n_requests=800, seed=4,
                                    arrival="bursty"))]
    assert a != c


def test_generate_trace_shares_data_per_bucket():
    cfg = TraceConfig(n_requests=400, seed=0,
                      workloads=("vecadd", "dotprod"))
    reqs = list(generate_trace(cfg))
    by_bucket = {}
    for r in reqs:
        shape = next(iter(r.chunked.values())).shape
        by_bucket.setdefault((r.workload, shape), set()).add(
            id(next(iter(r.chunked.values()))))
    # every request in a (workload, shape) bucket references the SAME
    # arrays — a million-request trace costs bucket-count allocations
    assert all(len(ids) == 1 for ids in by_bucket.values())


def test_generate_trace_zipf_and_tenant_skew():
    cfg = TraceConfig(n_requests=4000, seed=5)
    reqs = list(generate_trace(cfg))
    wl_counts = {}
    tn_counts = {}
    for r in reqs:
        wl_counts[r.workload] = wl_counts.get(r.workload, 0) + 1
        tn_counts[r.tenant] = tn_counts.get(r.tenant, 0) + 1
    ranked = sorted(wl_counts.values(), reverse=True)
    # Zipf head: the most popular workload dominates the median one
    assert ranked[0] > 4 * ranked[len(ranked) // 2]
    # tenant skew: the lead tenant out-submits the tail tenant
    assert tn_counts[cfg.tenants[0]] > 2 * tn_counts[cfg.tenants[-1]]
    # the SLO mix is applied: both deadline classes appear
    slos = {round(r.deadline_s - r.arrival_s, 6) for r in reqs}
    assert slos == {s for _, s in cfg.slo_choices}


def test_shape_churn_defeats_single_bucket():
    churned = TraceConfig(n_requests=900, seed=2, workloads=("vecadd",),
                          churn_prob=0.2)
    shapes = {next(iter(r.chunked.values())).shape
              for r in generate_trace(churned)}
    assert len(shapes) > 1
    frozen = TraceConfig(n_requests=900, seed=2, workloads=("vecadd",),
                         churn_prob=0.0, churn_every=0)
    shapes = {next(iter(r.chunked.values())).shape
              for r in generate_trace(frozen)}
    assert len(shapes) == 1


def test_generate_trace_rejects_unknown_arrival():
    with pytest.raises(ValueError):
        next(generate_trace(TraceConfig(n_requests=1, arrival="square")))


# -- queue policies under generated traffic ----------------------------------


def _mini(workload="w", tenant="t", priority=0, deadline=None):
    return WorkloadRequest(workload=workload, chunked={}, shared={},
                           tenant=tenant, priority=priority,
                           deadline_s=deadline)


def test_fair_rotates_under_tenant_skew():
    q = RequestQueue("fair")
    for i in range(6):
        q.push(_mini(workload=f"a{i}", tenant="chatty"))
    for i in range(2):
        q.push(_mini(workload=f"b{i}", tenant="quiet"))
    order = [(q.pop().tenant) for _ in range(8)]
    # round-robin while both have work, then the chatty backlog drains
    assert order == ["chatty", "quiet", "chatty", "quiet",
                     "chatty", "chatty", "chatty", "chatty"]


def test_priority_ties_break_by_arrival():
    q = RequestQueue("priority")
    q.push(_mini(workload="low", priority=0))
    q.push(_mini(workload="first", priority=5))
    q.push(_mini(workload="second", priority=5))
    assert [q.pop().workload for _ in range(3)] == \
        ["first", "second", "low"]


def test_deadline_boost_shed_and_ordering():
    clock = VirtualClock()
    q = RequestQueue("deadline", clock=clock)
    q.push(_mini(workload="slack", deadline=10.0))
    q.push(_mini(workload="doomed", deadline=1.0))
    q.push(_mini(workload="tight", deadline=2.0))
    q.push(_mini(workload="never"))               # no deadline: runs last
    assert q.pop().workload == "doomed"           # EDF boost
    clock.advance_to(1.5)
    # "doomed" already popped; next-nearest is now expired → shed
    q.push(_mini(workload="expired", deadline=1.2))
    assert q.pop().workload == "tight"
    assert [r.workload for r in q.shed] == ["expired"]
    clock.advance_to(99.0)
    # only expired + deadline-less left: slack sheds, "never" still runs
    assert q.pop().workload == "never"
    assert [r.workload for r in q.shed] == ["expired", "slack"]
    with pytest.raises(IndexError):
        q.pop()


def test_deadline_queue_all_expired_raises_after_shedding():
    clock = VirtualClock()
    q = RequestQueue("deadline", clock=clock)
    q.push(_mini(workload="a", deadline=1.0))
    q.push(_mini(workload="b", deadline=2.0))
    clock.advance_to(5.0)
    assert len(q) == 2            # classification happens at pop time
    with pytest.raises(IndexError):
        q.pop()
    assert len(q.shed) == 2 and len(q) == 0


def test_pending_by_tenant_consistent_across_policies():
    reqs = [("acme", 2, 1.0), ("acme", 0, None), ("globex", 1, 2.0),
            ("initech", 0, None), ("globex", 2, 3.0)]
    expected = {"acme": 2, "globex": 2, "initech": 1}
    for policy in POLICIES:
        q = RequestQueue(policy, clock=VirtualClock())
        for tenant, prio, dl in reqs:
            q.push(_mini(tenant=tenant, priority=prio, deadline=dl))
        assert q.pending_by_tenant() == expected, policy
        assert len(q) == len(reqs)


# -- replay harness ----------------------------------------------------------


def test_deadline_beats_fifo_on_bursty_trace():
    fifo = simulate_trace(generate_trace(BURSTY), policy="fifo", seed=11)
    edf = simulate_trace(generate_trace(BURSTY), policy="deadline",
                         seed=11)
    assert fifo["slo"]["violation_rate"] > 0.1      # genuinely overloaded
    assert edf["slo"]["violation_rate"] < fifo["slo"]["violation_rate"]
    # shedding happened and the accounting balances: every arrival either
    # retired or was shed, and shed work counts as an SLO miss
    assert edf["shed"] > 0
    assert edf["completed"] + edf["shed"] == edf["n_requests"]
    assert edf["slo"]["violation_rate"] == pytest.approx(
        (edf["slo"]["violations_retired"] + edf["shed"])
        / edf["slo"]["with_deadline"])
    # fifo never sheds
    assert fifo["shed"] == 0 and fifo["completed"] == fifo["n_requests"]
    # queue-depth stats are populated and ordered
    for r in (fifo, edf):
        qd = r["queue_depth"]
        assert 0 <= qd["mean"] <= qd["max"] and qd["p95"] <= qd["max"]


def test_stationary_trace_zero_refinements_at_window8():
    """The load-aware acceptance bar: 10^5-scale stationary traffic at
    window=8 must never confuse contention for drift (scaled down here;
    the full-size run is the committed BENCH_latency baseline)."""
    cfg = TraceConfig(n_requests=6000, seed=7, arrival="poisson")
    r = simulate_trace(generate_trace(cfg), policy="fifo", window=8,
                       seed=7)
    assert r["refinements"] == 0
    assert r["completed"] == 6000


def test_drift_injection_fires_exactly_one_refinement():
    cfg = TraceConfig(n_requests=5000, seed=5, arrival="poisson",
                      workloads=("vecadd",), churn_prob=0.0,
                      churn_every=0, slo_choices=None)
    r = simulate_trace(generate_trace(cfg), policy="fifo", seed=5,
                       drift_injections=[(4.0, "vecadd", 5.0)])
    assert r["refinements"] == 1
    assert r["refined_keys"][0].startswith("vecadd|")


def test_simulate_trace_deterministic():
    cfg = TraceConfig(n_requests=1500, seed=9, arrival="bursty")
    a = simulate_trace(generate_trace(cfg), policy="deadline", seed=9)
    b = simulate_trace(generate_trace(cfg), policy="deadline", seed=9)
    assert a == b


def test_policies_see_identical_service_draws():
    """Per-request service noise is indexed by arrival sequence, not
    dispatch order, so policy A/Bs compare on the same draws: under a
    light load where no queueing happens, every policy's latency list is
    identical."""
    cfg = TraceConfig(n_requests=600, seed=13, arrival="poisson",
                      rate_rps=20.0, slo_choices=None)
    stats = {p: simulate_trace(generate_trace(cfg), policy=p, seed=13)
             for p in POLICIES}
    base = stats["fifo"]["latency"]
    for p in POLICIES:
        assert stats[p]["latency"] == pytest.approx(base)


def test_simulate_trace_telemetry_stamps_monotone():
    cfg = TraceConfig(n_requests=400, seed=1, arrival="poisson")
    log = TelemetryLog()
    r = simulate_trace(generate_trace(cfg), policy="fifo", seed=1,
                       telemetry=log)
    assert len(log) == r["completed"] == 400
    for s in log:
        assert s.t_enqueue_s <= s.t_decide_s <= s.t_dispatch_s \
            <= s.t_retire_s
        assert s.latency_s == pytest.approx(s.t_retire_s - s.t_enqueue_s)
        assert s.deadline_s is not None
        assert s.queue_depth >= 0 and s.inflight >= 1
    # the summary computed from full samples agrees with the report
    assert log.summary()["latency"]["p95_s"] == \
        pytest.approx(r["latency"]["p95_s"])


def test_simulate_trace_rejects_unknown_policy():
    with pytest.raises(ValueError):
        simulate_trace([], policy="lifo")


def test_service_model_shift_and_determinism():
    a, b = ServiceModel(3), ServiceModel(3)
    assert a.true_time("vecadd", 512) == b.true_time("vecadd", 512)
    before = a.true_time("vecadd", 512)
    a.shift("vecadd", 4.0)
    assert a.true_time("vecadd", 512) == pytest.approx(4.0 * before)
    assert a.true_time("dotprod", 512) == b.true_time("dotprod", 512)
    assert ServiceModel(4).true_time("vecadd", 512) != before
