"""Serving stats CLI: render telemetry JSONL + metrics snapshots as a
terminal summary.

    python -m repro.launch.stats --telemetry telemetry.jsonl
    python -m repro.launch.stats --metrics metrics.json
    python -m repro.launch.stats --telemetry t.jsonl --follow

``--telemetry`` reads the per-request JSONL stream the schedulers append
(:class:`repro.serving.TelemetryLog`) and prints the aggregate view:
request/hit/refinement counts, the latency tail, SLO violations, mean
prediction error per workload, and the per-tenant breakdown.
``--metrics`` reads a :meth:`MetricsRegistry.save` snapshot and prints
every family (counters/gauges inline, histograms as count/mean/max).
``--follow`` re-reads and re-renders every ``--interval`` seconds —
`watch(1)` for a live serving process, surviving partial trailing lines
(the line-buffered log may be mid-write).

The pure :func:`render` function is the testable core: samples + an
optional metrics snapshot in, the formatted report string out.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Optional

from repro.serving.telemetry import TelemetryLog, TelemetrySample


def read_telemetry(path: str) -> list[TelemetrySample]:
    """Tolerant JSONL read: a truncated trailing line (the serving
    process is mid-append) is skipped, not fatal."""
    out: list[TelemetrySample] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(TelemetrySample.from_json(json.loads(line)))
            except (json.JSONDecodeError, TypeError):
                continue
    return out


def _fmt_s(v: Optional[float]) -> str:
    if v is None:
        return "-"
    if v < 1e-3:
        return f"{v * 1e6:.0f}us"
    if v < 1.0:
        return f"{v * 1e3:.1f}ms"
    return f"{v:.3f}s"


_BREAKER_STATES = {0: "closed", 1: "half-open", 2: "open"}


def _render_resilience(metrics: dict) -> list[str]:
    """The fault-tolerance dashboard block: injected / recovered /
    degraded totals and the per-(tenant, stage) circuit-breaker states
    (``serving.breaker.state`` gauges encode 0=closed, 1=half-open,
    2=open)."""
    lines: list[str] = []

    def total(name: str) -> int:
        return int(sum(v["value"] for v in
                       metrics.get(name, {}).get("values", [])))

    counts = {short: total(f"serving.faults.{short}")
              for short in ("injected", "recovered", "degraded")}
    counts["watchdog"] = total("serving.watchdog.fired")
    counts["failed"] = total("serving.requests.failed")
    if any(counts.values()):
        lines.append("faults   " + "  ".join(
            f"{k} {v}" for k, v in counts.items()))
    for entry in metrics.get("serving.breaker.state", {}).get("values", []):
        sel = entry["labels"]
        state = _BREAKER_STATES.get(int(entry["value"]),
                                    str(entry["value"]))
        lines.append(f"breaker  {sel.get('tenant', '?'):<12s} "
                     f"{sel.get('stage', '?'):<10s} {state}")
    return lines


def render(samples: list[TelemetrySample],
           metrics: Optional[dict] = None) -> str:
    """The report string for a sample list + optional metrics snapshot
    (the dict shape :meth:`MetricsRegistry.snapshot` returns)."""
    lines: list[str] = []
    log = TelemetryLog()
    log.samples = list(samples)
    s = log.summary()
    lines.append("== serving telemetry ==")
    lines.append(f"requests {s['requests']}  "
                 f"cache_hits {s['cache_hits']} "
                 f"(hit_rate {s['hit_rate']:.2f})  "
                 f"refinements {s['refinements']}")
    lat = s["latency"]
    if lat is not None:
        lines.append(f"latency  p50 {_fmt_s(lat['p50_s'])}  "
                     f"p95 {_fmt_s(lat['p95_s'])}  "
                     f"p99 {_fmt_s(lat['p99_s'])}  "
                     f"max {_fmt_s(lat['max_s'])}")
    else:
        lines.append("latency  (no retired requests)")
    if s["slo_violation_rate"] is not None:
        lines.append(f"slo      violations {s['slo_violations']} "
                     f"(rate {s['slo_violation_rate']:.3f})")
    if s["mean_rel_error"] is not None:
        lines.append(f"rel_err  mean {s['mean_rel_error']:.3f}")
        for w, e in s["mean_rel_error_by_workload"].items():
            lines.append(f"         {w:<20s} {e:.3f}")
    for name, t in s["per_tenant"].items():
        err = (f"{t['mean_rel_error']:.3f}"
               if t["mean_rel_error"] is not None else "-")
        lines.append(f"tenant   {name:<12s} served {t['requests']:<6d} "
                     f"hits {t['cache_hits']:<6d} "
                     f"refines {t['refinements']:<3d} err {err}")
    by_status = s.get("by_status") or {}
    if set(by_status) - {"ok"}:
        lines.append("status   " + "  ".join(
            f"{k} {by_status[k]}"
            for k in ("ok", "degraded", "failed", "timeout")
            if by_status.get(k)))
    if metrics:
        res = _render_resilience(metrics)
        if res:
            lines.append("== resilience ==")
            lines.extend(res)
        lines.append("== metrics ==")
        for name in sorted(metrics):
            fam = metrics[name]
            for entry in fam["values"]:
                sel = ",".join(f"{k}={v}" for k, v in
                               sorted(entry["labels"].items()))
                label = f"{name}{{{sel}}}" if sel else name
                v = entry["value"]
                if fam["type"] == "histogram":
                    mean = v["mean"]
                    lines.append(
                        f"{label:<44s} count {v['count']:<8d} "
                        f"mean {_fmt_s(mean)} max {_fmt_s(v['max'])}")
                else:
                    val = (f"{v:g}" if isinstance(v, float) else str(v))
                    lines.append(f"{label:<44s} {val}")
    return "\n".join(lines)


def main(argv: Optional[list[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="render serving telemetry/metrics artifacts")
    ap.add_argument("--telemetry", default=None,
                    help="per-request telemetry JSONL path")
    ap.add_argument("--metrics", default=None,
                    help="MetricsRegistry snapshot JSON path")
    ap.add_argument("--follow", action="store_true",
                    help="re-render every --interval seconds")
    ap.add_argument("--interval", type=float, default=2.0)
    args = ap.parse_args(argv)
    if not args.telemetry and not args.metrics:
        ap.error("give --telemetry and/or --metrics")

    def once() -> str:
        samples = (read_telemetry(args.telemetry)
                   if args.telemetry and os.path.exists(args.telemetry)
                   else [])
        metrics = None
        if args.metrics and os.path.exists(args.metrics):
            with open(args.metrics) as f:
                metrics = json.load(f)
        return render(samples, metrics)

    try:
        if not args.follow:
            print(once())
            return
        while True:
            print("\x1b[2J\x1b[H" + once(), flush=True)
            time.sleep(args.interval)
    except KeyboardInterrupt:
        print("", file=sys.stderr)
    except BrokenPipeError:
        # reader (head, less) closed the pipe — normal CLI exit, but
        # devnull-dup stdout so the interpreter's flush-at-exit is quiet
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())


if __name__ == "__main__":
    main()
