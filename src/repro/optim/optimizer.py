"""AdamW in pure JAX (no optax offline), with:
  - cosine/linear-warmup schedules,
  - optional bf16 first/second-moment state (halves optimizer HBM — a
    distributed-optimization lever recorded in EXPERIMENTS.md §Perf),
  - global-norm clipping,
  - state sharded exactly like the params (specs derive from param axes).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32   # bf16 halves optimizer memory
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"         # cosine | linear | const


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "const":
        decay = 1.0
    else:
        frac = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - frac
    return cfg.lr * warm * decay


def init_state(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def state_logical_axes(param_axes, cfg: AdamWConfig):
    """Optimizer state shards exactly like its parameter."""
    return {
        "step": (),
        "m": param_axes,
        "v": param_axes,
    }


def _global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else 1.0
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return (newp.astype(p.dtype), m32.astype(cfg.state_dtype),
                v32.astype(cfg.state_dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"step": step, "m": new_m, "v": new_v}, {
        "grad_norm": gnorm, "lr": lr}
