# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
from pathlib import Path

#: the repository checkout root — the single source for in-repo default
#: paths (profile cache, model registry); env vars override per path
REPO_ROOT = Path(__file__).resolve().parents[3]
