import os
import sys

# tests must see the real single CPU device (the 512-device flag is only
# ever set inside launch/dryrun.py's own process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)
