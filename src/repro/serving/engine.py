"""The concurrent serving engine: overlapped request execution on a
bounded worker pool.

The serial :class:`~repro.serving.scheduler.AdaptiveScheduler` chains
every request's millisecond *execution* behind the previous one, even
though the paper's whole point (§3.3) is that the placement *decision* is
microseconds.  This engine splits the per-request pipeline into the three
stages the scheduler already exposes and overlaps them across requests:

  decide    coordinator thread: queue pop (policy order), cache lookup,
            and — for the cold requests of a window fill — ONE batched
            model search over a ``(B, F)`` feature matrix
            (:meth:`AdaptiveScheduler._tune_cold_batch`);
  dispatch  a bounded worker pool (the ``host-threads`` backend's
            :class:`~repro.core.backends.host_threads.WindowedPool`
            machinery) executes up to ``window`` requests concurrently;
  retire    coordinator thread: completions are collected out of order,
            but telemetry / drift observation for each tuning bucket is
            flushed in that bucket's dispatch order
            (:class:`OrderedRetirer`), so the drift detector sees the
            same per-bucket sample sequence a serial pass would.

Ordering guarantees:
  * decisions (and therefore config choices) happen in queue-policy
    order, identical to the serial scheduler;
  * ``run()`` returns results in decision order;
  * telemetry ``seq`` reflects retirement order — out of order across
    buckets, dispatch-ordered within each bucket.

The dispatch hot path is amortized two ways: partition slicing plans are
memoized per (row-count, config) in :mod:`repro.core.backends.base`, and
:class:`ContextPool` recycles ``ExecutionContext`` objects per workload,
swapping in each request's buffers instead of rebuilding a
:class:`StreamedRunner` (an empty shared dict then costs zero H2D).

Measurement discipline: cold-path profiling (feature extraction, the
single-stream anchor of a persisted warm hit) drains the in-flight
window first, so the numbers persisted into the tuning cache and the
prediction anchor are measured on an idle pool.  ``measured_s`` itself
is wall time under concurrency — contention inflates it relative to an
isolated run — so the drift signal is **load-aware**: each dispatch is
stamped with its window occupancy, and at retire time ``measured_s`` is
divided by ``contention_factor(inflight, parallel_capacity, workers)``
(occupancy over the host's calibrated thread-scaling ceiling) before
the prediction error is computed.  Overlap inflation therefore no
longer masquerades as model drift; ``load_aware=False`` restores the
raw-wall-time signal for A/B measurement.
"""
from __future__ import annotations

import collections
import sys
from concurrent.futures import FIRST_COMPLETED, wait
from typing import Optional

from repro.core.backends import ExecutionContext
from repro.core.backends.host_threads import WindowedPool
from repro.core.streams import StreamedRunner, probe_host_capacity
from repro.core.workloads import get_workload
from repro.serving.queue import WorkloadRequest
from repro.serving.refinement import DriftDetector, contention_factor
from repro.serving.scheduler import (AdaptiveScheduler, PendingRequest,
                                     RequestResult)


class ContextPool:
    """Per-workload free lists of reusable :class:`ExecutionContext`\\ s.

    Concurrent requests of the same workload each lease their own
    context (their chunked/shared buffers differ); a released context is
    recycled for the next lease with
    :meth:`ExecutionContext.swap_buffers`."""

    def __init__(self, device=None):
        self.device = device
        self._free: dict[str, list[ExecutionContext]] = {}
        self.leases = 0
        self.reuses = 0

    def lease(self, wl, chunked: dict, shared: dict) -> ExecutionContext:
        self.leases += 1
        free = self._free.get(wl.name)
        if free:
            self.reuses += 1
            return free.pop().swap_buffers(chunked, shared)
        return ExecutionContext.create(wl.kernel, chunked, shared,
                                       self.device)

    def release(self, name: str, ctx: ExecutionContext) -> None:
        self._free.setdefault(name, []).append(ctx)


class OrderedRetirer:
    """Buffers out-of-order completions so each bucket retires in its own
    dispatch order.

    ``issue(key)`` stamps a dispatch index for the bucket;
    ``complete(key, idx, payload)`` hands back every payload that is now
    retirable — i.e. the contiguous run of completions starting at the
    bucket's next-unretired index.  Deterministic: for ANY completion
    order of a fixed dispatch sequence, the concatenation of returned
    payload lists per bucket is that bucket's dispatch order."""

    def __init__(self):
        self._issued: collections.Counter = collections.Counter()
        self._next: collections.Counter = collections.Counter()
        self._held: dict = {}

    def issue(self, key: str) -> int:
        idx = self._issued[key]
        self._issued[key] += 1
        return idx

    def complete(self, key: str, idx: int, payload) -> list:
        self._held[(key, idx)] = payload
        ready = []
        while (key, self._next[key]) in self._held:
            ready.append(self._held.pop((key, self._next[key])))
            self._next[key] += 1
        return ready

    @property
    def held(self) -> int:
        return len(self._held)


class ConcurrentScheduler(AdaptiveScheduler):
    """Adaptive scheduler with up to ``window`` requests in flight.

    ``window=1`` degenerates to the serial scheduler (same stages, same
    results, one extra thread hop).  Decisions, cold tuning, and
    retirement all run on the coordinating thread; only the execute
    stage — warmup, dispatch, block, D2H read-back — runs on pool
    workers, so all scheduler state mutation stays single-threaded."""

    def __init__(self, model, *, window: int = 4,
                 workers: Optional[int] = None,
                 capacity: Optional[float] = None,
                 load_aware: bool = True, **kwargs):
        # default drift detector: same thresholds as the serial
        # scheduler's, plus a load discount — samples retired at high
        # window occupancy carry residual contention noise the
        # normalization can't fully cancel, and at 10^5-request scale
        # that noise WILL eventually line up into a spurious window.
        # Callers passing their own detector keep full control.
        if kwargs.get("drift") is None:
            kwargs["drift"] = DriftDetector(load_discount=0.5)
        super().__init__(model, **kwargs)
        assert window >= 1, window
        self.window = window
        self.workers = workers if workers is not None else window
        self.pool = WindowedPool(self.workers, window, name="serve-engine")
        self.ctx_pool = ContextPool()
        self.retirer = OrderedRetirer()
        # load-aware drift: ``capacity`` is the host's measured
        # N-thread kernel-scaling ceiling (see
        # core.streams.parallel_capacity).  None → calibrated by a
        # one-off probe at ``run()`` entry, while the pool is idle.
        # ``load_aware=False`` reverts to raw-wall-time drift (the
        # pre-tenancy behavior, kept for A/B measurement).
        self.load_aware = load_aware
        self._capacity = capacity
        # drift-triggered refinements queue here and re-profile at the
        # next pool-quiesce point (the runner is held un-released until
        # then): profiling on a busy pool would write contention-skewed
        # measured speedups into the cache — the exact poisoning the
        # load-aware drift signal exists to prevent
        self._deferred_refinements: list = []

    @property
    def parallel_capacity(self) -> float:
        """The calibrated thread-scaling ceiling the contention factor
        divides by; probed once on first use when not injected."""
        if self._capacity is None:
            self._capacity = max(1.0, probe_host_capacity(self.workers))
        return self._capacity

    # -- pooled runners -------------------------------------------------------

    def _make_runner(self, req: WorkloadRequest) -> StreamedRunner:
        wl = get_workload(req.workload)
        ctx = self.ctx_pool.lease(wl, req.chunked, req.shared)
        return StreamedRunner(wl, req.chunked, req.shared,
                              backend=self.backend_name, ctx=ctx)

    def _release_runner(self, runner: StreamedRunner) -> None:
        self.ctx_pool.release(runner.wl.name, runner.ctx)

    # -- load-aware drift -----------------------------------------------------

    def _load_factor(self, pending: PendingRequest) -> float:
        """Occupancy over capacity: a request that shared the window
        with others has its ``measured_s`` deflated back to an isolated-
        run estimate before drift detection sees it.  An uncontended
        request (``inflight == 1``) never pays the calibration probe."""
        if not self.load_aware or pending.inflight <= 1:
            return 1.0
        return contention_factor(pending.inflight, self.parallel_capacity,
                                 self.workers)

    def _refine(self, pending, ctx, key, entry) -> None:
        """Defer the re-profiling to the next quiesce point; the
        triggering request's runner is kept leased until then so the
        refiner measures this request's own buffers, not a recycled
        context's."""
        pending.defer_release = True
        self._deferred_refinements.append((pending, ctx, key, entry))

    def _flush_refinements(self) -> None:
        """Run queued refinements on the now-idle pool (callers drain
        first), then release the held runners."""
        while self._deferred_refinements:
            pending, ctx, key, entry = self._deferred_refinements.pop(0)
            try:
                super()._refine(pending, ctx, key, entry)
            finally:
                self._release_runner(pending.runner)

    # -- the overlapped serving loop ------------------------------------------

    def run(self, max_requests: Optional[int] = None) -> list[RequestResult]:
        """Drain the queue with up to ``window`` requests in flight;
        returns results in decision (queue-policy) order."""
        # the coordinator contends for the GIL with busy workers; at the
        # default 5 ms switch interval a retire-and-refill cycle can
        # stall long enough to starve the pool, so run with a tighter
        # interval (restored on exit) — the same knob threaded Python
        # servers tune
        prev_switch = sys.getswitchinterval()
        sys.setswitchinterval(min(prev_switch, 1e-3))
        try:
            return self._run(max_requests)
        finally:
            sys.setswitchinterval(prev_switch)

    def _retire_completed(self, done, inflight: dict,
                          results: dict) -> Optional[BaseException]:
        """Retire a set of completed futures, flushing each touched
        bucket's contiguous dispatch-order run.  A future that raised
        still advances its bucket (a poisoned slot would hold every
        later completion of that bucket forever) and releases its
        context before the error is reported; the first error seen is
        returned rather than raised so the caller can drain the rest."""
        error: Optional[BaseException] = None
        for fut in done:
            p = inflight.pop(fut)
            try:
                payload = (p, *fut.result())
            except BaseException as e:
                self._release_runner(p.runner)
                payload = None
                if error is None:
                    error = e
            for flushed in self.retirer.complete(p.key, p.bucket_idx,
                                                 payload):
                if flushed is None:          # the failed slot itself
                    continue
                rp, routs, rmeasured = flushed
                results[rp.order] = self._retire(rp, routs, rmeasured)
                # a retire that triggered a refinement keeps its runner
                # leased until the deferred re-profiling has run
                if not rp.defer_release:
                    self._release_runner(rp.runner)
        return error

    def _drain(self, inflight: dict,
               results: dict) -> Optional[BaseException]:
        """Retire everything in flight; returns the first error seen."""
        error = None
        while inflight:
            done, _ = wait(inflight, return_when=FIRST_COMPLETED)
            error = self._retire_completed(done, inflight,
                                           results) or error
        return error

    def _run(self, max_requests: Optional[int]) -> list[RequestResult]:
        results: dict[int, RequestResult] = {}
        inflight: dict = {}                  # future -> PendingRequest
        decided = 0

        # calibrate the contention ceiling NOW, while nothing is in
        # flight: a lazy probe at the first contended retire would time
        # itself against the engine's own busy workers and cache a
        # permanently understated capacity (overstated load factors,
        # masked real drift)
        if self.load_aware and self.window > 1 and self._capacity is None:
            _ = self.parallel_capacity

        def budget_left() -> bool:
            return max_requests is None or decided < max_requests

        def check(error: Optional[BaseException]) -> None:
            if error is not None:
                # finish the survivors cleanly, then surface the failure;
                # queued refinements are abandoned (their runners still
                # go back to the pool), not profiled mid-error
                self._drain(inflight, results)
                for p, *_ in self._deferred_refinements:
                    self._release_runner(p.runner)
                self._deferred_refinements.clear()
                raise error

        while (self.queue and budget_left()) or inflight:
            # drift refinements queued by the last retire wave run FIRST,
            # on a drained pool, so (a) their re-profiling is measured
            # idle and (b) the decisions below see the refreshed cache
            # entry — the same visibility inline refinement had
            if self._deferred_refinements:
                check(self._drain(inflight, results))
                self._flush_refinements()
            # decide: fill the free window slots in queue-policy order
            batch: list[PendingRequest] = []
            while (self.queue and budget_left()
                   and len(inflight) + len(batch) < self.window):
                try:
                    req = self.queue.pop()
                except IndexError:
                    break   # deadline policy shed everything that was left
                batch.append(self._decide(req))
                decided += 1
            # batched cold path: one model search for every cold bucket
            # in this fill, measured on a quiesced pool — profiling
            # (cold features, single-stream anchors) on a busy pool
            # would persist contention-skewed numbers into the tuning
            # cache and the prediction anchor
            colds = [p for p in batch if p.entry is None]
            anchors = [p for p in batch if p.needs_anchor]
            if colds or anchors:
                check(self._drain(inflight, results))
            for p in anchors:
                self._measure_anchor(p)
            if len(colds) == 1:
                self._tune_cold(colds[0])
            elif colds:
                self._tune_cold_batch(colds)
            # dispatch: stamp each request's window occupancy — the
            # load-aware drift signal's numerator.  The whole wave is in
            # flight together (submits are microseconds, executions are
            # milliseconds), so every member gets the post-dispatch
            # occupancy; stamping len(inflight)+1 per submit would leave
            # the wave's FIRST request marked uncontended and its
            # contention-inflated wall time reading as drift
            occupancy = len(inflight) + len(batch)
            for p in batch:
                p.bucket_idx = self.retirer.issue(p.key)
                p.inflight = occupancy
                inflight[self.pool.submit(self._execute, p)] = p
            self._m_inflight.set(occupancy)
            if not inflight:
                continue
            # retire whatever completed first (out of order)
            done, _ = wait(inflight, return_when=FIRST_COMPLETED)
            check(self._retire_completed(done, inflight, results))

        self._flush_refinements()          # pool is idle: nothing in flight
        self._m_inflight.set(0)
        assert self.retirer.held == 0, "completions left unretired"
        assert not inflight, "futures left in flight"
        self.stats["ctx_reuses"] = self.ctx_pool.reuses
        return [results[i] for i in sorted(results)]

    def step(self) -> RequestResult:
        (result,) = self.run(max_requests=1)
        return result

    def close(self) -> None:
        """Worker-pool shutdown + telemetry flush/fsync/close."""
        self.pool.shutdown()
        super().close()

    def shutdown(self) -> None:
        self.close()
