"""End-to-end training driver.

Wires every subsystem together: model zoo -> sharded params/optimizer ->
streamed (microbatched) train step -> prefetching data pipeline -> atomic
checkpointing with auto-resume -> straggler watchdog.  The stream
configuration (#partitions x #microbatches) either comes from the CLI or
from the learned performance model (--autotune), closing the paper's loop
at the training-system level.

CPU-sized by default (reduced configs); the same driver lowers the full
configs under the production mesh via --mesh pod (see launch/dryrun.py for
the no-allocation variant).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --reduced \
      --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import get_arch, list_archs
from repro.core.stream_config import StreamConfig
from repro.core.streams import streamify_train_step
from repro.data.pipeline import DataConfig, PrefetchFeeder, SyntheticLM
from repro.models.model_zoo import Model
from repro.models.transformer import RunConfig
from repro.optim import optimizer as opt_lib


class StragglerWatchdog:
    """Detects stuck steps (dead/slow node analogue).  If a step exceeds
    `factor` x the rolling median it is logged; if it exceeds `timeout_s`
    the registered recovery callback fires (checkpoint-restore / remesh in
    a real deployment; here: logged + counted so tests can assert)."""

    def __init__(self, factor: float = 5.0, timeout_s: float = 300.0):
        self.factor = factor
        self.timeout_s = timeout_s
        self.history: list[float] = []
        self.flagged: list[int] = []

    def observe(self, step: int, dt: float) -> bool:
        self.history.append(dt)
        med = float(np.median(self.history[-50:]))
        slow = len(self.history) > 5 and (
            dt > self.factor * med or dt > self.timeout_s)
        if slow:
            self.flagged.append(step)
        return slow


@dataclasses.dataclass
class TrainLoopResult:
    steps_run: int
    final_loss: float
    losses: list
    resumed_from: Optional[int]
    straggler_steps: list


def train_loop(
    arch: str,
    *,
    steps: int = 50,
    batch: int = 8,
    seq: int = 64,
    microbatches: int = 1,
    reduced: bool = True,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 20,
    lr: float = 1e-3,
    seed: int = 0,
    prefetch: int = 2,
    verbose: bool = True,
) -> TrainLoopResult:
    model = Model(
        get_arch(arch).reduced() if reduced else get_arch(arch),
        RunConfig())
    cfg = model.cfg

    params, _ = model.init(jax.random.key(seed))
    ocfg = opt_lib.AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                               total_steps=steps)
    opt_state = opt_lib.init_state(params, ocfg)

    grad_fn = streamify_train_step(
        lambda p, b: model.loss(p, b), StreamConfig(1, microbatches),
        unroll=False)

    @jax.jit
    def train_step(params, opt_state, batch):
        loss, metrics, grads = grad_fn(params, batch)
        params, opt_state, om = opt_lib.apply_updates(
            params, grads, opt_state, ocfg)
        return params, opt_state, loss, om

    # ---- fault tolerance: auto-resume --------------------------------------
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    start_step, resumed_from = 0, None
    if ckpt is not None:
        latest, tree = ckpt.restore()
        if tree is not None:
            params = jax.tree.map(jnp.asarray, tree["params"])
            opt_state = jax.tree.map(jnp.asarray, tree["opt"])
            start_step = int(tree["meta"]["step"]) + 1
            resumed_from = latest
            if verbose:
                print(f"resumed from checkpoint step {latest}")

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch,
        seed=seed, frontend_dim=cfg.frontend_dim if cfg.frontend else 0))
    feeder = PrefetchFeeder(data, depth=prefetch, start_step=start_step)
    watchdog = StragglerWatchdog()

    losses: list[float] = []
    try:
        for step in range(start_step, steps):
            got_step, dev_batch = feeder.next()
            assert got_step == step
            t0 = time.perf_counter()
            params, opt_state, loss, om = train_step(
                params, opt_state, dev_batch)
            loss = float(loss)
            dt = time.perf_counter() - t0
            watchdog.observe(step, dt)
            losses.append(loss)
            if verbose and (step % 10 == 0 or step == steps - 1):
                print(f"step {step:4d} loss {loss:8.4f} "
                      f"gnorm {float(om['grad_norm']):7.3f} {dt*1e3:7.1f}ms")
            if ckpt is not None and (step + 1) % ckpt_every == 0:
                ckpt.save(step, {"params": params, "opt": opt_state,
                                 "meta": {"step": step}})
    finally:
        feeder.stop()
        if ckpt is not None:
            ckpt.wait()

    return TrainLoopResult(
        steps_run=len(losses), final_loss=losses[-1] if losses else float("nan"),
        losses=losses, resumed_from=resumed_from,
        straggler_steps=watchdog.flagged)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config — pod-scale memory!")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()
    res = train_loop(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        microbatches=args.microbatches, reduced=not args.full,
        ckpt_dir=args.ckpt_dir, lr=args.lr)
    print(f"done: {res.steps_run} steps, final loss {res.final_loss:.4f}")


if __name__ == "__main__":
    main()
