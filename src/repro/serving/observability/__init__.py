"""End-to-end serving observability: span tracing, a metrics registry,
and hot-path profiling.

Three planes, one clock:

  * :mod:`~repro.serving.observability.tracing` — per-request nested
    spans (``decide`` → ``tune`` → ``dispatch`` → ``retire`` →
    ``refine``) stamped from the owning scheduler's injected clock, so
    the virtual-clock trace harness and the real concurrent engine
    share one instrumentation path; exported as JSONL or Chrome
    trace-event JSON (Perfetto-loadable).
  * :mod:`~repro.serving.observability.metrics` — process-wide named
    counters / gauges / histograms with deterministic ``snapshot()``
    and a Prometheus text exporter.
  * :mod:`~repro.serving.observability.profiling` — opt-in tracemalloc
    allocation profiling plus per-stage wall/CPU aggregation; feeds
    ``benchmarks/run.py --serve-real-trace`` → ``BENCH_overhead.json``.

Everything defaults off: the schedulers ship with :data:`NULL_TRACER` /
:data:`NULL_METRICS`, whose hot-path operations are shared no-op
singletons.
"""
from repro.serving.observability.metrics import (DEFAULT_BUCKETS,
                                                 Counter, Gauge,
                                                 Histogram,
                                                 MetricsRegistry,
                                                 NULL_METRICS,
                                                 NullMetrics)
from repro.serving.observability.profiling import (AllocationProfiler,
                                                   HotPathProfiler,
                                                   aggregate_stage_times)
from repro.serving.observability.tracing import (NULL_TRACER, STAGES,
                                                 NullTracer, SpanRecord,
                                                 Tracer, stage_of)

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "SpanRecord", "STAGES",
    "stage_of",
    "MetricsRegistry", "NullMetrics", "NULL_METRICS",
    "Counter", "Gauge", "Histogram", "DEFAULT_BUCKETS",
    "AllocationProfiler", "HotPathProfiler", "aggregate_stage_times",
]
