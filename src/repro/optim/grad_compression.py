"""Int8 gradient compression with error feedback, for the DP reduce.

Used inside shard_map over the data axes: each shard quantizes its local
gradient to int8 with a per-tensor scale, psums the int8 payload (8x less
ICI traffic than f32 / 4x less than bf16), dequantizes, and keeps the
quantization residual as error feedback added to the next step's gradient
(Seide et al. 1-bit-SGD style convergence fix).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_tree(grads, error, *, mesh, dp_axes: tuple):
    """All-reduce `grads` over dp_axes with int8 compression + error
    feedback.  Returns (reduced_grads, new_error).  grads/error are local
    (unreduced) pytrees living inside a shard_map region — this helper is
    meant to be called from an explicitly-partitioned train step; see
    tests/test_grad_compression.py for the usage pattern."""

    def one(g, e):
        g = g.astype(jnp.float32) + e
        # agree on ONE scale across shards first (int8 payloads with
        # per-shard scales cannot be summed), then quantize and psum int32
        local_scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
        scale = jax.lax.pmax(local_scale, dp_axes)
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        summed = jax.lax.psum(q.astype(jnp.int32), dp_axes)
        n = 1
        for a in dp_axes:
            n *= mesh.shape[a]
        reduced = summed.astype(jnp.float32) * scale / n
        new_e = g - q.astype(jnp.float32) * scale
        return reduced, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))
