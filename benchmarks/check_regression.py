"""Bench-regression gate: compare a freshly produced serving benchmark
JSON against the committed baseline and fail CI on a real regression.

    python benchmarks/check_regression.py FRESH BASELINE [--tolerance 0.25]

Works on all three benchmark artifacts:

  BENCH_serving.json  (``--serve-concurrent``)  gated on
      ``capacity_fraction`` — the engine's speedup normalized by the SAME
      run's measured host parallel-capacity ceiling.  The raw ceiling on
      the shared 2-vCPU CI class drifts ~1.3-2.3x with neighbor load
      (ROADMAP), so raw throughput/speedup would flag the *host*, not the
      code; the fraction cancels the drift.
  BENCH_oracle.json   (``--serve-oracle``)      gated on
      ``mean_regret`` — achieved/oracle runtime ratio, already a ratio of
      two measurements taken on the same box under the same load regime.
  BENCH_model.json    (``--model-eval``)        gated on
      ``model_frac_of_oracle`` (LOO-CV achieved/oracle speedup of the
      trained model) and ``model_vs_heuristic`` (trained model vs the
      zero-training stand-in on the same corpus) — both ratios of
      measurements from one profiled grid, so host drift cancels.

A metric regresses when ``fresh < baseline * (1 - tolerance)``.  The
default 25% tolerance is deliberately loose for the same reason the
metrics are ratios: this gate exists to catch code-level regressions
(a scheduling bug halving overlap, a refinement loop converging to junk
configs), not to re-measure the neighbors.  Improvements are reported
but never fail.  Missing metrics fail loudly — a silently skipped gate
is worse than a red one.
"""
from __future__ import annotations

import argparse
import json
import sys

# metric name -> higher is better (all current metrics are ratios where
# bigger means healthier; extend here if a lower-is-better metric lands)
GATED_METRICS = {
    "capacity_fraction": "engine speedup / host parallel-capacity ceiling",
    "mean_regret": "steady-state achieved/oracle runtime ratio",
    "model_frac_of_oracle": "LOO-CV achieved/oracle speedup of the "
                            "trained model",
    "model_vs_heuristic": "trained-model / heuristic achieved speedup "
                          "on the same corpus",
}

# context printed next to the verdict but never gated (absolute numbers
# that legitimately drift with the shared host)
INFO_METRICS = ("speedup", "parallel_capacity", "wall_s")


def gate(fresh: dict, baseline: dict, tolerance: float) -> list[str]:
    """Returns a list of failure messages (empty == gate passes)."""
    shared = [m for m in GATED_METRICS if baseline.get(m) is not None]
    if not shared:
        return [f"baseline has none of the gated metrics "
                f"{sorted(GATED_METRICS)} — wrong file?"]
    failures = []
    for metric in shared:
        base = float(baseline[metric])
        if fresh.get(metric) is None:     # absent OR null (e.g. a trace
            # too short to serve every tenant leaves regret undefined)
            failures.append(f"{metric}: missing from fresh results "
                            f"(baseline {base:.3f})")
            continue
        got = float(fresh[metric])
        floor = base * (1.0 - tolerance)
        verdict = "OK" if got >= floor else "REGRESSION"
        print(f"  {metric:20s} fresh={got:7.3f}  baseline={base:7.3f}  "
              f"floor={floor:7.3f}  {verdict}   ({GATED_METRICS[metric]})")
        if got < floor:
            failures.append(
                f"{metric}: {got:.3f} < {floor:.3f} "
                f"(baseline {base:.3f} - {tolerance:.0%})")
    for metric in INFO_METRICS:
        if metric in fresh and metric in baseline:
            print(f"  {metric:20s} fresh={float(fresh[metric]):7.3f}  "
                  f"baseline={float(baseline[metric]):7.3f}  (info only)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", help="freshly produced benchmark JSON")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional drop below baseline "
                         "(default 0.25)")
    args = ap.parse_args()

    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    print(f"bench-regression gate: {args.fresh} vs {args.baseline} "
          f"(tolerance {args.tolerance:.0%})")
    failures = gate(fresh, baseline, args.tolerance)
    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    print("gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
