"""Serving drivers.

Two entry points share this module:

  * the batched LM driver (``serve``): prefill + decode loop with a
    KV/state cache; requests are batched (continuous-batching-lite:
    fixed batch slots, each slot holds one sequence; finished slots are
    refilled from the queue), the cache is pre-allocated at max_seq, and
    the decode step is the same ``serve_step`` the dry-run lowers at pod
    scale.  CPU-sized by default (reduced configs).

  * the adaptive streamed-workload driver (``adaptive_serve``,
    ``--adaptive``): drains a mixed multi-tenant trace through
    :class:`repro.serving.AdaptiveScheduler` — per-request model-predicted
    configs, tuning-cache warm hits, JSONL telemetry, and drift-triggered
    refinement.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch, list_archs
from repro.models.model_zoo import Model
from repro.models.transformer import RunConfig


@dataclasses.dataclass
class ServeResult:
    n_requests: int
    tokens_generated: int
    wall_s: float
    tokens_per_s: float
    outputs: list


def serve(
    arch: str,
    *,
    n_requests: int = 8,
    batch_slots: int = 4,
    prompt_len: int = 16,
    gen_len: int = 16,
    reduced: bool = True,
    seed: int = 0,
    greedy: bool = True,
    verbose: bool = True,
) -> ServeResult:
    model = Model(
        get_arch(arch).reduced() if reduced else get_arch(arch),
        RunConfig())
    cfg = model.cfg
    params, _ = model.init(jax.random.key(seed))
    max_seq = prompt_len + gen_len

    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (n_requests, prompt_len)).astype(np.int32)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    def make_batch(tokens):
        b = {"tokens": jnp.asarray(tokens)}
        if cfg.frontend:
            b["embeds"] = jnp.zeros(
                (tokens.shape[0], tokens.shape[1], cfg.frontend_dim),
                jnp.float32)
        return b

    outputs = []
    t0 = time.perf_counter()
    total_tokens = 0
    for start in range(0, n_requests, batch_slots):
        chunk = prompts[start:start + batch_slots]
        B = chunk.shape[0]
        logits, cache = prefill(params, make_batch(chunk))
        # grow cache to max_seq (attention k/v only)
        def grow(path_leaf):
            return path_leaf
        grown = {}
        for key, val in cache.items():
            if isinstance(val, dict) and "k" in val:
                grown[key] = {
                    kk: jnp.pad(vv, ((0, 0), (0, 0),
                                     (0, max_seq - prompt_len),
                                     (0, 0), (0, 0)))
                    for kk, vv in val.items()}
            else:
                grown[key] = val
        cache = grown
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        gen = [toks]
        for i in range(gen_len - 1):
            t = jnp.int32(prompt_len + i)
            logits, cache = decode(params, make_batch(toks[:, None]),
                                   cache, t)
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            gen.append(toks)
        seqs = np.stack([np.asarray(g) for g in gen], axis=1)
        outputs.extend(list(seqs))
        total_tokens += B * gen_len
        if verbose:
            print(f"batch {start//batch_slots}: {B} requests, "
                  f"{B * gen_len} tokens")
    wall = time.perf_counter() - t0
    return ServeResult(
        n_requests=n_requests, tokens_generated=total_tokens, wall_s=wall,
        tokens_per_s=total_tokens / wall, outputs=outputs)


DEFAULT_ADAPTIVE_WORKLOADS = ("vecadd", "dotprod", "mvmult")


def resolve_serving_model(spec: str = "latest", model_dir=None, *,
                          bootstrap: bool = True, verbose: bool = True,
                          metrics=None):
    """Resolve ``--model`` to ``(model, info)``.

    ``spec`` is ``"latest"``, an artifact id, an artifact directory
    path, or ``"heuristic"`` — the explicit opt-in for the zero-training
    stand-in.  The default path serves from a registry-loaded trained
    artifact; when ``latest`` resolves to an empty registry, a minimal
    artifact is bootstrap-trained and published first (one-off; the
    profile cache makes repeats cheap).  ``info["artifact_id"]`` doubles
    as the scheduler's ``model_tag`` so tuning-cache entries are keyed
    by model version and a hot-swapped model never serves stale picks.
    ``metrics`` (a MetricsRegistry) makes registry fallbacks — e.g. a
    dangling ``latest`` pointer resolving to the newest surviving
    version — countable instead of silent.
    """
    from repro.core.modeling import OverlapHeuristicModel
    from repro.core.modeling.registry import ModelRegistry

    if spec == "heuristic":
        return OverlapHeuristicModel(), {
            "spec": spec, "kind": "heuristic", "artifact_id": "heuristic"}
    registry = ModelRegistry(model_dir, metrics=metrics)
    try:
        model, manifest = registry.load(spec)
    except FileNotFoundError:
        if spec != "latest" or not bootstrap:
            raise
        from repro.launch.train_model import bootstrap_artifact
        artifact_id = bootstrap_artifact(registry, verbose=verbose)
        model, manifest = registry.load(artifact_id)
    info = {"spec": spec, "kind": manifest["kind"],
            "artifact_id": manifest["artifact_id"],
            "corpus_fingerprint": manifest.get("corpus_fingerprint"),
            "cv_frac_of_oracle": (manifest.get("cv") or {}).get(
                "frac_of_oracle")}
    if verbose:
        print(f"serving model: {info['artifact_id']} "
              f"(kind={info['kind']}, registry={registry.root})",
              file=sys.stderr, flush=True)
    return model, info


def adaptive_serve(
    workloads: Sequence[str] = DEFAULT_ADAPTIVE_WORKLOADS,
    *,
    n_requests: int = 10,
    backend: str = "host-sync",
    policy: str = "fifo",
    slo_ms: Optional[float] = None,
    telemetry_path: Optional[str] = None,
    cache_path: Optional[str] = None,
    drift_threshold: float = 4.0,
    window: int = 1,
    workers: Optional[int] = None,
    tenants: int = 0,
    model: str = "latest",
    model_dir=None,
    seed: int = 0,
    verbose: bool = True,
    trace_out: Optional[str] = None,
    metrics_out: Optional[str] = None,
    resilience: bool = False,
    watchdog_ms: Optional[float] = None,
    fault_plan: Optional[str] = None,
) -> dict:
    """Serve ``n_requests`` of a mixed multi-tenant trace adaptively.

    ``window > 1`` serves through the concurrent engine with that many
    requests in flight; its drift signal is load-aware (measured wall
    time is normalized by window occupancy over host capacity before
    error computation), so thresholds need no loosening for contention.
    ``slo_ms`` stamps every request with a deadline that many
    milliseconds after its arrival; under ``policy="deadline"`` the
    queue serves earliest-deadline-first and sheds already-expired work
    (reported as ``shed`` in the summary) instead of burning capacity
    on guaranteed misses.  ``tenants > 0`` names that many tenants AND
    isolates them: each gets
    its own tuning-cache namespace, drift windows, and (on first refit)
    a private model fork; ``tenants=0`` keeps the legacy two-tenant
    shared-state trace.  ``model`` selects the predictor: the default
    ``"latest"`` serves from the registry's pinned trained artifact
    (bootstrap-training one if the registry is empty); ``"heuristic"``
    opts into the zero-training stand-in.  Returns the telemetry summary
    dict (requests, hit rate, refinements, per-tenant breakdown, mean
    prediction error); the per-request JSONL stream lands at
    ``telemetry_path`` when given, and new tuning-cache entries persist
    to ``cache_path``.

    ``trace_out`` switches span tracing on and exports the run as Chrome
    trace-event JSON (load it in https://ui.perfetto.dev); a sibling
    ``.jsonl`` with the raw spans lands next to it.  ``metrics_out``
    switches the metrics registry on and saves its snapshot there;
    either flag also adds a ``metrics`` block to the returned summary.

    ``resilience=True`` (or a ``watchdog_ms`` / ``fault_plan``) arms the
    fault-tolerance layer (README "Resilience"): deadline-aware retries,
    the per-(tenant, stage) circuit breaker over the degradation ladder,
    an execution watchdog, and individual request failure instead of
    scheduler crashes — including falling back to the heuristic model
    when the registry itself cannot be loaded.  ``fault_plan`` names a
    :class:`~repro.serving.FaultPlan` JSON for deterministic injection.
    """
    import warnings

    from repro.core.autotuner import TuningCache
    from repro.core.modeling import OverlapHeuristicModel
    from repro.serving import (AdaptiveScheduler, ConcurrentScheduler,
                               DriftDetector, FaultPlan, MetricsRegistry,
                               ResiliencePolicy, TelemetryLog, Tracer,
                               make_trace)

    faults = FaultPlan.load(fault_plan) if fault_plan else None
    policy_obj = None
    if resilience or watchdog_ms is not None or faults is not None:
        policy_obj = ResiliencePolicy(
            watchdog_s=watchdog_ms / 1e3 if watchdog_ms else None)

    tracer = Tracer() if trace_out else None
    metrics = MetricsRegistry() if (metrics_out or trace_out) else None
    try:
        if faults is not None and faults.enabled:
            faults.bind(metrics=metrics)
            faults.fire("registry.load")
        serving_model, model_info = resolve_serving_model(
            model, model_dir, verbose=verbose, metrics=metrics)
    except Exception as e:  # noqa: BLE001 — top ladder rung
        if policy_obj is None:
            raise
        # registry down ==> serve on the zero-training heuristic rather
        # than refuse traffic (the top rung of the degradation ladder)
        warnings.warn(f"serving model unavailable ({type(e).__name__}: "
                      f"{e}); falling back to the heuristic model")
        if metrics is not None:
            metrics.counter("serving.faults.degraded").inc()
        serving_model = OverlapHeuristicModel()
        model_info = {"spec": model, "kind": "heuristic",
                      "artifact_id": "heuristic-fallback"}
    occurrences = -(-n_requests // len(workloads))  # ceil
    trace = make_trace(list(workloads), occurrences=occurrences,
                       tenants=tenants if tenants > 0
                       else ("tenant-a", "tenant-b"),
                       seed=seed)[:n_requests]
    common = dict(
        backend=backend, policy=policy,
        cache=TuningCache(cache_path),
        telemetry=TelemetryLog(telemetry_path),
        drift=DriftDetector(threshold=drift_threshold),
        isolate_tenants=tenants > 0,
        model_tag=model_info["artifact_id"],
        keep_outputs=False,
        tracer=tracer, metrics=metrics,
        faults=faults, resilience=policy_obj)
    if window > 1:
        sched = ConcurrentScheduler(serving_model,
                                    window=window, workers=workers,
                                    **common)
    else:
        sched = AdaptiveScheduler(serving_model, **common)
    # context-managed: telemetry is flushed/fsynced/closed even if the
    # trace dies mid-flight, so artifact uploads never see a truncated
    # last line
    with sched:
        sched.submit_all(trace)
        if slo_ms is not None:
            # arrival_s was stamped at submit; deadlines are absolute on
            # the scheduler's clock
            for req in trace:
                req.deadline_s = req.arrival_s + slo_ms / 1e3
        t0 = time.perf_counter()
        results = sched.run()
        wall = time.perf_counter() - t0
        if verbose:
            # progress goes to stderr so `--adaptive > summary.json`
            # stays valid JSON
            for r in results:
                if r.config is None or r.measured_s is None:
                    print(f"  #{r.sample.seq:<3d} {r.request.tenant:10s} "
                          f"{r.request.workload:12s} {r.status}: "
                          f"{r.error}", file=sys.stderr)
                    continue
                print(f"  #{r.sample.seq:<3d} {r.request.tenant:10s} "
                      f"{r.request.workload:12s} "
                      f"{r.config.partitions}x{r.config.tasks} "
                      f"{'hit ' if r.cache_hit else 'cold'} "
                      f"measured={r.measured_s*1e6:8.0f}us"
                      + (f" predicted={r.predicted_s*1e6:8.0f}us"
                         if r.predicted_s else ""), file=sys.stderr)
        summary = sched.telemetry.summary()
        summary["wall_s"] = wall
        summary["backend"] = backend
        summary["policy"] = policy
        summary["model"] = model_info
        summary["window"] = window
        summary["isolate_tenants"] = tenants > 0
        summary["throughput_rps"] = n_requests / max(wall, 1e-12)
        summary["slo_ms"] = slo_ms
        summary["shed"] = len(sched.queue.shed)
        summary["resilience"] = policy_obj is not None
        if faults is not None:
            summary["faults_injected"] = faults.fired
        if cache_path:
            sched.cache.save()
    if metrics is not None:
        snap = metrics.snapshot()
        # the compact dashboard block: single-valued families inline
        summary["metrics"] = {
            name: (fam["values"][0]["value"]
                   if len(fam["values"]) == 1
                   and not fam["values"][0]["labels"] else fam)
            for name, fam in snap.items()}
        if metrics_out:
            metrics.save(metrics_out)
            if verbose:
                print(f"metrics snapshot -> {metrics_out}",
                      file=sys.stderr)
    if tracer is not None and trace_out:
        n = tracer.export_chrome(trace_out)
        stem = trace_out[:-5] if trace_out.endswith(".json") else trace_out
        tracer.export_jsonl(stem + ".jsonl")
        if verbose:
            print(f"chrome trace ({n} spans) -> {trace_out} "
                  f"(+ {stem}.jsonl)", file=sys.stderr)
    return summary


def fleet_serve(
    workloads: Sequence[str] = DEFAULT_ADAPTIVE_WORKLOADS,
    *,
    n_requests: int = 16,
    worker_procs: int = 2,
    window: int = 2,
    backend: str = "host-sync",
    policy: str = "fifo",
    tenants: int = 8,
    model: str = "latest",
    model_dir=None,
    telemetry_path: Optional[str] = None,
    cache_path: Optional[str] = None,
    metrics_out: Optional[str] = None,
    drift_threshold: float = 4.0,
    wire: str = "auto",
    seed: int = 0,
    verbose: bool = True,
) -> dict:
    """Serve a mixed multi-tenant trace through the fleet router:
    ``worker_procs`` spawn-isolated worker processes, each running its
    own :class:`~repro.serving.ConcurrentScheduler` with ``window``
    requests in flight, tenants sharded stably across them (README
    "Fleet serving").

    The model spec is resolved *once* here (bootstrap-training if the
    registry is empty, exactly like single-process serving) and the
    pinned artifact id is what ships to the workers — N processes load
    one immutable registry version instead of racing ``latest``.
    Returns the merged fleet summary: worker-labeled telemetry
    aggregates, a ``per_worker`` breakdown, respawn/death counters, and
    (when ``metrics_out`` is set) the merged worker-labeled metrics
    snapshot, which ``repro.launch.stats --metrics`` renders unchanged.
    """
    from repro.serving import make_trace
    from repro.serving.fleet import FleetRouter, WorkerConfig

    model_obj, model_info = resolve_serving_model(
        model, model_dir, verbose=verbose)
    del model_obj                     # workers load their own copy
    spec = (model_info["artifact_id"]
            if model_info["kind"] != "heuristic" else "heuristic")
    occurrences = -(-n_requests // len(workloads))  # ceil
    trace = make_trace(list(workloads), occurrences=occurrences,
                       tenants=max(tenants, 1), seed=seed)[:n_requests]
    cfg = WorkerConfig(backend=backend, window=window, model=spec,
                       model_dir=model_dir, drift_threshold=drift_threshold,
                       cache_path=cache_path, wire=wire)
    t0 = time.perf_counter()
    with FleetRouter(worker_procs, worker=cfg, policy=policy,
                     telemetry_path=telemetry_path) as router:
        router.submit_all(trace)
        results = router.run()
        if verbose:
            for r in results:
                cfg_s = ("x".join(map(str, r["config"]))
                         if r["config"] else "-")
                meas = (f"{r['measured_s']*1e6:8.0f}us"
                        if r["measured_s"] is not None else "        -")
                print(f"  {r['sample'].get('worker', '?'):3s} "
                      f"{r['tenant']:10s} {r['workload']:12s} {cfg_s:8s} "
                      f"{'hit ' if r['cache_hit'] else 'cold'} "
                      f"measured={meas} {r['status']}", file=sys.stderr)
        wall = time.perf_counter() - t0
    summary = router.summary()
    summary["wall_s"] = wall
    summary["backend"] = backend
    summary["policy"] = policy
    summary["model"] = model_info
    summary["window"] = window
    summary["worker_procs"] = worker_procs
    summary["throughput_rps"] = len(results) / max(wall, 1e-12)
    summary["ipc"] = dict(router.last_run)
    if verbose and summary.get("ipc_overhead_fraction") is not None:
        print(f"  ipc overhead: "
              f"{summary['ipc_overhead_fraction']*100:.1f}% of run wall "
              f"({summary['result_frames']} result frames, "
              f"{summary['dispatch_frames']} dispatch frames)",
              file=sys.stderr)
    if metrics_out:
        from repro.serving.resilience import atomic_write_json
        atomic_write_json(metrics_out, router.metrics_snapshot())
        if verbose:
            print(f"merged fleet metrics -> {metrics_out}",
                  file=sys.stderr)
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(),
                    help="LM arch for the batched driver "
                         "(required unless --adaptive)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--adaptive", action="store_true",
                    help="serve a streamed-workload trace through the "
                         "adaptive scheduler instead of the LM driver")
    ap.add_argument("--workloads", default=",".join(
        DEFAULT_ADAPTIVE_WORKLOADS))
    ap.add_argument("--backend", default="host-sync")
    ap.add_argument("--policy", default="fifo",
                    choices=("fifo", "priority", "fair", "deadline"))
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="per-request SLO: deadline = arrival + this "
                         "many ms (deadline policy sheds expired work)")
    ap.add_argument("--telemetry", default=None,
                    help="append-only JSONL telemetry path")
    ap.add_argument("--tuning-cache", default=None,
                    help="persistent tuning-cache JSON path")
    ap.add_argument("--window", type=int, default=None,
                    help="in-flight request window; >1 serves through "
                         "the concurrent engine (default: 1, or 2 per "
                         "worker under --worker-procs)")
    ap.add_argument("--workers", type=int, default=None,
                    help="concurrent engine pool size (default: window)")
    ap.add_argument("--worker-procs", type=int, default=0,
                    help="serve through the fleet router with this many "
                         "worker PROCESSES (tenant-sharded, respawn on "
                         "death; implies --adaptive).  Each worker runs "
                         "its own concurrent engine with --window "
                         "requests in flight; 0 = single-process")
    ap.add_argument("--wire", default="auto",
                    choices=["auto", "v2", "legacy"],
                    help="fleet result wire: 'v2' batched frames of "
                         "positional rows, 'legacy' per-request payload "
                         "dicts, 'auto' = $REPRO_FLEET_WIRE or v2 "
                         "(only meaningful with --worker-procs)")
    ap.add_argument("--tenants", type=int, default=0,
                    help="serve N isolated tenants (per-tenant cache "
                         "namespace, drift windows, model fork on "
                         "refit); 0 = legacy shared-state trace")
    ap.add_argument("--model", default="latest",
                    help="'latest' (registry-pinned trained artifact, "
                         "the default), an artifact id/path, or "
                         "'heuristic' for the zero-training fallback")
    ap.add_argument("--model-dir", default=None,
                    help="model registry root (default: REPRO_MODEL_DIR "
                         "or <repo>/models)")
    ap.add_argument("--trace-out", default=None,
                    help="enable span tracing; write Chrome trace-event "
                         "JSON here (Perfetto-loadable; a .jsonl with "
                         "raw spans lands alongside)")
    ap.add_argument("--metrics-out", default=None,
                    help="enable the metrics registry; write its "
                         "snapshot JSON here (summary also gains a "
                         "'metrics' block)")
    ap.add_argument("--resilience", action="store_true",
                    help="arm the fault-tolerance layer: deadline-aware "
                         "retries, per-(tenant, stage) circuit breaker "
                         "over the degradation ladder, individual "
                         "request failure instead of scheduler crashes")
    ap.add_argument("--watchdog-ms", type=float, default=None,
                    help="execution watchdog: abandon + requeue-once a "
                         "dispatch exceeding this many ms (implies "
                         "--resilience)")
    ap.add_argument("--fault-plan", default=None,
                    help="FaultPlan JSON for deterministic fault "
                         "injection (implies --resilience; see "
                         "benchmarks/data/chaos_faults.json)")
    args = ap.parse_args()

    if args.worker_procs and args.worker_procs > 0:
        summary = fleet_serve(
            args.workloads.split(","),
            n_requests=args.requests,
            worker_procs=args.worker_procs,
            window=args.window if args.window is not None else 2,
            backend=args.backend,
            policy=args.policy,
            tenants=args.tenants if args.tenants > 0 else 8,
            model=args.model, model_dir=args.model_dir,
            telemetry_path=args.telemetry,
            cache_path=args.tuning_cache,
            metrics_out=args.metrics_out,
            wire=args.wire)
        print(json.dumps(summary, indent=2))
        return

    if args.adaptive:
        summary = adaptive_serve(
            args.workloads.split(","),
            n_requests=args.requests, backend=args.backend,
            policy=args.policy, slo_ms=args.slo_ms,
            telemetry_path=args.telemetry,
            cache_path=args.tuning_cache,
            window=args.window if args.window is not None else 1,
            workers=args.workers, tenants=args.tenants,
            model=args.model, model_dir=args.model_dir,
            trace_out=args.trace_out, metrics_out=args.metrics_out,
            resilience=args.resilience, watchdog_ms=args.watchdog_ms,
            fault_plan=args.fault_plan)
        print(json.dumps(summary, indent=2))
        return

    if not args.arch:
        ap.error("--arch is required unless --adaptive is given")
    res = serve(args.arch, n_requests=args.requests, batch_slots=args.slots,
                prompt_len=args.prompt_len, gen_len=args.gen_len)
    print(f"{res.tokens_generated} tokens in {res.wall_s:.2f}s "
          f"({res.tokens_per_s:.1f} tok/s)")


if __name__ == "__main__":
    main()
