"""The synchronous host backend — the seed executor, unchanged semantics.

Mirrors Figure 8c of the paper on a single host device:
  * the outer iteration space is split into ``tasks`` chunks;
  * each chunk's host->device transfer (``jax.device_put``) is issued
    asynchronously and overlaps the (async-dispatched) compute of earlier
    chunks — temporal sharing;
  * each chunk's kernel is dispatched as ``partitions`` sub-slices, which
    sets the kernel working-set granularity (cache blocking) and dispatch
    parallelism — the spatial-sharing analogue on a host backend.

The host loop runs ahead without bound: nothing caps how many tasks are
in flight, and each task's buffers are fresh allocations.  The pipelined
sibling (:mod:`repro.core.backends.host_pipelined`) fixes both.
"""
from __future__ import annotations

import jax

from repro.core.backends.base import ExecutionContext, StreamBackend, \
    split_arrays


class SyncHostBackend(StreamBackend):
    name = "host-sync"
    kind = "runner"

    def dispatch(self, ctx: ExecutionContext, config) -> list:
        outs = []
        for task in split_arrays(ctx.chunked, config.tasks):
            task_dev = jax.device_put(task, ctx.device)     # async H2D
            for part in split_arrays(task_dev, config.partitions):
                outs.append(ctx.jit_kernel(part, ctx.shared_dev))
        return outs
