"""One benchmark per paper table/figure, all consuming the profiled
sample cache (benchmarks/data/profile_cache.json).

  fig2   — speedup heatmap over (partitions, tasks) for two programs
  fig9   — our approach vs oracle, per program (leave-one-out CV)
  fig10  — vs fixed configurations
  fig12  — vs Liu et al. / Werkhoven et al. analytical models
  fig14  — vs the classification-based approach (prior work [16])
  table5 — alternative modeling techniques
  search — runtime overhead of feature extraction + model ranking
"""
from __future__ import annotations

import time
from collections import defaultdict

import numpy as np

from repro.core import dataset as ds
from repro.core.analytical import liu_config, probe_from_features, werkhoven_config
from repro.core.classifier import KNNClassifier
from repro.core.features import RAW_FEATURE_NAMES, config_features
from repro.core.perf_model import (ForestRegressor, KernelRidgeRBF,
                                   PerformanceModel, TreeRegressor)
from repro.core.search import search_best
from repro.core.stream_config import StreamConfig


def _geomean(xs) -> float:
    xs = np.asarray(list(xs), dtype=np.float64)
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-9)))))


def _nearest_cfg(sample: ds.Sample, cfg: StreamConfig) -> StreamConfig:
    """Snap a predicted config to the nearest profiled cell (for scoring)."""
    if cfg.as_tuple() in sample.times:
        return cfg
    cand = min(sample.times, key=lambda pt: (
        abs(np.log2(pt[0]) - np.log2(cfg.partitions))
        + abs(np.log2(pt[1]) - np.log2(cfg.tasks))))
    return StreamConfig(*cand)


def _achieved(sample: ds.Sample, cfg: StreamConfig) -> float:
    return sample.speedup(_nearest_cfg(sample, cfg))


def loo_predictions(samples, *, model_cls=PerformanceModel, epochs=600,
                    **kw):
    """Leave-one-out over programs: program -> [(sample, chosen_cfg)]."""
    programs = sorted({s.program for s in samples})
    out = {}
    for prog in programs:
        train, test = ds.loo_split(samples, prog)
        X, y = ds.training_matrix(train)
        if model_cls is PerformanceModel:
            model = model_cls.train(X, y, epochs=epochs, **kw)
        else:
            model = model_cls.train(X, y, **kw)
        picks = []
        for s in test:
            cfgs = [StreamConfig(p, t) for (p, t) in s.times]
            Xq = np.stack([np.concatenate(
                [s.features, config_features(c.partitions, c.tasks)])
                for c in cfgs])
            preds = model.predict(Xq)
            picks.append((s, cfgs[int(np.argmax(preds))]))
        out[prog] = picks
    return out


def fig2_heatmap(samples, programs=("binomial", "jacobi-1d")) -> list[str]:
    rows = []
    for prog in programs:
        best = None
        for s in samples:
            if s.program == prog:
                best = s if best is None or s.scale > best.scale else best
        if best is None:
            continue
        for (p, t), sec in sorted(best.times.items()):
            rows.append(f"fig2.{prog}@{best.scale},p={p},t={t},"
                        f"{sec*1e6:.1f},speedup={best.t_single/sec:.3f}")
    return rows


def fig9_overall(samples) -> tuple[list[str], dict]:
    preds = loo_predictions(samples)
    rows = []
    all_achieved, all_oracle = [], []
    for prog, picks in sorted(preds.items()):
        ach = [_achieved(s, c) for s, c in picks]
        orc = [s.oracle_speedup for s, _ in picks]
        all_achieved += ach
        all_oracle += orc
        rows.append(
            f"fig9.{prog},{_geomean(ach):.3f},oracle={_geomean(orc):.3f},"
            f"pct_of_oracle={100*_geomean(ach)/_geomean(orc):.1f}")
    mean_ach, mean_orc = _geomean(all_achieved), _geomean(all_oracle)
    rows.append(f"fig9.MEAN,{mean_ach:.3f},oracle={mean_orc:.3f},"
                f"pct_of_oracle={100*mean_ach/mean_orc:.1f}")
    summary = {"ours": mean_ach, "oracle": mean_orc,
               "pct": 100 * mean_ach / mean_orc,
               "per_sample": [( s.program, s.scale, _achieved(s, c),
                               s.oracle_speedup)
                              for picks in preds.values()
                              for s, c in picks]}
    return rows, summary


def fig10_fixed(samples) -> list[str]:
    # fixed config 1: hand-picked moderate config (paper: (4,16));
    # fixed config 2: best-average config over the whole corpus (paper: (17,85))
    per_cfg = defaultdict(list)
    for s in samples:
        for (p, t), sec in s.times.items():
            per_cfg[(p, t)].append(s.t_single / sec)
    common = {pt: _geomean(v) for pt, v in per_cfg.items()
              if len(v) == len(samples)}
    best_avg = max(common, key=common.get) if common else (2, 8)
    fixed = {"fixed(2,8)": StreamConfig(2, 8),
             f"fixed_bestavg{best_avg}": StreamConfig(*best_avg)}
    rows = []
    for name, cfg in fixed.items():
        achieved = [_achieved(s, cfg) for s in samples]
        rows.append(f"fig10.{name},{_geomean(achieved):.3f}")
    return rows


def fig12_analytical(samples) -> list[str]:
    rows = []
    for name, fn in (("liu", liu_config), ("werkhoven", werkhoven_config)):
        achieved = []
        for s in samples:
            probe = probe_from_features(dict(zip(RAW_FEATURE_NAMES,
                                                 s.features)))
            achieved.append(_achieved(s, fn(probe)))
        rows.append(f"fig12.{name},{_geomean(achieved):.3f}")
    return rows


def fig14_classifier(samples) -> list[str]:
    programs = sorted({s.program for s in samples})
    achieved = []
    for prog in programs:
        train, test = ds.loo_split(samples, prog)
        X = np.stack([s.features for s in train])
        labels = [s.best_config for s in train]
        clf = KNNClassifier.train(X, labels, k=3)
        for s in test:
            achieved.append(_achieved(s, clf.predict(s.features)))
    return [f"fig14.knn_classifier,{_geomean(achieved):.3f}"]


def table5_models(samples) -> list[str]:
    X, y = ds.training_matrix(samples)
    rows = []
    entries = [
        ("MLP_regression_ours", PerformanceModel, {"epochs": 600}),
        ("DCT_regression", TreeRegressor, {}),
        ("RF_regression", ForestRegressor, {}),
        ("SVR_analogue_KRR_rbf", KernelRidgeRBF, {}),
    ]
    for name, cls, kw in entries:
        t0 = time.perf_counter()
        preds = loo_predictions(samples, model_cls=cls,
                                **({"epochs": 300} if cls is PerformanceModel
                                   else {}))
        train_time = time.perf_counter() - t0
        ach = [_achieved(s, c) for picks in preds.values()
               for s, c in picks]
        # prediction latency for one full candidate ranking
        model = (cls.train(X, y, **kw) if cls is not PerformanceModel
                 else cls.train(X, y, epochs=200))
        s0 = samples[0]
        from repro.core.features import config_features
        cfgs = [StreamConfig(p, t) for p, t in s0.times]
        Xq = np.stack([np.concatenate(
            [s0.features, config_features(c.partitions, c.tasks)])
            for c in cfgs])
        t0 = time.perf_counter()
        model.predict(Xq)
        pred_ms = (time.perf_counter() - t0) * 1e3
        rows.append(f"table5.{name},{pred_ms*1e3:.0f},"
                    f"speedup={_geomean(ach):.3f},"
                    f"loo_train_s={train_time:.1f}")
    return rows


def search_overhead(samples) -> list[str]:
    X, y = ds.training_matrix(samples)
    model = PerformanceModel.train(X, y, epochs=300)
    s = samples[0]
    cfgs = [StreamConfig(p, t) for p, t in s.times]
    t0 = time.perf_counter()
    best, preds, dt = search_best(model, s.features, cfgs)
    total = time.perf_counter() - t0
    return [f"search.rank_{len(cfgs)}_configs,{total*1e6:.0f},"
            f"model_only_us={dt*1e6:.0f}"]
