"""The paper's experiment in miniature: leave-one-out autotuning across a
workload subset, reporting achieved vs oracle speedup per program
(paper Fig. 9).

    PYTHONPATH=src python examples/autotune_workloads.py
"""
import numpy as np

from repro.core import dataset as ds
from repro.core.features import config_features
from repro.core.perf_model import PerformanceModel
from repro.core.stream_config import StreamConfig

PROGRAMS = ["vecadd", "binomial", "sgemm", "jacobi-1d", "mri-q", "dotprod"]

samples = ds.generate(PROGRAMS, datasets_per_program=3, reps=2)

print(f"{'program':12s} {'achieved':>9s} {'oracle':>8s} {'% of oracle':>12s}")
total_a, total_o = [], []
for prog in PROGRAMS:
    train, test = ds.loo_split(samples, prog)
    X, y = ds.training_matrix(train)
    model = PerformanceModel.train(X, y, epochs=500)
    for s in test:
        cfgs = [StreamConfig(p, t) for (p, t) in s.times]
        Xq = np.stack([np.concatenate(
            [s.features, config_features(c.partitions, c.tasks)])
            for c in cfgs])
        pick = cfgs[int(np.argmax(model.predict(Xq)))]
        a, o = s.speedup(pick), s.oracle_speedup
        total_a.append(a)
        total_o.append(o)
        print(f"{prog+'@'+str(s.scale):18s} {a:8.2f}x {o:7.2f}x "
              f"{100*a/o:11.1f}%")

gm = lambda v: float(np.exp(np.mean(np.log(np.maximum(v, 1e-9)))))
print(f"\nGEOMEAN achieved {gm(total_a):.2f}x, oracle {gm(total_o):.2f}x "
      f"-> {100*gm(total_a)/gm(total_o):.1f}% of oracle "
      f"(paper: 93.7% XeonPhi / 97.9% GPU)")
