"""Logical-axis → mesh-axis sharding rules (MaxText-style).

Every parameter in the model zoo is born with a tuple of *logical* axis
names (e.g. ``("embed", "heads", "head_dim")``).  ``AxisRules`` maps those
names onto physical mesh axes, producing ``PartitionSpec``s for pjit and
``with_sharding_constraint`` hints for activations.  Smoke tests run with
``AxisRules.null()`` (no constraints, single device); the pod launcher uses
``AxisRules.pod()``.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Union

import jax
from jax.sharding import PartitionSpec

AxisVal = Union[None, str, tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    rules: Mapping[str, AxisVal]
    enabled: bool = True

    # -- constructors --------------------------------------------------------

    @staticmethod
    def null() -> "AxisRules":
        return AxisRules(rules={}, enabled=False)

    @staticmethod
    def pod(
        *,
        multi_pod: bool = False,
        fsdp: bool = True,
        fsdp_over_pod: bool = False,
        shard_heads: bool = True,
        shard_kv_heads: bool = True,
        seq_shard_attn: bool = False,
        tp: bool = True,
    ) -> "AxisRules":
        """Production rules for the (pod, data, model) / (data, model) mesh.

        - batch over ('pod','data'); TP dims over 'model'.
        - FSDP (ZeRO-3): the non-TP dim of every weight over 'data'
          (optionally ('pod','data') — cross-pod all-gathers, usually worse).
        - KV-cache sequence dim over 'model' (distributed flash-decode).
        - tp=False: no tensor parallelism — the 'model' axis becomes extra
          data parallelism (batch over (...,'model'), params FSDP over both
          axes).  This is the paper's #partitions knob at pod scale: small
          models are collective-crushed by 16-way TP (see §Perf).
        """
        dp: tuple[str, ...] = ("pod", "data") if multi_pod else ("data",)
        if not tp:
            dp_all = dp + ("model",)
            fsdp_axes = dp_all if fsdp else None
            return AxisRules(
                rules={
                    "batch": dp_all,
                    "seq": None,
                    "embed": fsdp_axes,
                    "embed_act": None,
                    "heads": None, "kv_heads": None, "head_dim": None,
                    "ff": None, "vocab": None,
                    "expert": None, "expert_ff": None, "expert_ff_tp": None,
                    "cache_batch": dp_all, "cache_seq": None,
                    "cache_heads": None, "layers": None,
                    "conv": None, "ssm_state": None, "inner": None,
                }
            )
        fsdp_axes = None
        if fsdp:
            fsdp_axes = dp if (fsdp_over_pod and multi_pod) else ("data",)
        return AxisRules(
            rules={
                "batch": dp,
                "seq": ("model",) if seq_shard_attn else None,
                "embed": fsdp_axes,        # FSDP dim of weights
                "embed_act": None,         # activation d_model dim
                # heads % model_size != 0 (arctic 56, musicgen 24, xlstm 4)
                # => replicate; the waste is visible in the roofline table
                "heads": ("model",) if shard_heads else None,
                "kv_heads": ("model",) if shard_kv_heads else None,
                "head_dim": None,
                "ff": ("model",),
                "vocab": ("model",),
                "expert": ("model",),      # EP
                "expert_ff": None,         # MoEConfig.sharding == "ep"
                "expert_ff_tp": ("model",),  # MoEConfig.sharding == "tp"
                "cache_batch": dp,
                "cache_seq": ("model",),   # seq-sharded KV cache
                "cache_heads": None,
                "layers": None,
                "conv": None,
                "ssm_state": None,
                "inner": ("model",),       # mamba/xlstm expanded inner dim
            }
        )

    # -- use -----------------------------------------------------------------

    def axes(self, name: Optional[str]) -> AxisVal:
        if name is None:
            return None
        if name not in self.rules:
            return None
        return self.rules[name]

    def spec(self, logical_axes: Sequence[Optional[str]]) -> PartitionSpec:
        return PartitionSpec(*(self.axes(a) for a in logical_axes))

    def constrain(self, x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
        """Annotate an activation with its sharding; no-op when disabled."""
        if not self.enabled:
            return x
        assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
        return jax.lax.with_sharding_constraint(x, self.spec(logical_axes))


def tree_specs(axes_tree, rules: AxisRules):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda axes: rules.spec(axes),
        axes_tree,
        is_leaf=lambda v: isinstance(v, tuple)
        and all(a is None or isinstance(a, str) for a in v),
    )
