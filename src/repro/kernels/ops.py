"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on a
real TPU runtime set ``REPRO_PALLAS_INTERPRET=0`` (or pass interpret=False)
to lower to Mosaic.
"""
from __future__ import annotations

import functools
import os

import jax

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas


def _default_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


@functools.partial(jax.jit, static_argnames=("causal", "q_block", "kv_block",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, q_block: int = 128,
                    kv_block: int = 128, interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return flash_attention_pallas(
        q, k, v, causal=causal, q_block=q_block, kv_block=kv_block,
        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "row_block", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-5, row_block: int = 256,
            interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return rmsnorm_pallas(x, scale, eps=eps, row_block=row_block,
                          interpret=interpret)
