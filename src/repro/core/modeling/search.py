"""Runtime configuration search (paper §3.3): rank every candidate stream
configuration with the performance model and take the top one.  One vmapped
MLP forward over the whole grid — microseconds of overhead, which is the
point: exhaustive *profiling* is hours, exhaustive *prediction* is free.

Also provides the simulated-annealing searcher the paper uses to motivate
model-based search (§2.3: SA needed 310k iterations to reach 84%).
"""
from __future__ import annotations

import math
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.modeling.perf_model import PerformanceModel
from repro.core.stream_config import StreamConfig, default_space


def search_best(
    model: PerformanceModel,
    prog_feats: np.ndarray,
    candidates: Optional[Sequence[StreamConfig]] = None,
    *,
    top_k: int = 1,
):
    """Returns (best_config(s), predicted speedups, search seconds)."""
    candidates = list(candidates or default_space())
    t0 = time.perf_counter()
    preds = model.predict_configs(prog_feats, candidates)
    dt = time.perf_counter() - t0
    # stable sort: prediction ties resolve to the earlier (cheaper)
    # candidate, so repeated searches — and tuning-cache entries written
    # from them — are deterministic for a fixed model.
    order = np.argsort(-np.asarray(preds), kind="stable")
    picks = [candidates[i] for i in order[:top_k]]
    if top_k == 1:
        return picks[0], preds, dt
    return picks, preds, dt


def search_best_batch(
    model: PerformanceModel,
    feats_matrix: np.ndarray,
    candidates: Optional[Sequence[StreamConfig]] = None,
    *,
    feasible: Optional[np.ndarray] = None,
):
    """Rank the candidate grid for ``B`` programs with ONE batched
    ``predict_configs`` call over a ``(B, F)`` feature matrix.

    ``feasible`` is an optional ``(B, C)`` bool mask; a row's infeasible
    candidates (e.g. unsplittable for that request's row count) are
    scored ``-inf``, which — with the same stable descending sort as
    :func:`search_best` — makes each row's pick identical to a serial
    ``search_best`` over that row's filtered candidate list.

    Returns ``(picks, best_preds, preds, seconds)``: per-program best
    config, its predicted speedup, the full ``(B, C)`` prediction
    matrix, and the search wall time.
    """
    candidates = list(candidates or default_space())
    F = np.atleast_2d(np.asarray(feats_matrix, dtype=np.float64))
    t0 = time.perf_counter()
    preds = np.atleast_2d(np.asarray(model.predict_configs(F, candidates)))
    dt = time.perf_counter() - t0
    scored = preds if feasible is None else np.where(feasible, preds,
                                                     -np.inf)
    order = np.argsort(-scored, axis=1, kind="stable")
    picks = [candidates[order[b, 0]] for b in range(F.shape[0])]
    best_preds = scored[np.arange(F.shape[0]), order[:, 0]]
    return picks, best_preds, preds, dt


def simulated_annealing(
    objective: Callable[[StreamConfig], float],
    *,
    max_partitions: int = 32,
    max_tasks: int = 64,
    iters: int = 100,
    seed: int = 0,
):
    """Minimize measured runtime by SA over the (p, t) lattice.  Each
    ``objective`` call is a real profiled run — this is the expensive
    alternative the paper's model-based search replaces."""
    rng = np.random.default_rng(seed)
    lp = int(math.log2(max_partitions))
    lt = int(math.log2(max_tasks))
    cur = StreamConfig(1, 1)
    cur_cost = objective(cur)
    best, best_cost = cur, cur_cost
    temp = 1.0
    for i in range(iters):
        dp = int(rng.integers(-1, 2))
        dt_ = int(rng.integers(-1, 2))
        p = 2 ** int(np.clip(math.log2(cur.partitions) + dp, 0, lp))
        t = 2 ** int(np.clip(math.log2(cur.tasks) + dt_, 0, lt))
        cand = StreamConfig(p, max(t, 1))
        cost = objective(cand)
        if cost < cur_cost or rng.random() < math.exp(
                -(cost - cur_cost) / max(temp * cur_cost, 1e-12)):
            cur, cur_cost = cand, cost
        if cost < best_cost:
            best, best_cost = cand, cost
        temp *= 0.95
    return best, best_cost
