"""The offline-trained model lifecycle in one package (paper §3).

  base        the :class:`Estimator` protocol + kind registry
  pipeline    Z-score -> correlation pruning -> PCA feature front end
  perf_model  the MLP regression model (ours), with online ``refit``
  learners    CART / random forest / RBF kernel ridge (Table 5)
  heuristic   the zero-training overlap bound (explicit fallback only)
  classifier  the classification-based prior-work baseline (§6.4)
  search      model-driven config ranking + the SA baseline (§3.3, §2.3)
  dataset     corpus profiling, the profile cache, LOO splits (§3.1.1)
  evaluate    leave-one-program-out CV scoring (§5.3.1)
  artifacts   versioned save/load: manifest.json + weights.npz, schema-
              hash guarded
  registry    artifact directory with ``latest`` pinning and hot-swap

Train at the factory (``launch/train_model.py`` publishes into the
registry), predict in production (``launch/serve.py`` loads ``latest``).
"""
from repro.core.modeling.artifacts import (SchemaMismatchError,
                                           corpus_fingerprint,
                                           feature_schema_hash,
                                           load_artifact, save_artifact)
from repro.core.modeling.base import (ESTIMATOR_KINDS, Estimator,
                                      EstimatorBase, assemble_rows,
                                      get_estimator_kind,
                                      register_estimator)
from repro.core.modeling.classifier import KNNClassifier, merge_labels
from repro.core.modeling.evaluate import (evaluate_model, geomean,
                                          loo_evaluate)
from repro.core.modeling.heuristic import OverlapHeuristicModel
from repro.core.modeling.learners import (ForestRegressor, KernelRidgeRBF,
                                          TreeRegressor)
from repro.core.modeling.perf_model import FeaturePipeline, PerformanceModel
from repro.core.modeling.registry import ModelRegistry, default_model_dir
from repro.core.modeling.search import (search_best, search_best_batch,
                                        simulated_annealing)

__all__ = [
    "Estimator", "EstimatorBase", "ESTIMATOR_KINDS", "assemble_rows",
    "register_estimator", "get_estimator_kind",
    "FeaturePipeline", "PerformanceModel",
    "TreeRegressor", "ForestRegressor", "KernelRidgeRBF",
    "OverlapHeuristicModel",
    "KNNClassifier", "merge_labels",
    "search_best", "search_best_batch", "simulated_annealing",
    "evaluate_model", "loo_evaluate", "geomean",
    "SchemaMismatchError", "save_artifact", "load_artifact",
    "feature_schema_hash", "corpus_fingerprint",
    "ModelRegistry", "default_model_dir",
]
