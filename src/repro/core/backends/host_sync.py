"""The synchronous host backend — the seed executor, unchanged semantics.

Mirrors Figure 8c of the paper on a single host device:
  * the outer iteration space is split into ``tasks`` chunks;
  * each chunk's host->device transfer (``jax.device_put``) is issued
    asynchronously and overlaps the (async-dispatched) compute of earlier
    chunks — temporal sharing;
  * each chunk's kernel is dispatched as ``partitions`` sub-slices, which
    sets the kernel working-set granularity (cache blocking) and dispatch
    parallelism — the spatial-sharing analogue on a host backend.

The host loop runs ahead without bound: nothing caps how many tasks are
in flight, and each task's buffers are fresh allocations.  The pipelined
sibling (:mod:`repro.core.backends.host_pipelined`) fixes both.
"""
from __future__ import annotations

import jax

from repro.core.backends.base import ExecutionContext, StreamBackend, \
    dispatch_plan, slice_rows


class SyncHostBackend(StreamBackend):
    name = "host-sync"
    kind = "runner"

    def dispatch(self, ctx: ExecutionContext, config) -> list:
        n_rows = next(iter(ctx.chunked.values())).shape[0]
        outs = []
        for parts in dispatch_plan(n_rows, config):
            t_lo = parts[0][0]
            task = slice_rows(ctx.chunked, t_lo, parts[-1][1])
            task_dev = jax.device_put(task, ctx.device)     # async H2D
            # partition slicing still happens on the DEVICE chunk — the
            # deliberate seed flaw the pipelined sibling fixes
            for p_lo, p_hi in parts:
                part = slice_rows(task_dev, p_lo - t_lo, p_hi - t_lo)
                outs.append(ctx.jit_kernel(part, ctx.shared_dev))
        return outs
