"""End-to-end observability: span tracing, the metrics registry, and
hot-path profiling (repro.serving.observability).

Covers the PR's acceptance bars: spans nest correctly under a virtual
clock, trace IDs survive the concurrent engine's out-of-order
retirement, two seeded replays produce byte-identical metrics
snapshots, the Chrome export is valid trace-event JSON, the disabled
path is a shared no-op singleton (zero per-call allocation), the
empty-window telemetry contract (typed raise at the primitive, None at
the aggregators), and one-clock plumbing across queue / scheduler /
refiner / tracer."""
import json

import pytest

from repro.serving import (AdaptiveScheduler, ConcurrentScheduler,
                           NULL_METRICS, NULL_TRACER, MetricsRegistry,
                           OverlapHeuristicModel, TelemetryLog, Tracer,
                           aggregate_stage_times, make_trace)
from repro.serving.clock import VirtualClock
from repro.serving.observability.metrics import (_NULL_INSTRUMENT,
                                                 Histogram)
from repro.serving.observability.tracing import _NULL_SPAN, stage_of
from repro.serving.telemetry import (EmptyWindowError, TelemetrySample,
                                     latency_stats, percentile)
from repro.serving.traces import TraceConfig, generate_trace, \
    simulate_trace


def _sched(model=None, **kw):
    kw.setdefault("telemetry", TelemetryLog())
    kw.setdefault("keep_outputs", False)
    return AdaptiveScheduler(model or OverlapHeuristicModel(), **kw)


# -- span tracing ------------------------------------------------------------


def test_spans_nest_under_virtual_clock():
    clock = VirtualClock()
    tr = Tracer(clock)
    with tr.span("retire", trace_id="r000000"):
        clock.advance(1.0)
        with tr.span("refine", trace_id="r000000", key="k"):
            clock.advance(2.0)
        clock.advance(0.5)
    inner, outer = tr.spans        # exit order: inner closes first
    assert inner.name == "refine" and outer.name == "retire"
    assert inner.parent == "retire" and inner.depth == 1
    assert outer.parent is None and outer.depth == 0
    assert inner.t_start == 1.0 and inner.t_end == 3.0
    assert outer.t_start == 0.0 and outer.t_end == 3.5
    assert inner.duration_s == pytest.approx(2.0)
    assert inner.attrs == {"key": "k"}


def test_stage_of_rollup():
    assert stage_of("tune.cold.batch") == "tune"
    assert stage_of("decide") == "decide"
    assert stage_of("custom") == "custom"


def test_aggregate_skips_nested_spans():
    tr = Tracer(VirtualClock())
    tr.record("retire", 0.0, 3.0, trace_id="a")
    tr.record("refine", 1.0, 2.0, trace_id="a")       # depth 0 by record
    with tr.span("decide"):
        with tr.span("tune.cold"):                    # depth 1: excluded
            pass
    agg = aggregate_stage_times(tr.spans)
    assert agg["retire"]["wall_s"] == pytest.approx(3.0)
    assert agg["refine"]["count"] == 1
    assert agg["tune"]["count"] == 0                  # nested, skipped
    assert agg["dispatch"] == {"wall_s": 0.0, "count": 0, "mean_s": None}


def test_trace_ids_survive_out_of_order_retirement():
    tr = Tracer()
    sched = ConcurrentScheduler(
        OverlapHeuristicModel(), window=3, tracer=tr,
        telemetry=TelemetryLog(), keep_outputs=False)
    trace = make_trace(["vecadd", "dotprod"], occurrences=3)
    with sched:
        submitted = [sched.submit(r).trace_id for r in trace]
        results = sched.run()
    assert submitted == [f"r{i:06d}" for i in range(len(trace))]
    # every result's telemetry sample carries its OWN request's id, even
    # though the engine retires buckets out of order
    for r in results:
        assert r.sample.trace_id == r.request.trace_id
    assert {s.trace_id for s in sched.telemetry} == set(submitted)
    # spans correlate by the same ids
    span_ids = {s.trace_id for s in tr.spans if s.trace_id}
    assert span_ids == set(submitted)


def test_chrome_export_is_valid_trace_event_json(tmp_path):
    clock = VirtualClock()
    tr = Tracer(clock)
    with tr.span("decide", trace_id="r000000", tenant="acme"):
        clock.advance(0.25)
    tr.record("dispatch", 0.25, 0.75, trace_id="r000000", tid=1)
    path = tmp_path / "trace.json"
    assert tr.export_chrome(str(path)) == 2
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    assert len(xs) == 2
    for e in xs:
        assert set(e) >= {"name", "cat", "ph", "ts", "dur", "pid", "tid"}
        assert e["ts"] >= 0 and e["dur"] >= 0         # rebased, us
    assert xs[0]["args"]["trace_id"] == "r000000"
    assert {e["tid"] for e in xs} == {0, 1}
    # metadata record names the process for the Perfetto track header
    assert events[0]["ph"] == "M"


def test_jsonl_export_roundtrip(tmp_path):
    tr = Tracer(VirtualClock())
    tr.record("retire", 1.0, 2.0, trace_id="r000003", load=1.5)
    path = tmp_path / "spans.jsonl"
    assert tr.export_jsonl(str(path)) == 1
    d = json.loads(path.read_text().strip())
    assert d == {"name": "retire", "t_start": 1.0, "t_end": 2.0,
                 "tid": 0, "trace_id": "r000003",
                 "attrs": {"load": 1.5}}


def test_null_tracer_is_shared_noop():
    # the hot-path contract: one shared span object, nothing recorded,
    # no clock reads — schedulers built without a tracer pay ~nothing
    s1 = NULL_TRACER.span("decide", trace_id="r000000", tenant="a")
    s2 = NULL_TRACER.span("dispatch")
    assert s1 is s2 is _NULL_SPAN
    with s1:
        pass
    NULL_TRACER.record("retire", 0.0, 1.0)
    assert len(NULL_TRACER) == 0 and NULL_TRACER.spans == []
    assert not NULL_TRACER.enabled


def test_scheduler_never_mutates_null_singletons():
    sched = _sched(clock=VirtualClock())
    assert sched.tracer is NULL_TRACER
    assert sched.metrics is NULL_METRICS
    assert NULL_TRACER.clock is None       # bind-my-clock must not leak


# -- metrics registry --------------------------------------------------------


def test_null_metrics_shared_instrument():
    c = NULL_METRICS.counter("serving.requests")
    g = NULL_METRICS.gauge("serving.queue.depth", tenant="acme")
    h = NULL_METRICS.histogram("serving.stage.decide.seconds")
    assert c is g is h is _NULL_INSTRUMENT
    c.inc(); g.set(3); h.observe(0.1)      # all no-ops
    assert NULL_METRICS.snapshot() == {}
    assert not NULL_METRICS.enabled


def test_registry_get_or_create_and_kind_confusion():
    m = MetricsRegistry()
    a = m.counter("serving.requests")
    assert m.counter("serving.requests") is a
    b = m.counter("serving.cache.hit", namespace="acme")
    assert m.counter("serving.cache.hit", namespace="globex") is not b
    with pytest.raises(TypeError):
        m.gauge("serving.requests")


def test_histogram_buckets_and_stats():
    h = Histogram(buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.5, 5.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 4
    assert snap["sum"] == pytest.approx(5.555)
    assert snap["min"] == 0.005 and snap["max"] == 5.0
    assert snap["buckets"] == {"0.01": 1, "0.1": 1, "1.0": 1, "+Inf": 1}


def test_prometheus_text_format():
    m = MetricsRegistry()
    m.counter("serving.requests").inc(3)
    m.counter("serving.cache.hit", namespace="acme").inc()
    m.histogram("serving.stage.decide.seconds",
                buckets=(0.1, 1.0)).observe(0.05)
    text = m.to_prometheus()
    assert "# TYPE serving_requests counter" in text
    assert "serving_requests 3" in text
    assert 'serving_cache_hit{namespace="acme"} 1' in text
    # histogram series: cumulative buckets + sum + count
    assert 'serving_stage_decide_seconds_bucket{le="0.1"} 1' in text
    assert 'serving_stage_decide_seconds_bucket{le="+Inf"} 1' in text
    assert "serving_stage_decide_seconds_count 1" in text


def test_metrics_snapshot_deterministic_across_replays():
    def run():
        m = MetricsRegistry()
        tr = Tracer()
        cfg = TraceConfig(n_requests=400, seed=7,
                          workloads=("vecadd", "dotprod"),
                          arrival="bursty")
        report = simulate_trace(generate_trace(cfg), policy="deadline",
                                seed=7, tracer=tr, metrics=m)
        return report, m.snapshot(), [s.to_json() for s in tr.spans]

    r1, snap1, spans1 = run()
    r2, snap2, spans2 = run()
    assert snap1 == snap2
    assert spans1 == spans2
    assert snap1["serving.requests"]["values"][0]["value"] \
        == r1["completed"]
    assert snap1["serving.queue.shed"]["values"][0]["value"] == r1["shed"]
    hit = snap1["serving.cache.hit"]["values"][0]["value"]
    miss = snap1["serving.cache.miss"]["values"][0]["value"]
    assert hit + miss == r1["completed"]
    assert miss == r1["cold_misses"]


def test_sim_spans_cover_stages_and_clock_is_virtual():
    tr = Tracer()
    cfg = TraceConfig(n_requests=50, seed=1, workloads=("vecadd",),
                      slo_choices=None)
    simulate_trace(generate_trace(cfg), policy="fifo", tracer=tr)
    names = {stage_of(s.name) for s in tr.spans}
    assert {"decide", "tune", "dispatch", "retire"} <= names
    # virtual timeline: all stamps inside the trace's virtual horizon,
    # far below any perf_counter reading
    assert all(0.0 <= s.t_start <= s.t_end < 1e4 for s in tr.spans)


# -- telemetry empty-window contract -----------------------------------------


def test_percentile_empty_raises_typed():
    with pytest.raises(EmptyWindowError) as ei:
        percentile([], 0.5)
    assert "empty window" in str(ei.value)
    assert isinstance(ei.value, ValueError)     # back-compat catch sites


def test_latency_stats_and_summary_empty_return_none():
    assert latency_stats([]) is None
    s = TelemetryLog().summary()                # nothing ever retired
    assert s["requests"] == 0
    assert s["latency"] is None
    assert s["hit_rate"] == 0.0
    assert s["slo_violation_rate"] is None
    assert s["mean_rel_error"] is None
    assert s["per_tenant"] == {}


def test_summary_when_every_request_shed():
    # deadline queue sheds the whole trace -> zero samples, but both the
    # scheduler summary path and the sim report must still render
    clock = VirtualClock()
    sched = _sched(policy="deadline", clock=clock)
    trace = make_trace(["vecadd"], occurrences=2)
    for req in trace:
        req.deadline_s = -1.0                   # expired before submit
    sched.submit_all(trace)
    assert sched.run() == []
    assert len(sched.queue.shed) == len(trace)
    s = sched.telemetry.summary()
    assert s["requests"] == 0 and s["latency"] is None


# -- one clock everywhere ----------------------------------------------------


def test_clock_plumbed_to_every_component():
    clock = VirtualClock()
    tr = Tracer()
    sched = _sched(clock=clock, tracer=tr, metrics=MetricsRegistry())
    assert sched.clock is clock
    assert sched.queue.clock is clock
    assert sched.refiner.clock is clock
    assert sched.tracer.clock is clock


def test_explicit_tracer_clock_is_respected():
    mine = VirtualClock()
    tr = Tracer(mine)
    sched = _sched(tracer=tr)
    assert tr.clock is mine                     # not rebound


# -- live schedulers: spans + metrics on the real path -----------------------


def test_serial_scheduler_metrics_and_spans_consistent():
    tr = Tracer()
    m = MetricsRegistry()
    sched = _sched(tracer=tr, metrics=m)
    with sched:
        sched.submit_all(make_trace(["vecadd", "dotprod"], occurrences=2))
        results = sched.run()
    n = len(results)
    snap = m.snapshot()

    def val(name):
        return snap[name]["values"][0]["value"]

    assert val("serving.requests") == n
    hits = sum(e["value"] for e in snap["serving.cache.hit"]["values"])
    misses = sum(e["value"] for e in snap["serving.cache.miss"]["values"])
    assert hits + misses == n
    assert misses == sum(not r.cache_hit for r in results)
    assert val("serving.model.searches") == sched.stats["model_searches"]
    for stage in ("decide", "dispatch", "retire"):
        assert val(f"serving.stage.{stage}.seconds")["count"] == n
    # one top-level decide/dispatch/retire span per request
    by_stage = aggregate_stage_times(tr.spans)
    assert by_stage["decide"]["count"] == n
    assert by_stage["dispatch"]["count"] == n
    assert by_stage["retire"]["count"] == n
    # telemetry carries the queue-assigned ids
    assert all(s.trace_id is not None for s in sched.telemetry)


def test_engine_batched_tune_records_batch_size():
    m = MetricsRegistry()
    sched = ConcurrentScheduler(
        OverlapHeuristicModel(), window=4, metrics=m,
        telemetry=TelemetryLog(), keep_outputs=False)
    with sched:
        sched.submit_all(make_trace(["vecadd", "dotprod", "mvmult"],
                                    occurrences=1))
        sched.run()
    snap = m.snapshot()
    batch = snap["serving.cold_batch.size"]["values"][0]["value"]
    assert batch["count"] >= 1
    assert batch["max"] >= 2                   # >=2 cold buckets batched


# -- stats CLI ---------------------------------------------------------------


def test_stats_render_smoke():
    from repro.launch.stats import render
    samples = [TelemetrySample(
        seq=i, tenant="acme", workload="vecadd", key="k",
        backend="host-sync", partitions=1, tasks=2, cache_hit=i > 0,
        predicted_s=1e-3, measured_s=1.1e-3, rel_error=0.1,
        latency_s=2e-3, trace_id=f"r{i:06d}") for i in range(3)]
    m = MetricsRegistry()
    m.counter("serving.requests").inc(3)
    m.histogram("serving.stage.decide.seconds").observe(1e-4)
    out = render(samples, m.snapshot())
    assert "requests 3" in out
    assert "hit_rate 0.67" in out
    assert "p95" in out
    assert "serving.requests" in out
    assert "serving.stage.decide.seconds" in out


def test_stats_render_empty_samples():
    from repro.launch.stats import render
    out = render([])
    assert "no retired requests" in out
