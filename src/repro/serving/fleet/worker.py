"""Fleet worker process: one :class:`ConcurrentScheduler` per process.

``worker_main`` is the spawn target the router launches N of.  Each
worker builds its own serving stack — model, tuning cache, telemetry
log, metrics registry, drift detector — so nothing is shared across
processes except the two ``multiprocessing`` queues: ``task_q`` (router
→ worker) carries serve batches and control messages, ``result_q``
(worker → router, one per worker) carries per-request results and the
lifecycle handshakes.  A dedicated result queue per worker matters for
crash handling: a SIGKILL mid-``put`` can corrupt a queue's byte
stream, and with per-worker queues the corruption dies with the worker
— the router discards the queue on respawn instead of losing the whole
fleet's result channel.

Wire protocol (plain picklable tuples, first element is the kind):

  router → worker
    ("serve", [(token, WorkloadRequest), ...])   run a batch
    ("refresh", spec)                            reload model, swap in
    ("ping",)                                    liveness probe
    ("stop",)                                    graceful shutdown

  worker → router
    ("ready", label, pid, model_tag)             startup handshake
    ("result", label, token, payload)            one terminal request
    ("refreshed", label, model_tag, error)       refresh ack
    ("pong", label)
    ("bye", label, {"summary", "metrics", "stats"})  shutdown handshake
    ("fatal", label, error)                      dying; router respawns

``token`` is the router-assigned ``trace_id`` — the worker's own queue
preserves it (``RequestQueue.push`` only assigns when unset), so results
map back to router bookkeeping without a shared sequence space.

Workers default to a :class:`ResiliencePolicy`: a bad request fails
*individually* (terminal ``failed`` result) instead of taking the
process down.  Anything that still escapes — a scheduler bug, an OOM —
exits the process nonzero after a best-effort ``fatal`` message, and
the router's death handler requeues the un-acked work on a respawn:
crash recovery composes out of per-request resilience inside the
process and whole-process replacement outside it.
"""
from __future__ import annotations

import dataclasses
import os
import queue as queue_mod
from typing import Optional


@dataclasses.dataclass
class WorkerConfig:
    """Per-process serving configuration; must stay picklable (it is
    shipped to the spawn child as a process argument)."""

    worker_id: int = 0
    backend: str = "host-sync"
    #: in-flight window of the per-worker ConcurrentScheduler
    window: int = 2
    #: engine thread-pool size (default: window)
    workers: Optional[int] = None
    #: model spec — "heuristic", an artifact id, or a registry path.
    #: Pass a *pinned* artifact id rather than "latest": workers resolve
    #: with ``bootstrap=False`` so N processes never race to train
    model: str = "heuristic"
    model_dir: Optional[str] = None
    drift_threshold: float = 4.0
    #: per-worker tuning-cache JSON path (None = in-memory only); the
    #: router derives distinct paths per slot so namespaces never collide
    cache_path: Optional[str] = None
    #: per-worker telemetry JSONL path (None = in-memory; the router
    #: aggregates the merged fleet stream either way)
    telemetry_path: Optional[str] = None
    #: arm ResiliencePolicy: bad requests fail individually instead of
    #: killing the process
    resilience: bool = True
    #: load-aware drift capacity.  Fleet workers share one host, so a
    #: per-process thread-scaling probe would both slow startup and
    #: measure its neighbors; 1.0 disables within-worker load
    #: normalization (None = probe, as single-process serving does)
    capacity: Optional[float] = 1.0
    keep_outputs: bool = False

    @property
    def label(self) -> str:
        return f"w{self.worker_id}"


def _build_scheduler(cfg: WorkerConfig):
    """The worker's private serving stack.  Imports live here, not at
    module top: the spawn child pays them once, and the router process
    can import this module's dataclass without dragging in jax."""
    from repro.core.autotuner import TuningCache
    from repro.launch.serve import resolve_serving_model
    from repro.serving import (ConcurrentScheduler, DriftDetector,
                               MetricsRegistry, ResiliencePolicy,
                               TelemetryLog)

    model, info = resolve_serving_model(
        cfg.model, cfg.model_dir, bootstrap=False, verbose=False)
    sched = ConcurrentScheduler(
        model,
        window=cfg.window,
        workers=cfg.workers,
        capacity=cfg.capacity,
        backend=cfg.backend,
        policy="fifo",                 # admission ordering is the router's
        cache=TuningCache(cfg.cache_path),
        telemetry=TelemetryLog(cfg.telemetry_path),
        drift=DriftDetector(threshold=cfg.drift_threshold,
                            load_discount=0.5),
        model_tag=info["artifact_id"],
        keep_outputs=cfg.keep_outputs,
        metrics=MetricsRegistry(),
        resilience=ResiliencePolicy() if cfg.resilience else None)
    return sched, info["artifact_id"]


def _light_result(r, label: str) -> dict:
    """Strip a RequestResult for the wire: the request's numpy payload
    stays in the worker (the router kept its own copy for requeue), only
    the decision/outcome/telemetry crosses back."""
    sample = r.sample
    sample.worker = label
    return {
        "status": r.status,
        "error": r.error,
        "workload": r.request.workload,
        "tenant": r.request.tenant,
        "config": ([r.config.partitions, r.config.tasks]
                   if r.config is not None else None),
        "measured_s": r.measured_s,
        "predicted_s": r.predicted_s,
        "cache_hit": r.cache_hit,
        "refined": r.refined,
        "sample": sample.to_json(),
    }


def _drain_serve(task_q, batch: list):
    """Greedily fold queued-up serve messages into one batch so the
    engine sees a full window instead of chunk-sized trickles; the first
    non-serve message ends the drain and is returned for handling."""
    while True:
        try:
            msg = task_q.get_nowait()
        except queue_mod.Empty:
            return batch, None
        if msg[0] == "serve":
            batch.extend(msg[1])
        else:
            return batch, msg


def _serve_batch(sched, label: str, batch, result_q) -> None:
    for _token, req in batch:
        sched.submit(req)
    for r in sched.run():
        # token == the router-assigned trace_id, preserved by push()
        result_q.put(("result", label, r.request.trace_id,
                      _light_result(r, label)))


def _refresh(sched, cfg: WorkerConfig, spec: str):
    from repro.launch.serve import resolve_serving_model
    model, info = resolve_serving_model(
        spec, cfg.model_dir, bootstrap=False, verbose=False)
    sched.swap_model(model, model_tag=info["artifact_id"])
    return info["artifact_id"]


def worker_main(cfg: WorkerConfig, task_q, result_q) -> None:
    """Spawn-target serving loop (must live in an importable module —
    spawn re-imports the target by qualified name, so a closure or
    ``__main__`` function would break under pytest and ``-m`` entry
    points)."""
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    label = cfg.label
    try:
        sched, model_tag = _build_scheduler(cfg)
    except BaseException as e:  # noqa: BLE001 — report, then die loudly
        result_q.put(("fatal", label, f"{type(e).__name__}: {e}"))
        raise SystemExit(1)
    result_q.put(("ready", label, os.getpid(), model_tag))

    try:
        pending_ctrl = None
        while True:
            msg = pending_ctrl if pending_ctrl is not None else task_q.get()
            pending_ctrl = None
            kind = msg[0]
            if kind == "stop":
                break
            if kind == "serve":
                batch, pending_ctrl = _drain_serve(task_q, list(msg[1]))
                _serve_batch(sched, label, batch, result_q)
            elif kind == "refresh":
                try:
                    tag = _refresh(sched, cfg, msg[1])
                    result_q.put(("refreshed", label, tag, None))
                except Exception as e:  # noqa: BLE001 — keep serving on
                    # a bad publish; the old model stays live
                    result_q.put(("refreshed", label, None,
                                  f"{type(e).__name__}: {e}"))
            elif kind == "ping":
                result_q.put(("pong", label))
    except BaseException as e:  # noqa: BLE001 — anything past the
        # per-request resilience barrier is process-fatal: report, exit
        # nonzero, let the router respawn and requeue un-acked work
        result_q.put(("fatal", label, f"{type(e).__name__}: {e}"))
        raise SystemExit(1)

    # graceful goodbye: ship the per-worker aggregates for the fleet
    # merge, then tear down (telemetry close fsyncs the JSONL)
    result_q.put(("bye", label, {
        "summary": sched.telemetry.summary(),
        "metrics": sched.metrics.snapshot(),
        "stats": dict(sched.stats),
    }))
    if cfg.cache_path:
        sched.cache.save()
    sched.close()
    result_q.close()
    result_q.join_thread()
