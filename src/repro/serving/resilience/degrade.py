"""Graceful degradation: circuit breaker + documented fallback ladder.

The serving path never has only one way to answer a request — it has a
*ladder* (README "Resilience" for the full table):

====================  =======================================
failing stage         step-down
====================  =======================================
model search          trained registry model -> OverlapHeuristicModel
                      -> cache-nearest-bucket config -> single stream
backend dispatch      host-pipelined/host-threads -> host-sync
persisted JSON        quarantine the corrupt file, rebuild empty
====================  =======================================

The :class:`CircuitBreaker` decides *when* to stop paying for the
primary: after ``k`` consecutive failures for a (tenant, stage) key it
opens (requests go straight to the fallback, no retry storm), and after
``cooldown_s`` it lets exactly one half-open probe through — success
closes it, failure re-opens.  State transitions are exported on the
metrics registry (``serving.breaker.state``: 0=closed, 1=half-open,
2=open) and recorded on ``events`` for recovery-time measurement in the
chaos bench.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import threading
from typing import Optional

from repro.core.autotuner import TuneResult, TuningCache, \
    quarantine_file  # noqa: F401  (re-exported: the resilience-facing name)

CLOSED, HALF_OPEN, OPEN = "closed", "half-open", "open"
_STATE_CODE = {CLOSED: 0, HALF_OPEN: 1, OPEN: 2}


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """``k`` consecutive failures open the breaker for ``cooldown_s``."""

    k: int = 3
    cooldown_s: float = 2.0


class _Breaker:
    __slots__ = ("failures", "state", "opened_at", "probing")

    def __init__(self) -> None:
        self.failures = 0
        self.state = CLOSED
        self.opened_at = 0.0
        self.probing = False


class CircuitBreaker:
    """Per-key (conventionally ``(tenant, stage)``) circuit breaker.

    Thread-safe: the concurrent engine's workers record dispatch
    outcomes from the pool threads while the coordinator asks
    :meth:`allow` for the next request.
    """

    def __init__(self, config: BreakerConfig = BreakerConfig(), *,
                 clock=None, metrics=None):
        self.config = config
        self.clock = clock
        self.metrics = metrics
        self._lock = threading.Lock()
        self._keys: dict[tuple, _Breaker] = {}
        #: (t, key, state) transition log — the chaos bench derives
        #: open->closed recovery times from it
        self.events: list[tuple[float, tuple, str]] = []

    def _now(self) -> float:
        return self.clock.now() if self.clock is not None else 0.0

    def _transition(self, key: tuple, b: _Breaker, state: str) -> None:
        b.state = state
        self.events.append((self._now(), key, state))
        if self.metrics is not None:
            tenant, stage = (key if len(key) == 2 else (str(key), ""))
            self.metrics.gauge("serving.breaker.state",
                               tenant=str(tenant), stage=str(stage)
                               ).set(_STATE_CODE[state])
            if state == OPEN:
                self.metrics.counter("serving.breaker.opened",
                                     tenant=str(tenant), stage=str(stage)
                                     ).inc()

    def allow(self, key: tuple) -> bool:
        """May the primary path be attempted for ``key`` right now?"""
        with self._lock:
            b = self._keys.get(key)
            if b is None or b.state == CLOSED:
                return True
            if b.state == OPEN:
                if self._now() - b.opened_at >= self.config.cooldown_s:
                    self._transition(key, b, HALF_OPEN)
                    b.probing = True
                    return True     # this caller is the recovery probe
                return False
            # half-open: exactly one outstanding probe
            if b.probing:
                return False
            b.probing = True
            return True

    def record_success(self, key: tuple) -> None:
        with self._lock:
            b = self._keys.get(key)
            if b is None:
                return
            b.failures = 0
            b.probing = False
            if b.state != CLOSED:
                self._transition(key, b, CLOSED)

    def record_failure(self, key: tuple) -> None:
        with self._lock:
            b = self._keys.setdefault(key, _Breaker())
            b.failures += 1
            b.probing = False
            if b.state == HALF_OPEN or (b.state == CLOSED
                                        and b.failures >= self.config.k):
                b.opened_at = self._now()
                self._transition(key, b, OPEN)

    def state(self, key: tuple) -> str:
        with self._lock:
            b = self._keys.get(key)
            return b.state if b is not None else CLOSED

    def states(self) -> dict[tuple, str]:
        with self._lock:
            return {k: b.state for k, b in self._keys.items()}


# ---------------------------------------------------------------------------
# Cache-nearest-bucket fallback (the bottom rung above single-stream)
# ---------------------------------------------------------------------------


def _split_key(key: str) -> Optional[tuple[str, str, str, str, str]]:
    """Split a :meth:`TuningCache.key` string into
    (namespace, workload, backend, model_tag, signature)."""
    ns = ""
    if key.startswith("tenant:"):
        ns, _, key = key.partition("|")
        ns = ns[len("tenant:"):]
    parts = key.split("|", 3)
    if len(parts) != 4:
        return None
    workload, backend, tag, sig = parts
    return ns, workload, backend, tag, sig


def _lead_rows(sig: str) -> Optional[tuple[int, str]]:
    """(bucketed leading dim of the first chunked buffer, rest-of-sig)
    — the rest must match exactly for two buckets to be comparable."""
    try:
        d = json.loads(sig)
        chunked = d["chunked"]
        rows = int(chunked[0][1][0])
    except (ValueError, KeyError, IndexError, TypeError):
        return None
    skeleton = json.dumps(
        {"chunked": [[name, shape[1:], dt] for name, shape, dt in chunked],
         "shared": d.get("shared", [])}, separators=(",", ":"))
    return rows, skeleton


def nearest_bucket_entry(cache: Optional[TuningCache], key: str,
                         n_rows: int) -> Optional[TuneResult]:
    """Borrow the tuned config of the *nearest shape bucket* when the
    model search itself is down: same (tenant, workload, backend,
    model_tag) and identical inner dims/dtypes/shared buffers, minimal
    ``|log2(rows_a / rows_b)|`` distance, and still splittable for this
    batch.  Returns None when no comparable bucket exists."""
    if cache is None:
        return None
    want = _split_key(key)
    if want is None:
        return None
    want_rows = _lead_rows(want[4])
    if want_rows is None:
        return None
    best: Optional[TuneResult] = None
    best_d = math.inf
    for other in cache.keys():
        if other == key:
            continue
        got = _split_key(other)
        if got is None or got[:4] != want[:4]:
            continue
        got_rows = _lead_rows(got[4])
        if got_rows is None or got_rows[1] != want_rows[1]:
            continue
        entry = cache.peek(other)
        if entry is None or entry.config.partitions * entry.config.tasks \
                > n_rows:
            continue
        d = abs(math.log2(max(got_rows[0], 1) / max(want_rows[0], 1)))
        if d < best_d:
            best, best_d = entry, d
    return best


# ---------------------------------------------------------------------------
# Crash-safe persistence helpers
# ---------------------------------------------------------------------------


def atomic_write_json(path, payload, *, indent: Optional[int] = 0) -> str:
    """tmp + flush + fsync + rename: a crash mid-write leaves the old
    file intact, never a half-written JSON document."""
    path = str(path)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=indent)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path
