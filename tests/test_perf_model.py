"""Performance model: feature pipeline + MLP regression (paper §3)."""
import numpy as np
import pytest

from repro.core.features import N_CONFIG_FEATURES, config_features
from repro.core.perf_model import (FeaturePipeline, ForestRegressor,
                                   KernelRidgeRBF, PerformanceModel,
                                   TreeRegressor)
from repro.core.stream_config import StreamConfig


N_SYN_FEATURES = 6  # few noise dims so PCA(9) keeps the config signal —
# the real 22-feature pipeline is exercised end-to-end in test_system.py


def _synthetic(n=600, seed=0, n_feat=N_SYN_FEATURES):
    """Speedup = f(features, config) with a known sweet spot."""
    rng = np.random.default_rng(seed)
    X = []
    y = []
    for _ in range(n):
        feats = rng.normal(size=n_feat)
        ratio = feats[-1]  # pretend comp/comm ratio
        p = 2 ** rng.integers(0, 5)
        t = 2 ** rng.integers(0, 6)
        cfgf = config_features(p, t)
        # ground truth: best tasks grows with ratio; partitions penalized
        opt_logt = 2.0 + ratio
        speed = 1.5 - 0.15 * (np.log2(t) - opt_logt) ** 2 - 0.1 * np.log2(p)
        speed += rng.normal() * 0.02
        X.append(np.concatenate([feats, cfgf]))
        y.append(max(speed, 0.1))
    return np.asarray(X), np.asarray(y)


def test_pipeline_shapes_and_pruning():
    X, y = _synthetic()
    # duplicate a column to force pruning
    X2 = np.concatenate([X, X[:, :1] * 2.0], axis=1)
    pipe = FeaturePipeline.fit(X2, y, n_components=9)
    assert len(pipe.keep_idx) < X2.shape[1]  # pruned the duplicate
    Z = pipe.transform(X2)
    assert Z.shape[0] == len(y) and Z.shape[1] <= 9
    yn = pipe.transform_y(y)
    assert abs(yn.mean()) < 1e-8 and abs(yn.std() - 1) < 1e-6
    np.testing.assert_allclose(pipe.inverse_y(yn), y, rtol=1e-6)


@pytest.mark.slow
def test_mlp_learns_synthetic_speedups():
    X, y = _synthetic()
    m = PerformanceModel.train(X, y, epochs=500)
    pred = m.predict(X)
    mse = float(np.mean((pred - y) ** 2))
    assert mse < 0.05, mse


@pytest.mark.slow
def test_model_ranks_configs_sensibly():
    X, y = _synthetic()
    m = PerformanceModel.train(X, y, epochs=500)
    feats = np.zeros(N_SYN_FEATURES)
    feats[-1] = 1.0  # ratio=1 -> optimal log2(t)=3
    cfgs = [StreamConfig(1, t) for t in (1, 2, 4, 8, 16, 32)]
    preds = m.predict_configs(feats, cfgs)
    best = cfgs[int(np.argmax(preds))]
    assert best.tasks in (4, 8, 16), best  # near the true optimum 8


def test_generalizes_to_unseen_configs():
    """The regression model scores configs never present in training
    (the key advantage over the classifier, paper §6.4)."""
    X, y = _synthetic()
    m = PerformanceModel.train(X, y, epochs=300)
    feats = np.zeros(N_SYN_FEATURES)
    unseen = StreamConfig(3, 24)  # non-power-of-two, never in training
    pred = m.predict_configs(feats, [unseen])
    assert np.isfinite(pred).all()


@pytest.mark.parametrize("cls", [TreeRegressor, ForestRegressor,
                                 KernelRidgeRBF])
def test_alternative_learners(cls):
    X, y = _synthetic(n=400)
    m = cls.train(X, y)
    pred = m.predict(X)
    assert np.isfinite(pred).all()
    mse = float(np.mean((pred - y) ** 2))
    assert mse < 0.25, (cls.__name__, mse)


def test_config_features_monotone():
    a = config_features(1, 1)
    b = config_features(4, 16)
    assert a.shape == (N_CONFIG_FEATURES,)
    assert b[0] > a[0] and b[1] > a[1]


def test_predict_configs_batched_matches_per_program():
    """A (B, F) feature matrix ranks B programs in one forward pass with
    exactly the per-program predictions (the serving engine's batched
    cold path)."""
    X, y = _synthetic()
    m = PerformanceModel.train(X, y, epochs=200)
    rng = np.random.default_rng(1)
    progs = rng.normal(size=(3, N_SYN_FEATURES))
    cands = [StreamConfig(1, 1), StreamConfig(1, 8), StreamConfig(2, 4),
             StreamConfig(4, 16)]
    batched = m.predict_configs(progs, cands)
    assert batched.shape == (3, len(cands))
    for b in range(3):
        single = m.predict_configs(progs[b], cands)
        assert single.shape == (len(cands),)
        np.testing.assert_allclose(batched[b], single, rtol=1e-5,
                                   atol=1e-6)
