"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887; hf].

One 8-layer period holds 1 attention + 7 Mamba blocks; every other layer's
FFN is MoE (16 experts, top-2), the rest are dense MLPs — 9 periods = 72
layers.  Params check out at ~398B total / ~95B active (see configs/base.py
param_counts and tests/test_configs.py).
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register

JAMBA15_LARGE_398B = register(
    ArchConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=24576,
        vocab_size=65536,
        layer_pattern=(
            "mamba", "mamba", "mamba", "attn",
            "mamba", "mamba", "mamba", "mamba",
        ),
        ffn_on="all",
        moe_layer_indices=(1, 3, 5, 7),
        moe=MoEConfig(
            num_experts=16,
            top_k=2,
            expert_d_ff=24576,
            sharding="ep",  # 16 experts / 16-way model axis = 1 per group
        ),
        ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
        subquadratic=True,  # 1:7 attn:mamba => long_500k cell runs
        source="arXiv:2403.19887",
    )
)
