"""End-to-end runtime autotuner (paper Fig. 4): features -> model ->
ranked configs -> StreamConfig, in milliseconds, per program x dataset.

Also hosts the pod-scale face of the technique: ``rank_mesh_candidates``
scores (mesh factorization x microbatch) candidates for a training step
from dry-run roofline features — the TPU-native generalization where
"profiling" is exact static analysis (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.core import features as feat_lib
from repro.core.perf_model import PerformanceModel
from repro.core.search import search_best
from repro.core.stream_config import StreamConfig, default_space
from repro.core.streams import StreamedRunner
from repro.core.workloads import Workload


@dataclasses.dataclass
class TuneResult:
    config: StreamConfig
    predicted_speedup: float
    feature_seconds: float
    search_seconds: float


class AutoTuner:
    def __init__(self, model: PerformanceModel,
                 candidates: Optional[Sequence[StreamConfig]] = None):
        self.model = model
        self.candidates = list(candidates or default_space())

    def tune(self, wl: Workload, chunked: dict, shared: dict,
             *, runner: Optional[StreamedRunner] = None) -> TuneResult:
        t0 = time.perf_counter()
        runner = runner or StreamedRunner(wl, chunked, shared)
        feats = feat_lib.extract_features(runner, profile_reps=1)
        t_feat = time.perf_counter() - t0
        n_rows = next(iter(chunked.values())).shape[0]
        cands = [c for c in self.candidates
                 if c.partitions * c.tasks <= n_rows]
        best, preds, t_search = search_best(self.model, feats.values, cands)
        return TuneResult(best, float(np.max(preds)), t_feat, t_search)


# ---------------------------------------------------------------------------
# Pod-scale candidate ranking (mesh backend)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshCandidate:
    """A pod-scale 'stream configuration': how the fixed chip grid is
    factorized (spatial) and how many microbatches per step (temporal)."""

    data: int
    model: int
    microbatches: int

    @property
    def stream_config(self) -> StreamConfig:
        return StreamConfig(self.data, self.microbatches)


def rank_by_roofline(candidates, terms: dict) -> list:
    """Rank MeshCandidates by their dry-run roofline makespan estimate.

    ``terms`` maps candidate -> dict(compute=, memory=, collective=) in
    seconds (from repro.roofline.analysis).  The makespan model assumes the
    collective term overlaps compute up to the dominant-term bound — the
    same overlap objective the paper's model learns.
    """
    def makespan(c):
        t = terms[c]
        return max(t["compute"], t["memory"]) + max(
            0.0, t["collective"] - 0.5 * max(t["compute"], t["memory"]))

    return sorted(candidates, key=makespan)
