"""The concurrent serving engine: overlapped request execution on a
bounded worker pool.

The serial :class:`~repro.serving.scheduler.AdaptiveScheduler` chains
every request's millisecond *execution* behind the previous one, even
though the paper's whole point (§3.3) is that the placement *decision* is
microseconds.  This engine splits the per-request pipeline into the three
stages the scheduler already exposes and overlaps them across requests:

  decide    coordinator thread: queue pop (policy order), cache lookup,
            and — for the cold requests of a window fill — ONE batched
            model search over a ``(B, F)`` feature matrix
            (:meth:`AdaptiveScheduler._tune_cold_batch`);
  dispatch  a bounded worker pool (the ``host-threads`` backend's
            :class:`~repro.core.backends.host_threads.WindowedPool`
            machinery) executes up to ``window`` requests concurrently;
  retire    coordinator thread: completions are collected out of order,
            but telemetry / drift observation for each tuning bucket is
            flushed in that bucket's dispatch order
            (:class:`OrderedRetirer`), so the drift detector sees the
            same per-bucket sample sequence a serial pass would.

Ordering guarantees:
  * decisions (and therefore config choices) happen in queue-policy
    order, identical to the serial scheduler;
  * ``run()`` returns results in decision order;
  * telemetry ``seq`` reflects retirement order — out of order across
    buckets, dispatch-ordered within each bucket.

The dispatch hot path is amortized two ways: partition slicing plans are
memoized per (row-count, config) in :mod:`repro.core.backends.base`, and
:class:`ContextPool` recycles ``ExecutionContext`` objects per workload,
swapping in each request's buffers instead of rebuilding a
:class:`StreamedRunner` (an empty shared dict then costs zero H2D).

Measurement discipline: cold-path profiling (feature extraction, the
single-stream anchor of a persisted warm hit) drains the in-flight
window first, so the numbers persisted into the tuning cache and the
prediction anchor are measured on an idle pool.  ``measured_s`` itself
is wall time under concurrency — contention inflates it relative to an
isolated run — so the drift signal is **load-aware**: each dispatch is
stamped with its window occupancy, and at retire time ``measured_s`` is
divided by ``contention_factor(inflight, parallel_capacity, workers)``
(occupancy over the host's calibrated thread-scaling ceiling) before
the prediction error is computed.  Overlap inflation therefore no
longer masquerades as model drift; ``load_aware=False`` restores the
raw-wall-time signal for A/B measurement.
"""
from __future__ import annotations

import collections
import dataclasses
import sys
from concurrent.futures import FIRST_COMPLETED, wait
from typing import Optional

from repro.core.backends import ExecutionContext
from repro.core.backends.host_threads import WindowedPool
from repro.core.streams import StreamedRunner, probe_host_capacity
from repro.core.workloads import get_workload
from repro.serving.queue import WorkloadRequest
from repro.serving.refinement import DriftDetector, contention_factor
from repro.serving.scheduler import (AdaptiveScheduler, PendingRequest,
                                     RequestResult)


class ContextPool:
    """Per-workload free lists of reusable :class:`ExecutionContext`\\ s.

    Concurrent requests of the same workload each lease their own
    context (their chunked/shared buffers differ); a released context is
    recycled for the next lease with
    :meth:`ExecutionContext.swap_buffers`."""

    def __init__(self, device=None):
        self.device = device
        self._free: dict[str, list[ExecutionContext]] = {}
        self.leases = 0
        self.reuses = 0

    def lease(self, wl, chunked: dict, shared: dict) -> ExecutionContext:
        self.leases += 1
        free = self._free.get(wl.name)
        if free:
            self.reuses += 1
            return free.pop().swap_buffers(chunked, shared)
        return ExecutionContext.create(wl.kernel, chunked, shared,
                                       self.device)

    def release(self, name: str, ctx: ExecutionContext) -> None:
        self._free.setdefault(name, []).append(ctx)


class OrderedRetirer:
    """Buffers out-of-order completions so each bucket retires in its own
    dispatch order.

    ``issue(key)`` stamps a dispatch index for the bucket;
    ``complete(key, idx, payload)`` hands back every payload that is now
    retirable — i.e. the contiguous run of completions starting at the
    bucket's next-unretired index.  Deterministic: for ANY completion
    order of a fixed dispatch sequence, the concatenation of returned
    payload lists per bucket is that bucket's dispatch order."""

    def __init__(self):
        self._issued: collections.Counter = collections.Counter()
        self._next: collections.Counter = collections.Counter()
        self._held: dict = {}

    def issue(self, key: str) -> int:
        idx = self._issued[key]
        self._issued[key] += 1
        return idx

    def complete(self, key: str, idx: int, payload) -> list:
        self._held[(key, idx)] = payload
        ready = []
        while (key, self._next[key]) in self._held:
            ready.append(self._held.pop((key, self._next[key])))
            self._next[key] += 1
        return ready

    @property
    def held(self) -> int:
        return len(self._held)


class ConcurrentScheduler(AdaptiveScheduler):
    """Adaptive scheduler with up to ``window`` requests in flight.

    ``window=1`` degenerates to the serial scheduler (same stages, same
    results, one extra thread hop).  Decisions, cold tuning, and
    retirement all run on the coordinating thread; only the execute
    stage — warmup, dispatch, block, D2H read-back — runs on pool
    workers, so all scheduler state mutation stays single-threaded."""

    def __init__(self, model, *, window: int = 4,
                 workers: Optional[int] = None,
                 capacity: Optional[float] = None,
                 load_aware: bool = True, **kwargs):
        # default drift detector: same thresholds as the serial
        # scheduler's, plus a load discount — samples retired at high
        # window occupancy carry residual contention noise the
        # normalization can't fully cancel, and at 10^5-request scale
        # that noise WILL eventually line up into a spurious window.
        # Callers passing their own detector keep full control.
        if kwargs.get("drift") is None:
            kwargs["drift"] = DriftDetector(load_discount=0.5)
        super().__init__(model, **kwargs)
        assert window >= 1, window
        self.window = window
        self.workers = workers if workers is not None else window
        self.pool = WindowedPool(self.workers, window, name="serve-engine")
        self.ctx_pool = ContextPool()
        self.retirer = OrderedRetirer()
        # load-aware drift: ``capacity`` is the host's measured
        # N-thread kernel-scaling ceiling (see
        # core.streams.parallel_capacity).  None → calibrated by a
        # one-off probe at ``run()`` entry, while the pool is idle.
        # ``load_aware=False`` reverts to raw-wall-time drift (the
        # pre-tenancy behavior, kept for A/B measurement).
        self.load_aware = load_aware
        self._capacity = capacity
        # drift-triggered refinements queue here and re-profile at the
        # next pool-quiesce point (the runner is held un-released until
        # then): profiling on a busy pool would write contention-skewed
        # measured speedups into the cache — the exact poisoning the
        # load-aware drift signal exists to prevent
        self._deferred_refinements: list = []
        # watchdog-abandoned futures: the worker is still running (a
        # thread cannot be cancelled mid-dispatch), so the future parks
        # here and a done-callback reclaims its ExecutionContext when
        # the backend finally returns; pool.shutdown(wait=True) at
        # close() joins them
        self._zombies: set = set()
        self._m_watchdog = self.metrics.counter("serving.watchdog.fired")

    @property
    def parallel_capacity(self) -> float:
        """The calibrated thread-scaling ceiling the contention factor
        divides by; probed once on first use when not injected."""
        if self._capacity is None:
            self._capacity = max(1.0, probe_host_capacity(self.workers))
        return self._capacity

    # -- pooled runners -------------------------------------------------------

    def _make_runner(self, req: WorkloadRequest) -> StreamedRunner:
        wl = get_workload(req.workload)
        ctx = self.ctx_pool.lease(wl, req.chunked, req.shared)
        return StreamedRunner(wl, req.chunked, req.shared,
                              backend=self.backend_name, ctx=ctx)

    def _release_runner(self, runner: StreamedRunner) -> None:
        self.ctx_pool.release(runner.wl.name, runner.ctx)

    # -- load-aware drift -----------------------------------------------------

    def _load_factor(self, pending: PendingRequest) -> float:
        """Occupancy over capacity: a request that shared the window
        with others has its ``measured_s`` deflated back to an isolated-
        run estimate before drift detection sees it.  An uncontended
        request (``inflight == 1``) never pays the calibration probe."""
        if not self.load_aware or pending.inflight <= 1:
            return 1.0
        return contention_factor(pending.inflight, self.parallel_capacity,
                                 self.workers)

    def _refine(self, pending, ctx, key, entry) -> None:
        """Defer the re-profiling to the next quiesce point; the
        triggering request's runner is kept leased until then so the
        refiner measures this request's own buffers, not a recycled
        context's."""
        pending.defer_release = True
        self._deferred_refinements.append((pending, ctx, key, entry))

    def _flush_refinements(self) -> None:
        """Run queued refinements on the now-idle pool (callers drain
        first), then release the held runners.  Under a resilience
        policy a failing refinement loses one model update, never the
        run."""
        while self._deferred_refinements:
            pending, ctx, key, entry = self._deferred_refinements.pop(0)
            try:
                super()._refine(pending, ctx, key, entry)
            except Exception:  # noqa: BLE001 — fault barrier
                if self.resilience is None:
                    raise
                self.stats["refine_failures"] += 1
                self.metrics.counter("serving.refine.failed").inc()
            finally:
                self._release_runner(pending.runner)

    # -- the overlapped serving loop ------------------------------------------

    def run(self, max_requests: Optional[int] = None) -> list[RequestResult]:
        """Drain the queue with up to ``window`` requests in flight;
        returns results in decision (queue-policy) order."""
        # the coordinator contends for the GIL with busy workers; at the
        # default 5 ms switch interval a retire-and-refill cycle can
        # stall long enough to starve the pool, so run with a tighter
        # interval (restored on exit) — the same knob threaded Python
        # servers tune
        prev_switch = sys.getswitchinterval()
        sys.setswitchinterval(min(prev_switch, 1e-3))
        try:
            return self._run(max_requests)
        finally:
            sys.setswitchinterval(prev_switch)

    def _flush_ready(self, flushed, results: dict) -> None:
        """Retire a bucket's now-contiguous dispatch-order run.  ``None``
        payloads (failed or watchdog-abandoned slots) were already
        accounted for when their slot advanced."""
        for item in flushed:
            if item is None:
                continue
            rp, routs, rmeasured = item
            try:
                results[rp.order] = self._retire(rp, routs, rmeasured)
            except Exception as e:  # noqa: BLE001 — fault barrier
                if self.resilience is None:
                    raise
                results[rp.order] = self._fail_request(rp.req, rp, e)
                rp.defer_release = False
            # a retire that triggered a refinement keeps its runner
            # leased until the deferred re-profiling has run
            if not rp.defer_release:
                self._release_runner(rp.runner)

    def _retire_completed(self, done, inflight: dict,
                          results: dict) -> Optional[BaseException]:
        """Retire a set of completed futures, flushing each touched
        bucket's contiguous dispatch-order run.  A future that raised
        still advances its bucket (a poisoned slot would hold every
        later completion of that bucket forever) and releases its
        context before the error is reported.  Without a resilience
        policy the first error seen is returned rather than raised so
        the caller can drain the rest; WITH one, an execution error
        fails that request individually (error telemetry + ``status``)
        and the loop keeps serving."""
        error: Optional[BaseException] = None
        for fut in done:
            p = inflight.pop(fut)
            try:
                payload = (p, *fut.result())
            # deliberate blanket catch: ANY worker outcome must advance
            # the bucket slot or every later completion hangs
            except BaseException as e:  # noqa: BLE001
                self._release_runner(p.runner)
                payload = None
                if self.resilience is not None and isinstance(e, Exception):
                    results[p.order] = self._fail_request(p.req, p, e)
                elif error is None:
                    error = e
            self._flush_ready(self.retirer.complete(p.key, p.bucket_idx,
                                                    payload), results)
        return error

    def _wait_completed(self, inflight: dict, results: dict) -> set:
        """Wait for at least one completion — with the resilience
        watchdog armed, wake at the earliest in-flight deadline instead
        and reap overdue executions (abandon + requeue once, then fail
        individually).  Returns the completed set; empty after a reap
        pass (the caller re-enters with the updated window)."""
        if not inflight:
            return set()
        wd = self.resilience.watchdog_s \
            if self.resilience is not None else None
        if wd is None:
            done, _ = wait(inflight, return_when=FIRST_COMPLETED)
            return done
        while True:
            now = self.clock.now()
            deadlines = [p.watchdog_deadline_s for p in inflight.values()
                         if p.watchdog_deadline_s is not None]
            # no stamped deadline = nothing has STARTED executing yet
            # (deadlines arm at worker entry); heartbeat at wd anyway
            timeout = max(1e-3, min(deadlines) - now) if deadlines else wd
            done, _ = wait(inflight, timeout=timeout,
                           return_when=FIRST_COMPLETED)
            if done:
                return done
            if self._reap_overdue(inflight, results) or not inflight:
                return set()

    def _reclaim_zombie(self, fut, runner):
        def _cb(f) -> None:
            self._zombies.discard(fut)
            try:
                f.result()
            # the zombie's outcome is irrelevant — its slot was already
            # advanced and its request requeued or failed
            except BaseException:  # noqa: BLE001
                pass
            self._release_runner(runner)
        return _cb

    def _watched_execute(self, p):
        """Execute stage under the watchdog: the deadline arms at
        WORKER ENTRY, not at submit — a task queued behind a
        zombie-occupied worker must not burn its execution budget
        waiting for a thread."""
        p.watchdog_deadline_s = self.clock.now() + self.resilience.watchdog_s
        return self._execute_safe(p)

    def _reap_overdue(self, inflight: dict, results: dict) -> bool:
        """Watchdog: an execution past its deadline is abandoned (the
        worker thread cannot be cancelled; the future parks in
        ``_zombies`` and a done-callback reclaims its context), its
        bucket slot advances, and the request is re-dispatched on a
        FRESH runner at most ``requeue_limit`` times before failing
        individually with ``status="timeout"``."""
        now = self.clock.now()
        acted = False
        for fut, p in list(inflight.items()):
            if fut.done() or p.watchdog_deadline_s is None \
                    or now < p.watchdog_deadline_s:
                continue
            acted = True
            del inflight[fut]
            self._zombies.add(fut)
            fut.add_done_callback(self._reclaim_zombie(fut, p.runner))
            self._m_watchdog.inc()
            self.stats["watchdog_fired"] += 1
            self._flush_ready(self.retirer.complete(p.key, p.bucket_idx,
                                                    None), results)
            if p.requeues < self.resilience.requeue_limit:
                p2 = dataclasses.replace(
                    p, runner=self._make_runner(p.req),
                    requeues=p.requeues + 1,
                    bucket_idx=self.retirer.issue(p.key),
                    watchdog_deadline_s=None)
                inflight[self.pool.submit(self._watched_execute, p2)] = p2
            else:
                results[p.order] = self._fail_request(
                    p.req, p,
                    TimeoutError(
                        f"execution exceeded the "
                        f"{self.resilience.watchdog_s:g}s watchdog "
                        f"{p.requeues + 1}x"),
                    status="timeout")
        return acted

    def _drain(self, inflight: dict,
               results: dict) -> Optional[BaseException]:
        """Retire everything in flight; returns the first error seen."""
        error = None
        while inflight:
            done = self._wait_completed(inflight, results)
            if done:
                error = self._retire_completed(done, inflight,
                                               results) or error
        return error

    def _run(self, max_requests: Optional[int]) -> list[RequestResult]:
        results: dict[int, RequestResult] = {}
        inflight: dict = {}                  # future -> PendingRequest
        decided = 0

        # calibrate the contention ceiling NOW, while nothing is in
        # flight: a lazy probe at the first contended retire would time
        # itself against the engine's own busy workers and cache a
        # permanently understated capacity (overstated load factors,
        # masked real drift)
        if self.load_aware and self.window > 1 and self._capacity is None:
            _ = self.parallel_capacity

        def budget_left() -> bool:
            return max_requests is None or decided < max_requests

        def check(error: Optional[BaseException]) -> None:
            if error is not None:
                # finish the survivors cleanly, then surface the failure;
                # queued refinements are abandoned (their runners still
                # go back to the pool), not profiled mid-error
                self._drain(inflight, results)
                for p, *_ in self._deferred_refinements:
                    self._release_runner(p.runner)
                self._deferred_refinements.clear()
                raise error

        while (self.queue and budget_left()) or inflight:
            # drift refinements queued by the last retire wave run FIRST,
            # on a drained pool, so (a) their re-profiling is measured
            # idle and (b) the decisions below see the refreshed cache
            # entry — the same visibility inline refinement had
            if self._deferred_refinements:
                check(self._drain(inflight, results))
                self._flush_refinements()
            # decide: fill the free window slots in queue-policy order
            batch: list[PendingRequest] = []
            while (self.queue and budget_left()
                   and len(inflight) + len(batch) < self.window):
                try:
                    req = self.queue.pop()
                except IndexError:
                    break   # deadline policy shed everything that was left
                try:
                    batch.append(self._decide(req))
                except Exception as e:  # noqa: BLE001 — fault barrier
                    if self.resilience is None:
                        raise
                    # _decide failed before allocating an order slot
                    results[self._order] = self._fail_request(req, None, e)
                    self._order += 1
                decided += 1
            # batched cold path: one model search for every cold bucket
            # in this fill, measured on a quiesced pool — profiling
            # (cold features, single-stream anchors) on a busy pool
            # would persist contention-skewed numbers into the tuning
            # cache and the prediction anchor
            colds = [p for p in batch if p.entry is None]
            anchors = [p for p in batch if p.needs_anchor]
            if colds or anchors:
                check(self._drain(inflight, results))
            for p in anchors:
                if self.resilience is None:
                    self._measure_anchor(p)
                else:
                    self._try_anchor(p)
            if len(colds) == 1:
                self._tune_cold_safe(colds[0])
            elif colds:
                try:
                    self._tune_cold_batch(colds)
                except Exception:  # noqa: BLE001 — fault barrier
                    if self.resilience is None:
                        raise
                    # batched search died: walk the ladder per bucket
                    for p in colds:
                        if p.entry is None:
                            self._tune_cold_safe(p)
            # dispatch: stamp each request's window occupancy — the
            # load-aware drift signal's numerator.  The whole wave is in
            # flight together (submits are microseconds, executions are
            # milliseconds), so every member gets the post-dispatch
            # occupancy; stamping len(inflight)+1 per submit would leave
            # the wave's FIRST request marked uncontended and its
            # contention-inflated wall time reading as drift
            occupancy = len(inflight) + len(batch)
            wd = self.resilience.watchdog_s \
                if self.resilience is not None else None
            run_stage = (self._execute_safe if wd is None
                         else self._watched_execute)
            for p in batch:
                p.bucket_idx = self.retirer.issue(p.key)
                p.inflight = occupancy
                inflight[self.pool.submit(run_stage, p)] = p
            self._m_inflight.set(occupancy)
            if not inflight:
                continue
            # retire whatever completed first (out of order); an empty
            # set means the watchdog reshaped the window instead
            done = self._wait_completed(inflight, results)
            if done:
                check(self._retire_completed(done, inflight, results))

        self._flush_refinements()          # pool is idle: nothing in flight
        self._m_inflight.set(0)
        assert self.retirer.held == 0, "completions left unretired"
        assert not inflight, "futures left in flight"
        self.stats["ctx_reuses"] = self.ctx_pool.reuses
        return [results[i] for i in sorted(results)]

    def step(self) -> RequestResult:
        (result,) = self.run(max_requests=1)
        return result

    def close(self) -> None:
        """Worker-pool shutdown + telemetry flush/fsync/close."""
        self.pool.shutdown()
        super().close()

    def shutdown(self) -> None:
        self.close()
