"""xlstm-350m [ssm] — alternating sLSTM + mLSTM blocks [arXiv:2405.04517;
unverified].  Fully recurrent (matrix/scalar memories), so the long_500k
decode cell runs: state is O(1) in sequence length.
"""
from repro.configs.base import ArchConfig, XLSTMConfig, register

XLSTM_350M = register(
    ArchConfig(
        name="xlstm-350m",
        family="ssm",
        num_layers=24,
        d_model=1024,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,  # xLSTM blocks embed their own up/down projections
        vocab_size=50304,
        layer_pattern=("slstm", "mlstm"),
        ffn_on="none",
        xlstm=XLSTMConfig(),
        subquadratic=True,
        source="arXiv:2405.04517",
    )
)
