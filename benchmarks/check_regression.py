"""Bench-regression gate: compare a freshly produced serving benchmark
JSON against the committed baseline and fail CI on a real regression.

    python benchmarks/check_regression.py FRESH BASELINE [--tolerance 0.25]

Works on all the benchmark artifacts:

  BENCH_serving.json  (``--serve-concurrent``)  gated on
      ``capacity_fraction`` — the engine's speedup normalized by the SAME
      run's measured host parallel-capacity ceiling.  The raw ceiling on
      the shared 2-vCPU CI class drifts ~1.3-2.3x with neighbor load
      (ROADMAP), so raw throughput/speedup would flag the *host*, not the
      code; the fraction cancels the drift.
  BENCH_oracle.json   (``--serve-oracle``)      gated on
      ``mean_regret`` — achieved/oracle runtime ratio, already a ratio of
      two measurements taken on the same box under the same load regime.
  BENCH_model.json    (``--model-eval``)        gated on
      ``model_frac_of_oracle`` (LOO-CV achieved/oracle speedup of the
      trained model) and ``model_vs_heuristic`` (trained model vs the
      zero-training stand-in on the same corpus) — both ratios of
      measurements from one profiled grid, so host drift cancels.
  BENCH_latency.json  (``--serve-trace``)       gated on
      tail-latency / SLO metrics from the virtual-time trace replay:
      ``deadline_slo_violation_rate``, ``fifo_slo_violation_rate`` and
      ``deadline_p95_latency_ms`` (lower is better),
      ``stationary_refinements`` (a baseline of 0 makes this an
      exact-zero gate: contention must never masquerade as drift on a
      stationary trace), and ``deadline_vs_fifo_violation_improvement``
      (higher is better — EDF + shedding must keep beating FIFO).
      These numbers are deterministic given the seed (no wall clock in
      the loop), so even a tight tolerance is noise-free.
  BENCH_resilience.json (``--serve-chaos``)     gated on
      ``chaos_crashes`` (baseline 0 == exact-zero gate),
      ``chaos_terminal_fraction``, ``chaos_failed_fraction`` and
      ``chaos_slo_violation_delta`` from the fault-injected run of the
      real engine under the committed schedule
      (``benchmarks/data/chaos_faults.json``).
  BENCH_fleet.json    (``--serve-fleet``)       gated on
      ``fleet_scaling_fraction`` — N-worker-process speedup normalized
      by min(N, the same run's measured capacity ceiling), the
      multi-process twin of ``capacity_fraction`` — plus two exact-zero
      gates: ``fleet_worker_crashes`` (unplanned worker deaths) and
      ``fleet_kill_lost_requests`` (requests not terminal after the
      SIGKILL + respawn drill), and ``fleet_kill_terminal_fraction``.
      ``ipc_overhead_fraction`` (share of router wall not covered by
      the busiest worker's engine wall — the data-plane tax; lower is
      better) is gated with an absolute-slack cushion (see ABS_SLACK):
      it is a small absolute fraction, so a pure relative tolerance
      would turn measurement noise on a tiny baseline into a red gate.
      Fleet baselines also arm one STRUCTURAL check: fresh
      ``throughput_rps["2"]`` must be strictly above
      ``throughput_rps["1"]`` — adding the second worker process must
      never make the fleet slower, regardless of what the shared host
      does to the absolute numbers (both sides of the comparison ride
      the same box in the same run).
  BENCH_overhead.json (``--serve-real-trace``)  gated on
      ``python_overhead_fraction`` — coordinator decide+retire wall over
      total wall in the real-engine replay (lower is better).  A ratio
      of two times from the same run, so shared-host drift largely
      cancels; gate it with a loose tolerance anyway — the numerator is
      small and absolute, not seed-deterministic.

A higher-is-better metric regresses when
``fresh < baseline * (1 - tolerance)``; a lower-is-better one when
``fresh > baseline * (1 + tolerance)``.  The default 25% tolerance is
deliberately loose for the same reason the wall-clock metrics are
ratios: this gate exists to catch code-level regressions (a scheduling
bug halving overlap, a refinement loop converging to junk configs), not
to re-measure the neighbors.  Improvements are reported but never fail.
Missing metrics fail loudly — a silently skipped gate is worse than a
red one.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# metric name -> (direction, description); direction is "higher" when
# bigger means healthier, "lower" for latency/violation-style metrics
GATED_METRICS = {
    "capacity_fraction":
        ("higher", "engine speedup / host parallel-capacity ceiling"),
    "mean_regret":
        ("higher", "steady-state achieved/oracle runtime ratio"),
    "model_frac_of_oracle":
        ("higher", "LOO-CV achieved/oracle speedup of the trained model"),
    "model_vs_heuristic":
        ("higher", "trained-model / heuristic achieved speedup on the "
                   "same corpus"),
    "deadline_slo_violation_rate":
        ("lower", "SLO misses (retired late + shed) / deadline-carrying "
                  "requests, deadline policy, bursty trace"),
    "fifo_slo_violation_rate":
        ("lower", "same, fifo policy — the no-admission-control bound"),
    "deadline_p95_latency_ms":
        ("lower", "p95 end-to-end latency, deadline policy, virtual ms"),
    "stationary_refinements":
        ("lower", "drift refinements on a stationary trace (baseline 0 "
                  "== exact-zero gate)"),
    "deadline_vs_fifo_violation_improvement":
        ("higher", "fifo / deadline SLO-violation rate on the same "
                   "trace"),
    "python_overhead_fraction":
        ("lower", "coordinator (decide+retire) wall over total wall in "
                  "the real-engine trace replay — same-run ratio, host "
                  "drift largely cancels"),
    "chaos_crashes":
        ("lower", "scheduler crashes under the committed fault schedule "
                  "(baseline 0 == exact-zero gate: the resilience layer "
                  "must NEVER let an injected fault kill the process)"),
    "chaos_terminal_fraction":
        ("higher", "requests reaching a terminal status (served / "
                   "degraded / failed / timeout) under chaos — a lost "
                   "request is a scheduler bug"),
    "chaos_failed_fraction":
        ("lower", "requests individually failed/timed out under chaos "
                  "— deterministic given the committed fault windows"),
    "chaos_slo_violation_delta":
        ("lower", "SLO-violation rate added by the committed faults vs "
                  "the same run fault-free; gate loosely (thread-timing "
                  "noise), it exists to catch retry storms and "
                  "unrecovered breakers"),
    "fleet_scaling_fraction":
        ("higher", "N-worker-process fleet speedup / min(N, measured "
                   "parallel-capacity ceiling) — the same-run "
                   "normalization that cancels shared-host drift"),
    "fleet_worker_crashes":
        ("lower", "UNplanned worker-process deaths across the fleet "
                  "scaling runs (baseline 0 == exact-zero gate; "
                  "injected SIGKILLs are excluded)"),
    "fleet_kill_lost_requests":
        ("lower", "requests that never reached a terminal status after "
                  "a mid-trace SIGKILL + respawn (baseline 0 == "
                  "exact-zero gate: handoff must requeue everything)"),
    "fleet_kill_terminal_fraction":
        ("higher", "admitted requests reaching a terminal status in the "
                   "SIGKILL drill — the fleet twin of "
                   "chaos_terminal_fraction"),
    "ipc_overhead_fraction":
        ("lower", "fleet data-plane tax at max N: router run wall not "
                  "covered by the busiest worker's engine wall, over "
                  "run wall — dispatch + pickling + collection cost"),
}

# metric -> absolute slack added on top of the relative tolerance when
# computing the bound.  For small absolute fractions (an ipc overhead
# baseline of e.g. 0.05) a pure relative band is narrower than the
# run-to-run noise on a shared CI box; the slack keeps the gate about
# code-level regressions (a reintroduced poll loop, a fat wire format)
# instead of scheduler jitter
ABS_SLACK = {
    "ipc_overhead_fraction": 0.15,
}

# context printed next to the verdict but never gated (absolute numbers
# that legitimately drift with the shared host)
INFO_METRICS = ("speedup", "fleet_speedup", "parallel_capacity", "wall_s")


def gate(fresh: dict, baseline: dict, tolerance: float,
         rows: list | None = None) -> list[str]:
    """Returns a list of failure messages (empty == gate passes).

    ``rows``, when given, collects one
    ``{metric, fresh, baseline, bound, verdict, description}`` dict per
    gated metric — the structured form the CI step-summary table is
    rendered from (stdout keeps the full-precision log lines)."""
    shared = [m for m in GATED_METRICS if baseline.get(m) is not None]
    if not shared:
        return [f"baseline has none of the gated metrics "
                f"{sorted(GATED_METRICS)} — wrong file?"]
    failures = []
    for metric in shared:
        direction, desc = GATED_METRICS[metric]
        base = float(baseline[metric])
        if fresh.get(metric) is None:     # absent OR null (e.g. a trace
            # too short to serve every tenant leaves regret undefined)
            failures.append(f"{metric}: missing from fresh results "
                            f"(baseline {base:.3f})")
            if rows is not None:
                rows.append({"metric": metric, "fresh": None,
                             "baseline": base, "bound": None,
                             "verdict": "MISSING", "description": desc})
            continue
        got = float(fresh[metric])
        slack = ABS_SLACK.get(metric, 0.0)
        if direction == "higher":
            bound = base * (1.0 - tolerance) - slack
            bad = got < bound
            kind, rel = "floor", "<"
        else:
            bound = base * (1.0 + tolerance) + slack
            bad = got > bound
            kind, rel = "ceil", ">"
        verdict = "REGRESSION" if bad else "OK"
        print(f"  {metric:38s} fresh={got:9.4f}  baseline={base:9.4f}  "
              f"{kind}={bound:9.4f}  {verdict}   ({desc})")
        if rows is not None:
            rows.append({"metric": metric, "fresh": got, "baseline": base,
                         "bound": bound, "verdict": verdict,
                         "description": f"{kind} ({direction} is better)"})
        if bad:
            failures.append(
                f"{metric}: {got:.4f} {rel} {bound:.4f} "
                f"(baseline {base:.4f} {'-' if direction == 'higher' else '+'}"
                f" {tolerance:.0%})")
    failures += _structural_checks(fresh, baseline, rows)
    for metric in INFO_METRICS:
        if metric in fresh and metric in baseline \
                and isinstance(fresh[metric], (int, float)) \
                and isinstance(baseline[metric], (int, float)):
            print(f"  {metric:20s} fresh={float(fresh[metric]):7.3f}  "
                  f"baseline={float(baseline[metric]):7.3f}  (info only)")
    return failures


def _structural_checks(fresh: dict, baseline: dict,
                       rows: list | None = None) -> list[str]:
    """Same-run shape invariants, armed by the baseline's artifact kind
    rather than a stored number.  Fleet baselines (those carrying
    ``fleet_scaling_fraction``) require the fresh run's 2-worker
    throughput to be STRICTLY above its 1-worker throughput: both sides
    come from the same box in the same run, so shared-host drift
    cancels and any ratio <= 1 means the second process bought nothing
    — a data-plane regression no relative tolerance should forgive."""
    if baseline.get("fleet_scaling_fraction") is None:
        return []
    rps = fresh.get("throughput_rps") or {}
    if not ({"1", "2"} <= set(rps)):
        return []                     # single-worker run: nothing to compare
    ratio = float(rps["2"]) / max(float(rps["1"]), 1e-12)
    bad = ratio <= 1.0
    verdict = "REGRESSION" if bad else "OK"
    print(f"  {'fleet_throughput_1to2':38s} fresh={ratio:9.4f}  "
          f"baseline={1.0:9.4f}  floor={1.0:9.4f}  {verdict}   "
          f"(2-worker rps / 1-worker rps, strict; structural)")
    if rows is not None:
        rows.append({"metric": "fleet_throughput_1to2", "fresh": ratio,
                     "baseline": 1.0, "bound": 1.0, "verdict": verdict,
                     "description": "strict floor (structural)"})
    if bad:
        return [f"fleet_throughput_1to2: {ratio:.4f} <= 1.0 (2-worker "
                f"throughput must be strictly above 1-worker: "
                f"{float(rps['2']):.1f} vs {float(rps['1']):.1f} rps)"]
    return []


def _fmt(v) -> str:
    return f"{v:.4f}" if isinstance(v, float) else str(v)


def write_step_summary(title: str, rows: list, failures: list[str],
                       path: str) -> None:
    """Append a markdown pass/fail table to ``$GITHUB_STEP_SUMMARY`` —
    one header line + one row per gated metric, so a red gate is
    readable from the Actions summary page without opening raw logs."""
    lines = [f"### {title}", ""]
    lines.append("| metric | fresh | baseline | bound | verdict |")
    lines.append("|---|---|---|---|---|")
    for r in rows:
        icon = {"OK": "✅", "REGRESSION": "❌",
                "MISSING": "❓"}.get(r["verdict"], "")
        lines.append(
            f"| `{r['metric']}` "
            f"| {_fmt(r['fresh']) if r['fresh'] is not None else '—'} "
            f"| {_fmt(r['baseline'])} "
            f"| {_fmt(r['bound']) if r['bound'] is not None else '—'} "
            f"| {icon} {r['verdict']} |")
    lines.append("")
    if failures:
        tripped = ", ".join(f"`{f.split(':', 1)[0]}`" for f in failures)
        lines.append(f"**GATE FAILED** — tripped: {tripped}")
    else:
        lines.append("Gate passed.")
    lines.append("")
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", help="freshly produced benchmark JSON")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional drop below baseline "
                         "(default 0.25)")
    args = ap.parse_args()

    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    print(f"bench-regression gate: {args.fresh} vs {args.baseline} "
          f"(tolerance {args.tolerance:.0%})")
    rows: list = []
    failures = gate(fresh, baseline, args.tolerance, rows=rows)
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        write_step_summary(
            f"{os.path.basename(args.fresh)} vs "
            f"{os.path.basename(args.baseline)} "
            f"(tolerance {args.tolerance:.0%})",
            rows, failures, summary_path)
    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        # name the exact tripped metrics in the last line, so the step's
        # one-line failure annotation says WHAT regressed, not just that
        # something did
        tripped = ", ".join(sorted({f.split(":", 1)[0] for f in failures}))
        print(f"REGRESSION GATE FAILED on: {tripped}", file=sys.stderr)
        return 1
    print("gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
