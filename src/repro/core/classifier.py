"""Back-compat shim: moved to :mod:`repro.core.modeling.classifier`."""
from repro.core.modeling.classifier import KNNClassifier, merge_labels

__all__ = ["KNNClassifier", "merge_labels"]
