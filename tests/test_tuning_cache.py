"""Persistent tuning cache: hit/miss semantics, shape bucketing, JSON
round-trip, and the warm-path speedup contract."""
import time

import numpy as np
import pytest

from repro.core.autotuner import (AutoTuner, TuneResult, TuningCache,
                                  data_signature, shape_bucket)
from repro.core.stream_config import StreamConfig
from repro.core.workloads import get_workload


class _StubModel:
    """Deterministic stand-in for the trained MLP: prefers tasks=4."""

    def predict_configs(self, feats, candidates):
        return np.array([1.0 / (1.0 + abs(c.tasks - 4)) - 0.01 * c.partitions
                         for c in candidates])


def _data(name="vecadd", rows=256, seed=0):
    wl = get_workload(name)
    chunked, shared = wl.make_data(rows, np.random.default_rng(seed))
    return wl, chunked, shared


def test_shape_bucket():
    assert shape_bucket(1) == 1
    assert shape_bucket(2) == 2
    assert shape_bucket(3) == 4
    assert shape_bucket(100) == 128
    assert shape_bucket(128) == 128
    assert shape_bucket(129) == 256


def test_miss_then_hit_same_config(tmp_path):
    wl, chunked, shared = _data()
    cache = TuningCache()
    tuner = AutoTuner(_StubModel(), cache=cache)
    cold = tuner.tune(wl, chunked, shared)
    assert not cold.cached
    assert cache.misses == 1 and cache.hits == 0
    warm = tuner.tune(wl, chunked, shared)
    assert warm.cached
    assert cache.hits == 1
    assert warm.config == cold.config
    assert warm.predicted_speedup == cold.predicted_speedup


def test_same_bucket_shares_entry():
    """Two batches whose leading dims round to the same power of two hit
    one cache entry; a different bucket misses."""
    wl = get_workload("vecadd")
    rng = np.random.default_rng(0)
    c100, s100 = wl.make_data(100, rng)
    c120, s120 = wl.make_data(120, rng)   # bucket 128, same as 100
    c300, s300 = wl.make_data(300, rng)   # bucket 512
    k100 = TuningCache.key(wl.name, c100, s100, "host-sync")
    k120 = TuningCache.key(wl.name, c120, s120, "host-sync")
    k300 = TuningCache.key(wl.name, c300, s300, "host-sync")
    assert k100 == k120
    assert k100 != k300

    cache = TuningCache()
    tuner = AutoTuner(_StubModel(), cache=cache)
    tuner.tune(wl, c100, s100)
    warm = tuner.tune(wl, c120, s120)
    assert warm.cached
    assert not tuner.tune(wl, c300, s300).cached


def test_hit_invalid_for_smaller_batch_retunes():
    """A config tuned on a big batch may not be splittable for a smaller
    batch in the same bucket — the hit must be rejected and re-tuned."""

    class _MaxSplitModel:
        # always prefers the largest partitions*tasks product offered
        def predict_configs(self, feats, candidates):
            return np.array([float(c.partitions * c.tasks)
                             for c in candidates])

    wl = get_workload("vecadd")
    rng = np.random.default_rng(0)
    c2048, s2048 = wl.make_data(2048, rng)
    c1056, s1056 = wl.make_data(1056, rng)   # same bucket (2048)
    assert (TuningCache.key(wl.name, c2048, s2048, "host-sync")
            == TuningCache.key(wl.name, c1056, s1056, "host-sync"))

    cache = TuningCache()
    tuner = AutoTuner(_MaxSplitModel(), cache=cache)
    big = tuner.tune(wl, c2048, s2048)
    assert big.config.partitions * big.config.tasks == 2048
    small = tuner.tune(wl, c1056, s1056)     # hit is unsplittable -> retune
    assert not small.cached
    assert small.config.partitions * small.config.tasks <= 1056
    # the entry now holds the conservative config; both sizes can hit it
    assert tuner.tune(wl, c2048, s2048).cached
    assert tuner.tune(wl, c1056, s1056).cached


def test_key_separates_workload_backend_and_model_tag():
    wl, chunked, shared = _data()
    k_sync = TuningCache.key(wl.name, chunked, shared, "host-sync")
    k_pipe = TuningCache.key(wl.name, chunked, shared, "host-pipelined")
    k_other = TuningCache.key("sgemm", chunked, shared, "host-sync")
    k_v2 = TuningCache.key(wl.name, chunked, shared, "host-sync",
                           model_tag="v2")
    assert len({k_sync, k_pipe, k_other, k_v2}) == 4


def test_explicit_runner_backend_wins():
    """tune(runner=...) caches under the runner's backend, not the
    tuner's default."""
    from repro.core.streams import StreamedRunner
    wl, chunked, shared = _data()
    cache = TuningCache()
    tuner = AutoTuner(_StubModel(), cache=cache)  # default host-sync
    runner = StreamedRunner(wl, chunked, shared, backend="host-pipelined")
    result = tuner.tune(wl, chunked, shared, runner=runner)
    assert result.backend == "host-pipelined"
    # a plain host-sync tune must NOT warm-hit the pipelined entry
    assert not tuner.tune(wl, chunked, shared).cached


def test_rejected_hit_counts_as_miss():
    wl, chunked, shared = _data()
    cache = TuningCache()
    tuner = AutoTuner(_StubModel(), cache=cache)
    tuner.tune(wl, chunked, shared)
    cache.get(cache.key(wl.name, chunked, shared, "host-sync"),
              valid=lambda r: False)
    assert cache.hits == 0 and cache.misses == 2


def test_signature_covers_shared_and_dtype():
    wl, chunked, shared = _data("mvmult", rows=128)
    sig = data_signature(chunked, shared)
    assert "float32" in sig and "v" in sig and "A" in sig
    # inner dims exact, leading dim bucketed
    assert "768" in sig


def test_json_roundtrip_restores_identical_results(tmp_path):
    wl, chunked, shared = _data()
    path = str(tmp_path / "cache.json")
    cache = TuningCache(path)
    tuner = AutoTuner(_StubModel(), cache=cache)
    cold = tuner.tune(wl, chunked, shared)
    cache.save()

    restored = TuningCache(path)            # load happens in __init__
    assert len(restored) == len(cache) == 1
    tuner2 = AutoTuner(_StubModel(), cache=restored)
    warm = tuner2.tune(wl, chunked, shared)
    assert warm.cached
    assert warm.config == cold.config
    assert warm.predicted_speedup == pytest.approx(cold.predicted_speedup)
    assert warm.backend == cold.backend


def test_corrupt_cache_file_degrades_to_cold_start(tmp_path):
    path = tmp_path / "cache.json"
    path.write_text("{not json")
    with pytest.warns(UserWarning, match="unreadable tuning cache"):
        cache = TuningCache(str(path))
    assert len(cache) == 0
    with pytest.raises(Exception):
        cache.load(str(path))  # explicit load still surfaces the error


def test_tuneresult_json_roundtrip():
    r = TuneResult(StreamConfig(3, 24), 1.75, 0.2, 0.001,
                   backend="host-pipelined")
    back = TuneResult.from_json(r.to_json())
    assert back == r


def test_warm_hit_is_100x_faster_and_same_config():
    # a (workload, scale) no other test compiles, so the cold path pays
    # real compile + profile cost the way a fresh serving process would
    wl, chunked, shared = _data("fwt", rows=512, seed=7)
    cache = TuningCache()
    tuner = AutoTuner(_StubModel(), cache=cache)
    t0 = time.perf_counter()
    cold = tuner.tune(wl, chunked, shared)
    t_cold = time.perf_counter() - t0
    t_warm = float("inf")
    for _ in range(5):
        t1 = time.perf_counter()
        warm = tuner.tune(wl, chunked, shared)
        t_warm = min(t_warm, time.perf_counter() - t1)
    assert warm.config == cold.config
    # cold path compiles + profiles the workload; warm is a dict lookup
    assert t_warm < t_cold / 100, (t_cold, t_warm)


def test_uncached_tuner_unchanged():
    """Without a cache the tuner behaves exactly as before."""
    wl, chunked, shared = _data()
    tuner = AutoTuner(_StubModel())
    r1 = tuner.tune(wl, chunked, shared)
    r2 = tuner.tune(wl, chunked, shared)
    assert not r1.cached and not r2.cached
    assert r1.config == r2.config  # deterministic stub + stable search
