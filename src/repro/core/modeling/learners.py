"""Alternative learners for the Table-5 comparison: a CART regression
tree, a bagged random forest, and RBF kernel ridge regression (the
closed-form stand-in for the paper's SVR — no sklearn offline).  All
share the :class:`~repro.core.modeling.pipeline.FeaturePipeline` front
end and the :class:`~repro.core.modeling.base.EstimatorBase` surface, so
they serve, fork, and round-trip through artifacts exactly like the MLP.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.modeling.base import EstimatorBase, register_estimator
from repro.core.modeling.pipeline import FeaturePipeline

__all__ = ["TreeRegressor", "ForestRegressor", "KernelRidgeRBF"]


@dataclasses.dataclass
class _TreeNode:
    feature: int = -1
    thresh: float = 0.0
    value: float = 0.0
    left: Optional["_TreeNode"] = None
    right: Optional["_TreeNode"] = None


def _build_tree(X, y, depth, min_leaf=8) -> _TreeNode:
    node = _TreeNode(value=float(y.mean()))
    if depth == 0 or len(y) < 2 * min_leaf or y.std() < 1e-9:
        return node
    best = (None, None, np.inf)
    n_feat = X.shape[1]
    for j in range(n_feat):
        order = np.argsort(X[:, j])
        xs, ys = X[order, j], y[order]
        csum = np.cumsum(ys)
        csq = np.cumsum(ys ** 2)
        total, total_sq = csum[-1], csq[-1]
        for i in range(min_leaf, len(ys) - min_leaf):
            if xs[i] == xs[i - 1]:
                continue
            nl, nr = i, len(ys) - i
            sl, sr = csum[i - 1], total - csum[i - 1]
            ql, qr = csq[i - 1], total_sq - csq[i - 1]
            sse = (ql - sl**2 / nl) + (qr - sr**2 / nr)
            if sse < best[2]:
                best = (j, (xs[i] + xs[i - 1]) / 2, sse)
    if best[0] is None:
        return node
    j, t, _ = best
    mask = X[:, j] <= t
    node.feature, node.thresh = j, t
    node.left = _build_tree(X[mask], y[mask], depth - 1, min_leaf)
    node.right = _build_tree(X[~mask], y[~mask], depth - 1, min_leaf)
    return node


def _tree_predict_one(node: _TreeNode, x) -> float:
    while node.feature >= 0:
        node = node.left if x[node.feature] <= node.thresh else node.right
    return node.value


def _tree_to_arrays(root: _TreeNode, prefix: str) -> dict:
    """Preorder-flattened node table: parallel arrays of (feature,
    thresh, value, left, right) with -1 child indices at leaves."""
    feature, thresh, value, left, right = [], [], [], [], []

    def visit(node: _TreeNode) -> int:
        idx = len(feature)
        feature.append(node.feature)
        thresh.append(node.thresh)
        value.append(node.value)
        left.append(-1)
        right.append(-1)
        if node.left is not None:
            left[idx] = visit(node.left)
        if node.right is not None:
            right[idx] = visit(node.right)
        return idx

    visit(root)
    return {
        f"{prefix}feature": np.asarray(feature, np.int64),
        f"{prefix}thresh": np.asarray(thresh, np.float64),
        f"{prefix}value": np.asarray(value, np.float64),
        f"{prefix}left": np.asarray(left, np.int64),
        f"{prefix}right": np.asarray(right, np.int64),
    }


def _tree_from_arrays(arrays: dict, prefix: str) -> _TreeNode:
    feature = arrays[f"{prefix}feature"]
    thresh = arrays[f"{prefix}thresh"]
    value = arrays[f"{prefix}value"]
    left = arrays[f"{prefix}left"]
    right = arrays[f"{prefix}right"]

    def build(idx: int) -> _TreeNode:
        node = _TreeNode(int(feature[idx]), float(thresh[idx]),
                         float(value[idx]))
        if left[idx] >= 0:
            node.left = build(int(left[idx]))
        if right[idx] >= 0:
            node.right = build(int(right[idx]))
        return node

    return build(0)


@register_estimator
@dataclasses.dataclass
class TreeRegressor(EstimatorBase):
    pipeline: FeaturePipeline
    root: _TreeNode

    kind = "cart"

    @staticmethod
    def train(X_raw, y, *, depth=10, n_components=9,
              max_rows=4000, seed=0) -> "TreeRegressor":
        pipe = FeaturePipeline.fit(X_raw, y, n_components=n_components)
        X = pipe.transform(X_raw)
        yn = pipe.transform_y(y)
        if len(yn) > max_rows:
            idx = np.random.default_rng(seed).choice(
                len(yn), max_rows, replace=False)
            X, yn = X[idx], yn[idx]
        root = _build_tree(X, yn, depth)
        return TreeRegressor(pipe, root)

    def predict(self, X_raw) -> np.ndarray:
        X = self.pipeline.transform(np.atleast_2d(X_raw))
        yn = np.array([_tree_predict_one(self.root, x) for x in X])
        return self.pipeline.inverse_y(yn)

    def to_state(self) -> tuple[dict, dict]:
        arrays = self.pipeline.to_arrays()
        arrays.update(_tree_to_arrays(self.root, "tree."))
        return arrays, {}

    @classmethod
    def from_state(cls, arrays: dict, extras: dict) -> "TreeRegressor":
        return cls(FeaturePipeline.from_arrays(arrays),
                   _tree_from_arrays(arrays, "tree."))


@register_estimator
@dataclasses.dataclass
class ForestRegressor(EstimatorBase):
    pipeline: FeaturePipeline
    roots: list

    kind = "forest"

    @staticmethod
    def train(X_raw, y, *, n_trees=5, depth=8, n_components=9,
              max_rows=2000, seed=0) -> "ForestRegressor":
        pipe = FeaturePipeline.fit(X_raw, y, n_components=n_components)
        X = pipe.transform(X_raw)
        yn = pipe.transform_y(y)
        rng = np.random.default_rng(seed)
        roots = []
        for _ in range(n_trees):
            idx = rng.integers(0, len(yn), min(len(yn), max_rows))
            roots.append(_build_tree(X[idx], yn[idx], depth))
        return ForestRegressor(pipe, roots)

    def predict(self, X_raw) -> np.ndarray:
        X = self.pipeline.transform(np.atleast_2d(X_raw))
        yn = np.mean([[_tree_predict_one(r, x) for x in X]
                      for r in self.roots], axis=0)
        return self.pipeline.inverse_y(yn)

    def to_state(self) -> tuple[dict, dict]:
        arrays = self.pipeline.to_arrays()
        for i, root in enumerate(self.roots):
            arrays.update(_tree_to_arrays(root, f"tree{i}."))
        return arrays, {"n_trees": len(self.roots)}

    @classmethod
    def from_state(cls, arrays: dict, extras: dict) -> "ForestRegressor":
        roots = [_tree_from_arrays(arrays, f"tree{i}.")
                 for i in range(int(extras["n_trees"]))]
        return cls(FeaturePipeline.from_arrays(arrays), roots)


@register_estimator
@dataclasses.dataclass
class KernelRidgeRBF(EstimatorBase):
    """RBF kernel ridge regression — closed-form SVR stand-in (no sklearn
    offline; documented substitution for the paper's SVM regressor)."""

    pipeline: FeaturePipeline
    X_train: np.ndarray
    alpha: np.ndarray
    gamma: float

    kind = "krr"

    @staticmethod
    def train(X_raw, y, *, lam=1e-2, gamma=None,
              n_components=9, max_train=3000, seed=0) -> "KernelRidgeRBF":
        pipe = FeaturePipeline.fit(X_raw, y, n_components=n_components)
        X = pipe.transform(X_raw)
        yn = pipe.transform_y(y)
        if len(yn) > max_train:
            rng = np.random.default_rng(seed)
            idx = rng.choice(len(yn), max_train, replace=False)
            X, yn = X[idx], yn[idx]
        gamma = gamma or 1.0 / X.shape[1]
        K = _rbf(X, X, gamma)
        alpha = np.linalg.solve(K + lam * np.eye(len(yn)), yn)
        return KernelRidgeRBF(pipe, X, alpha, gamma)

    def predict(self, X_raw) -> np.ndarray:
        X = self.pipeline.transform(np.atleast_2d(X_raw))
        yn = _rbf(X, self.X_train, self.gamma) @ self.alpha
        return self.pipeline.inverse_y(yn)

    def to_state(self) -> tuple[dict, dict]:
        arrays = self.pipeline.to_arrays()
        arrays["krr.X_train"] = np.asarray(self.X_train, np.float64)
        arrays["krr.alpha"] = np.asarray(self.alpha, np.float64)
        arrays["krr.gamma"] = np.asarray(self.gamma, np.float64)
        return arrays, {}

    @classmethod
    def from_state(cls, arrays: dict, extras: dict) -> "KernelRidgeRBF":
        return cls(FeaturePipeline.from_arrays(arrays),
                   arrays["krr.X_train"], arrays["krr.alpha"],
                   float(arrays["krr.gamma"]))


def _rbf(A, B, gamma):
    d2 = (np.sum(A**2, 1)[:, None] + np.sum(B**2, 1)[None, :]
          - 2 * A @ B.T)
    return np.exp(-gamma * np.maximum(d2, 0.0))
