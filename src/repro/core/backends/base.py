"""Executor-backend protocol: how a (partitions, tasks) stream config is
realized on a concrete substrate.

A backend receives an :class:`ExecutionContext` — the immutable per-run
state (kernel, host data, device, jitted callables, resident shared
buffers) — and a :class:`~repro.core.stream_config.StreamConfig`, and
returns the list of per-slice outputs in deterministic (task-major,
partition-minor) order.  That ordering contract is what makes every
backend comparable against the single-stream reference: concatenating the
outputs along axis 0 must reproduce the unsplit result for ``concat``
workloads.

Two backend kinds exist:
  * ``runner``     — drives a chunkable data-parallel kernel
                     (``dispatch`` is the entry point);
  * ``train-step`` — rewrites a training step into a streamed equivalent
                     (``wrap_train_step`` is the entry point).
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Any, Callable, Optional

import jax
import numpy as np

# Process-wide jit memo: serving creates one ExecutionContext per request,
# and a fresh ``jax.jit(kernel)`` wrapper per request would recompile every
# shape it has already seen.  Workload kernels are module-level callables
# with stable identity, so memoizing the wrapper by kernel shares the trace
# cache across contexts (and across requests for the whole process).
# Bounded with FIFO eviction: a jitted wrapper strongly references its
# kernel, so a weak-keyed map would never collect entries anyway, and
# callers jitting dynamically created closures must not grow the memo (and
# every compiled executable behind it) without bound.
_JIT_MEMO: dict = {}
_JIT_MEMO_MAX = 256


def memoized_jit(kernel: Callable, *, donate: bool = False) -> Callable:
    """``jax.jit(kernel)`` with the wrapper shared across ExecutionContexts."""
    try:
        entry = _JIT_MEMO.get(kernel)
    except TypeError:          # unhashable callable: no memoization
        return (jax.jit(kernel, donate_argnums=0) if donate
                else jax.jit(kernel))
    if entry is None:
        while len(_JIT_MEMO) >= _JIT_MEMO_MAX:
            _JIT_MEMO.pop(next(iter(_JIT_MEMO)))
        entry = _JIT_MEMO[kernel] = {}
    key = "donate" if donate else "plain"
    if key not in entry:
        entry[key] = (jax.jit(kernel, donate_argnums=0) if donate
                      else jax.jit(kernel))
    return entry[key]


def split_arrays(arrs: dict, n: int) -> list[dict]:
    """Split every array in the dict into n chunks along axis 0."""
    if n == 1:
        return [arrs]
    keys = list(arrs)
    pieces = {k: np.array_split(arrs[k], n) for k in keys}
    return [{k: pieces[k][i] for k in keys} for i in range(n)]


@dataclasses.dataclass
class ExecutionContext:
    """Per-(workload, dataset) state shared by every runner backend."""

    kernel: Callable
    chunked: dict
    shared: dict
    device: Any
    jit_kernel: Callable
    shared_dev: Any
    _donating_jit: Optional[Callable] = None

    @classmethod
    def create(cls, kernel: Callable, chunked: dict, shared: dict,
               device=None) -> "ExecutionContext":
        device = device or jax.devices()[0]
        # buffer-validity tracking (paper §4.4.5): shared buffers are
        # transferred once and stay resident across tasks and runs.
        shared_dev = jax.device_put(shared, device)
        jax.block_until_ready(shared_dev)
        return cls(kernel=kernel, chunked=chunked, shared=shared,
                   device=device, jit_kernel=memoized_jit(kernel),
                   shared_dev=shared_dev)

    @property
    def donating_jit(self) -> Callable:
        """Kernel jitted with the chunk argument donated, so a finished
        task's device buffers are recycled for its outputs (no-op on
        backends without donation support, e.g. CPU)."""
        if self._donating_jit is None:
            self._donating_jit = memoized_jit(self.kernel, donate=True)
        return self._donating_jit


class StreamBackend(abc.ABC):
    """One realization of the streamed-execution strategy."""

    #: unique registry key
    name: str = ""
    #: "runner" (chunkable kernels) or "train-step" (training loops)
    kind: str = "runner"

    def dispatch(self, ctx: ExecutionContext, config) -> list:
        """Issue the full iteration space under ``config``; returns the
        per-slice outputs (possibly still in flight — callers block)."""
        raise NotImplementedError(f"{self.name} is not a runner backend")

    def wrap_train_step(self, loss_fn: Callable, config, *,
                        unroll: bool = True) -> Callable:
        """Rewrite ``loss_fn(params, batch) -> (loss, aux)`` into a
        streamed step function."""
        raise NotImplementedError(f"{self.name} is not a train-step backend")

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<StreamBackend {self.name} ({self.kind})>"
