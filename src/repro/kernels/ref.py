"""Pure-jnp oracles for every Pallas kernel (shape/dtype-sweep targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import reference_attention


def flash_attention_ref(q, k, v, *, causal: bool = True) -> jax.Array:
    """Naive attention oracle (B,Sq,H,hd) x (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    return reference_attention(q, k, v, causal=causal)


def rmsnorm_ref(x, scale, *, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(
        x.dtype)
