"""Concurrent serving engine: serial-vs-concurrent equivalence (same
trace → same configs, allclose outputs, identical telemetry count),
deterministic per-bucket retirement under ANY completion order, the
batched cold-path model search, pooled ExecutionContexts, and the
memoized dispatch-plan cache."""
import itertools

import numpy as np
import pytest

from repro.core.backends import (ExecutionContext, dispatch_plan,
                                 get_backend, split_arrays)
from repro.core.stream_config import SINGLE_STREAM, StreamConfig
from repro.core.streams import StreamedRunner
from repro.core.workloads import Workload, get_workload
from repro.serving import (AdaptiveScheduler, ConcurrentScheduler,
                           ContextPool, DriftDetector,
                           OverlapHeuristicModel, OrderedRetirer,
                           WorkloadRequest, make_trace)

WORKLOADS = ["vecadd", "dotprod", "mvmult"]


class _BatchedStub:
    """Deterministic constant predictor that records every call's feature
    batch size; works for (F,) and (B, F) inputs like the real models."""

    def __init__(self):
        self.calls = []

    def predict_configs(self, feats, candidates):
        F = np.atleast_2d(np.asarray(feats))
        self.calls.append(F.shape[0])
        preds = np.ones((F.shape[0], len(candidates)))
        return preds[0] if np.ndim(feats) == 1 else preds


def _req(workload="vecadd", rows=256, seed=0, **kw):
    wl = get_workload(workload)
    chunked, shared = wl.make_data(rows, np.random.default_rng(seed))
    return WorkloadRequest(workload=workload, chunked=chunked,
                          shared=shared, **kw)


def _lenient_drift():
    return DriftDetector(threshold=1e9)


def _concat(outputs):
    return np.concatenate([np.asarray(o) for o in outputs], axis=0)


# -- serial vs concurrent equivalence ----------------------------------------


def test_concurrent_matches_serial_end_to_end():
    """Same trace through both engines: identical per-request configs and
    cache-hit pattern, allclose outputs, identical telemetry count, and
    results returned in decision order."""
    serial = AdaptiveScheduler(_BatchedStub(), drift=_lenient_drift())
    conc = ConcurrentScheduler(_BatchedStub(), window=4,
                               drift=_lenient_drift())
    serial.submit_all(make_trace(WORKLOADS, occurrences=3, seed=0))
    conc.submit_all(make_trace(WORKLOADS, occurrences=3, seed=0))
    rs, rc = serial.run(), conc.run()

    assert len(rs) == len(rc) == 9
    assert [r.config for r in rc] == [r.config for r in rs]
    assert [r.cache_hit for r in rc] == [r.cache_hit for r in rs]
    assert len(conc.telemetry) == len(serial.telemetry) == 9
    # decision order: results line up with the trace's arrival sequence
    assert [r.request.seq for r in rc] == [r.request.seq for r in rs]
    for a, b in zip(rs, rc):
        np.testing.assert_allclose(
            _concat(b.outputs), _concat(a.outputs), rtol=2e-4, atol=1e-3,
            err_msg=a.request.workload)
    assert conc.stats["requests"] == 9
    assert conc.stats["batched_searches"] >= 1


def test_window_one_degenerates_to_serial():
    serial = AdaptiveScheduler(_BatchedStub(), drift=_lenient_drift())
    conc = ConcurrentScheduler(_BatchedStub(), window=1,
                               drift=_lenient_drift())
    serial.submit_all([_req(seed=s) for s in range(3)])
    conc.submit_all([_req(seed=s) for s in range(3)])
    rs, rc = serial.run(), conc.run()
    assert [r.config for r in rc] == [r.config for r in rs]
    assert [r.cache_hit for r in rc] == [r.cache_hit for r in rs]
    for a, b in zip(rs, rc):
        np.testing.assert_allclose(_concat(b.outputs), _concat(a.outputs),
                                   rtol=2e-4, atol=1e-3)


def test_concurrent_respects_queue_policy_order():
    conc = ConcurrentScheduler(_BatchedStub(), window=2, policy="priority",
                               drift=_lenient_drift())
    conc.submit(_req(tenant="background", priority=0, seed=0))
    conc.submit(_req(tenant="interactive", priority=9, seed=1))
    results = conc.run()
    assert [r.request.tenant for r in results] == ["interactive",
                                                   "background"]


def test_concurrent_max_requests_budget():
    conc = ConcurrentScheduler(_BatchedStub(), window=4,
                               drift=_lenient_drift())
    conc.submit_all([_req(seed=s) for s in range(5)])
    first = conc.run(max_requests=2)
    assert len(first) == 2 and len(conc.queue) == 3
    rest = conc.run()
    assert len(rest) == 3 and not conc.queue


# -- out-of-order retirement determinism -------------------------------------


def test_ordered_retirer_deterministic_under_any_completion_order():
    """For a fixed dispatch sequence, EVERY completion permutation flushes
    each bucket's payloads in that bucket's dispatch order."""
    dispatch = ["a", "a", "b", "a", "b"]
    for perm in itertools.permutations(range(len(dispatch))):
        retirer = OrderedRetirer()
        issued = [(key, retirer.issue(key)) for key in dispatch]
        flushed: dict[str, list] = {"a": [], "b": []}
        for i in perm:
            key, idx = issued[i]
            flushed[key].extend(retirer.complete(key, idx, (key, idx)))
        assert retirer.held == 0
        assert flushed["a"] == [("a", 0), ("a", 1), ("a", 2)]
        assert flushed["b"] == [("b", 0), ("b", 1)]


def test_per_bucket_telemetry_follows_dispatch_order():
    """One bucket, tenants stamped in arrival order: even with 4 requests
    in flight, the bucket's telemetry sequence is its dispatch order."""
    conc = ConcurrentScheduler(_BatchedStub(), window=4,
                               drift=_lenient_drift())
    conc.submit_all([_req(tenant=f"t{i}", seed=i) for i in range(8)])
    conc.run()
    assert [s.tenant for s in conc.telemetry] == [f"t{i}" for i in range(8)]
    # telemetry seq is retirement order: strictly increasing, no gaps
    assert [s.seq for s in conc.telemetry] == list(range(1, 9))


def test_failed_execution_releases_resources_and_bucket():
    """A raised execute must not poison its bucket or leak contexts:
    survivors retire, the error propagates, and the engine stays
    serviceable for the next run."""
    class Flaky(ConcurrentScheduler):
        def _execute(self, pending):
            if pending.req.tenant == "boom":
                raise RuntimeError("injected")
            return super()._execute(pending)

    eng = Flaky(_BatchedStub(), window=4, drift=_lenient_drift())
    eng.submit_all([_req(tenant="ok0", seed=0), _req(tenant="boom", seed=1),
                    _req(tenant="ok1", seed=2), _req(tenant="ok2", seed=3)])
    with pytest.raises(RuntimeError, match="injected"):
        eng.run()
    assert eng.retirer.held == 0
    served = {s.tenant for s in eng.telemetry}
    assert "boom" not in served
    assert {"ok0", "ok1", "ok2"} <= served       # same-bucket survivors
    # leased contexts all came back: the pool can serve again
    eng.submit(_req(tenant="after", seed=4))
    (res,) = eng.run()
    assert res.request.tenant == "after" and res.cache_hit


# -- batched cold path --------------------------------------------------------


def test_cold_window_uses_one_batched_search():
    """Three cold buckets decided in one window fill → exactly ONE
    predict_configs call carrying a (3, F) feature matrix."""
    model = _BatchedStub()
    conc = ConcurrentScheduler(model, window=4, drift=_lenient_drift())
    conc.submit_all(make_trace(WORKLOADS, occurrences=1, seed=0))
    results = conc.run()
    assert len(results) == 3
    assert model.calls == [3]
    assert conc.stats["batched_searches"] == 1
    assert conc.stats["batched_search_programs"] == 3
    assert conc.stats["model_searches"] == 1


def test_batched_cold_duplicates_share_the_entry():
    """Two same-bucket requests in one cold window: one feature
    extraction, the duplicate becomes a warm hit on the fresh entry."""
    model = _BatchedStub()
    conc = ConcurrentScheduler(model, window=4, drift=_lenient_drift())
    conc.submit_all([_req(seed=0), _req(seed=1), _req("dotprod", seed=2)])
    results = conc.run()
    assert model.calls == [2]          # vecadd + dotprod buckets only
    assert [r.cache_hit for r in results] == [False, True, False]
    assert results[1].config == results[0].config


def test_batched_infeasible_candidates_fall_back_to_single_stream():
    conc = ConcurrentScheduler(_BatchedStub(), window=4,
                               candidates=[StreamConfig(32, 64)],
                               drift=_lenient_drift())
    conc.submit_all([_req(rows=16, seed=0), _req("dotprod", rows=16,
                                                 seed=1)])
    results = conc.run()
    assert all(r.config == SINGLE_STREAM for r in results)
    assert _concat(results[0].outputs).shape[0] == 16


def test_heuristic_model_batched_matches_per_row():
    rng = np.random.default_rng(0)
    feats = rng.uniform(1.0, 1000.0, size=(4, 22))
    cands = [StreamConfig(1, 1), StreamConfig(1, 4), StreamConfig(2, 8),
             StreamConfig(8, 64)]
    m = OverlapHeuristicModel()
    batched = m.predict_configs(feats, cands)
    assert batched.shape == (4, len(cands))
    for b in range(4):
        np.testing.assert_allclose(batched[b],
                                   m.predict_configs(feats[b], cands))


# -- pooled execution contexts ------------------------------------------------


def test_context_pool_reuses_and_swaps_shared_buffers():
    pool = ContextPool()
    wl = get_workload("mvmult")
    backend = get_backend("host-sync")
    rng = np.random.default_rng(0)

    c1, s1 = wl.make_data(128, rng)
    ctx1 = pool.lease(wl, c1, s1)
    out1 = _concat(backend.dispatch(ctx1, StreamConfig(1, 2)))
    np.testing.assert_allclose(out1, c1["A"] @ s1["v"], rtol=2e-4,
                               atol=1e-3)
    pool.release(wl.name, ctx1)

    c2, s2 = wl.make_data(128, rng)
    ctx2 = pool.lease(wl, c2, s2)
    assert ctx2 is ctx1 and pool.reuses == 1       # recycled, not rebuilt
    out2 = _concat(backend.dispatch(ctx2, StreamConfig(1, 2)))
    # the swapped-in shared buffer serves the NEW request's v, not stale
    np.testing.assert_allclose(out2, c2["A"] @ s2["v"], rtol=2e-4,
                               atol=1e-3)


def test_context_pool_empty_shared_swap_skips_upload():
    pool = ContextPool()
    wl = get_workload("vecadd")
    c1, s1 = wl.make_data(64, np.random.default_rng(0))
    ctx = pool.lease(wl, c1, s1)
    pool.release(wl.name, ctx)
    c2, s2 = wl.make_data(64, np.random.default_rng(1))
    ctx2 = pool.lease(wl, c2, s2)
    assert ctx2 is ctx and ctx2.shared_dev == {}
    out = _concat(get_backend("host-sync").dispatch(ctx2, SINGLE_STREAM))
    np.testing.assert_allclose(out, c2["a"] + c2["b"], rtol=2e-4,
                               atol=1e-3)


def test_concurrent_leases_are_distinct_contexts():
    pool = ContextPool()
    wl = get_workload("vecadd")
    c1, s1 = wl.make_data(64, np.random.default_rng(0))
    c2, s2 = wl.make_data(64, np.random.default_rng(1))
    ctx1 = pool.lease(wl, c1, s1)
    ctx2 = pool.lease(wl, c2, s2)        # ctx1 not released: new context
    assert ctx1 is not ctx2
    assert pool.leases == 2 and pool.reuses == 0


# -- dispatch-plan cache ------------------------------------------------------


@pytest.mark.parametrize("n_rows,config", [
    (13, StreamConfig(3, 2)),
    (100, StreamConfig(2, 3)),
    (7, StreamConfig(1, 7)),
    (64, StreamConfig(4, 8)),
])
def test_dispatch_plan_matches_nested_array_split(n_rows, config):
    x = np.arange(n_rows)
    expect = []
    for task in np.array_split(x, config.tasks):
        expect.extend(np.array_split(task, config.partitions))
    plan = dispatch_plan(n_rows, config)
    got = [x[lo:hi] for parts in plan for lo, hi in parts]
    assert len(got) == len(expect)
    for g, e in zip(got, expect):
        np.testing.assert_array_equal(g, e)
    assert dispatch_plan(n_rows, config) is plan      # memoized


def test_backends_equivalent_on_non_divisible_rows():
    wl = get_workload("vecadd")
    chunked, shared = wl.make_data(100, np.random.default_rng(0))
    ref = None
    for name in ("host-sync", "host-pipelined", "host-threads"):
        runner = StreamedRunner(wl, chunked, shared, backend=name)
        got = _concat(runner.dispatch(StreamConfig(2, 3)))
        if ref is None:
            ref = _concat(runner.dispatch(SINGLE_STREAM))
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=1e-3,
                                   err_msg=name)


# -- full-leaf D2H read-back --------------------------------------------------


def test_run_materializes_all_output_leaves():
    """A multi-output kernel must round-trip every leaf through the host
    during a timed run (the old read-back touched only the first)."""
    import jax.numpy as jnp

    wl = Workload(
        "multi-out-local", "test",
        kernel=lambda c, s: {"doubled": c["x"] * 2.0,
                             "summed": jnp.sum(c["x"], axis=1)},
        make_data=lambda n, rng: (
            {"x": rng.standard_normal((n, 8)).astype(np.float32)}, {}),
        datasets=(32,))
    chunked, shared = wl.make_data(32, np.random.default_rng(0))
    runner = StreamedRunner(wl, chunked, shared)
    t = runner.run(StreamConfig(1, 2), reps=1)
    assert np.isfinite(t) and t > 0
    outs = runner.dispatch(StreamConfig(1, 2))
    got = np.concatenate([np.asarray(o["doubled"]) for o in outs], axis=0)
    np.testing.assert_allclose(got, chunked["x"] * 2.0, rtol=1e-6,
                               atol=1e-6)


def test_split_arrays_still_exported():
    # back-compat: older callers split dicts directly
    parts = split_arrays({"x": np.arange(10)}, 3)
    assert [len(p["x"]) for p in parts] == [4, 3, 3]


def test_execution_context_swap_rebinds_chunked():
    wl = get_workload("vecadd")
    c1, s1 = wl.make_data(32, np.random.default_rng(0))
    ctx = ExecutionContext.create(wl.kernel, c1, s1, None)
    c2, s2 = wl.make_data(32, np.random.default_rng(1))
    ctx.swap_buffers(c2, s2)
    assert ctx.chunked is c2 and ctx.shared is s2


def test_engine_latency_stamps_monotone():
    """Every retired request carries the full enqueue→decide→dispatch→
    retire stamp chain on one clock, in order, with latency_s equal to
    the retire-minus-enqueue span — the trace harness and the SLO
    accounting both lean on these invariants."""
    from repro.serving import TelemetryLog
    eng = ConcurrentScheduler(_BatchedStub(), window=3,
                              drift=_lenient_drift(),
                              telemetry=TelemetryLog(),
                              keep_outputs=False)
    with eng:
        eng.submit_all(make_trace(WORKLOADS, occurrences=2, seed=0))
        eng.run()
    assert len(eng.telemetry) == 2 * len(WORKLOADS)
    for s in eng.telemetry:
        assert s.t_enqueue_s is not None
        assert s.t_enqueue_s <= s.t_decide_s <= s.t_dispatch_s \
            <= s.t_retire_s
        assert s.latency_s == pytest.approx(s.t_retire_s - s.t_enqueue_s)
        assert s.latency_s >= s.measured_s
        assert s.queue_depth >= 0
