"""Per-arch smoke tests (REQUIRED): reduced same-family config, one
forward + one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import list_archs
from repro.models.model_zoo import build_model
from repro.models.transformer import RunConfig
from repro.optim import optimizer as opt_lib

B, S = 2, 16

# the huge-config archs dominate CPU wall-clock; they run in the slow tier
_HEAVY = {"jamba-1.5-large-398b", "arctic-480b", "grok-1-314b"}


def _arch_params(archs, heavy=_HEAVY):
    return [pytest.param(a, marks=pytest.mark.slow) if a in heavy else a
            for a in archs]


def _batch(cfg, key):
    ks = jax.random.split(key, 2)
    b = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)}
    if cfg.frontend:
        b["embeds"] = jax.random.normal(
            ks[0], (B, S, cfg.frontend_dim), jnp.float32)
    return b


@pytest.mark.parametrize("arch", _arch_params(list_archs()))
def test_forward_shapes_and_finite(arch):
    m = build_model(arch, reduced=True)
    params, axes = m.init(jax.random.key(0))
    batch = _batch(m.cfg, jax.random.key(1))
    logits = m.forward_logits(params, batch)
    assert logits.shape == (B, S, m.cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch


# forward smoke stays fast for every arch; the costlier train-step check
# keeps one representative per family fast and defers the rest
@pytest.mark.parametrize("arch", _arch_params(
    list_archs(),
    heavy=_HEAVY | {"codeqwen1.5-7b", "pixtral-12b", "musicgen-medium",
                    "stablelm-3b"}))
def test_train_step_no_nans(arch):
    m = build_model(arch, reduced=True)
    params, _ = m.init(jax.random.key(0))
    batch = _batch(m.cfg, jax.random.key(1))
    ocfg = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt_state = opt_lib.init_state(params, ocfg)

    @jax.jit
    def step(p, o, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda pp: m.loss(pp, b), has_aux=True)(p)
        p, o, om = opt_lib.apply_updates(p, grads, o, ocfg)
        return p, o, loss, om

    p1, o1, loss, om = step(params, opt_state, batch)
    assert bool(jnp.isfinite(loss)), arch
    assert float(om["grad_norm"]) > 0
    for leaf in jax.tree.leaves(p1):
        assert bool(jnp.isfinite(leaf).all()), arch


@pytest.mark.parametrize("arch", [
    "yi-9b",
    pytest.param("jamba-1.5-large-398b", marks=pytest.mark.slow),
    pytest.param("xlstm-350m", marks=pytest.mark.slow),
    pytest.param("musicgen-medium", marks=pytest.mark.slow),
])
def test_two_steps_reduce_loss(arch):
    """A couple of steps on a repeated batch must reduce the loss."""
    m = build_model(arch, reduced=True)
    params, _ = m.init(jax.random.key(0))
    batch = _batch(m.cfg, jax.random.key(1))
    ocfg = opt_lib.AdamWConfig(lr=5e-3, warmup_steps=1, total_steps=100,
                               weight_decay=0.0)
    opt = opt_lib.init_state(params, ocfg)

    @jax.jit
    def step(p, o):
        (loss, _), grads = jax.value_and_grad(
            lambda pp: m.loss(pp, batch), has_aux=True)(p)
        p, o, _ = opt_lib.apply_updates(p, grads, o, ocfg)
        return p, o, loss

    losses = []
    for _ in range(8):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


@pytest.mark.slow
def test_remat_matches_no_remat():
    m0 = build_model("yi-9b", RunConfig(remat="none"), reduced=True)
    m1 = build_model("yi-9b", RunConfig(remat="full"), reduced=True)
    params, _ = m0.init(jax.random.key(0))
    batch = _batch(m0.cfg, jax.random.key(1))
    g0 = jax.grad(lambda p: m0.loss(p, batch)[0])(params)
    g1 = jax.grad(lambda p: m1.loss(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        assert jnp.allclose(a, b, atol=1e-5), "remat changed gradients"


def test_unscanned_matches_scanned():
    m0 = build_model("yi-9b", RunConfig(scan_layers=True), reduced=True)
    m1 = build_model("yi-9b", RunConfig(scan_layers=False), reduced=True)
    params, _ = m0.init(jax.random.key(0))
    batch = _batch(m0.cfg, jax.random.key(1))
    l0 = m0.forward_logits(params, batch)
    l1 = m1.forward_logits(params, batch)
    assert jnp.allclose(l0, l1, atol=1e-5)
