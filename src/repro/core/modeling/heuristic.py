"""The zero-training overlap heuristic — the explicit fallback estimator.

Historically this lived inside the serving scheduler as the stand-in for
a trained model; serving now loads a trained artifact by default and the
heuristic is demoted to an opt-in fallback (``serve.py --model
heuristic``) and the no-training baseline the benchmark harness scores
the learnt model against.
"""
from __future__ import annotations

import numpy as np

from repro.core import features as feat_lib
from repro.core.features import RAW_FEATURE_NAMES
from repro.core.modeling.base import EstimatorBase, register_estimator

_I_T_XFER = RAW_FEATURE_NAMES.index("t_transfer_us")
_I_T_COMP = RAW_FEATURE_NAMES.index("t_compute_us")


@register_estimator
class OverlapHeuristicModel(EstimatorBase):
    """Zero-training stand-in for a trained :class:`PerformanceModel`.

    Scores each candidate with the classic streams overlap bound: with
    ``n`` tasks the makespan is the dominant phase plus ``1/n`` of the
    overlapped phase plus a per-dispatch overhead that grows with
    partitions × tasks.  Deterministic given the extracted features, so
    smoke paths that opt into it (``--model heuristic``) need no
    training set.

    Fully vectorized: the candidate grid is scored as numpy arrays (the
    ``(partitions, tasks)`` columns are memoized per grid), and a
    ``(B, F)`` feature matrix scores ``B`` programs in one call — the
    same batched contract as :meth:`PerformanceModel.predict_configs`.
    """

    kind = "heuristic"

    def __init__(self, overhead_s: float = 30e-6):
        self.overhead_s = overhead_s

    def predict_configs(self, prog_feats: np.ndarray,
                        configs) -> np.ndarray:
        P = np.atleast_2d(np.asarray(prog_feats, dtype=np.float64))
        t_comp = P[:, _I_T_COMP, None] * 1e-6          # (B, 1)
        t_xfer = P[:, _I_T_XFER, None] * 1e-6
        base = np.maximum(t_comp + t_xfer, 1e-9)
        parts, tasks = feat_lib.config_pt_arrays(configs)   # (C,), (C,)
        makespan = (np.maximum(t_comp, t_xfer)
                    + np.minimum(t_comp, t_xfer) / tasks
                    + self.overhead_s * parts * tasks)
        preds = base / makespan                         # (B, C)
        return preds[0] if np.ndim(prog_feats) == 1 else preds

    # no ``refit``: the heuristic is immutable under serving, so tenancy
    # never forks it and drift refinement only rewrites cache entries

    def fork(self) -> "OverlapHeuristicModel":
        return self

    def to_state(self) -> tuple[dict, dict]:
        return {}, {"overhead_s": float(self.overhead_s)}

    @classmethod
    def from_state(cls, arrays: dict, extras: dict) -> "OverlapHeuristicModel":
        return cls(overhead_s=float(extras.get("overhead_s", 30e-6)))
