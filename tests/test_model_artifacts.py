"""Versioned model artifacts: bit-identical save -> load -> predict
round-trips across every estimator kind, schema-hash mismatch refusal,
and the FeaturePipeline degenerate-input regressions (constant columns,
n_samples < n_components)."""
import json

import numpy as np
import pytest

from repro.core.features import RAW_FEATURE_NAMES, config_features
from repro.core.modeling import (ESTIMATOR_KINDS, Estimator, FeaturePipeline,
                                 ForestRegressor, KernelRidgeRBF,
                                 OverlapHeuristicModel, PerformanceModel,
                                 SchemaMismatchError, TreeRegressor,
                                 corpus_fingerprint, load_artifact,
                                 save_artifact)
from repro.core.stream_config import StreamConfig

N_FEAT = len(RAW_FEATURE_NAMES)
CANDS = [StreamConfig(1, 1), StreamConfig(1, 8), StreamConfig(2, 4),
         StreamConfig(4, 16), StreamConfig(8, 32)]


def _corpus(n=240, seed=0):
    """Synthetic (raw features ++ config) -> speedup rows over the full
    22-feature layout, so every kind — including the heuristic, which
    indexes named raw features — scores the same inputs."""
    rng = np.random.default_rng(seed)
    X, y = [], []
    for _ in range(n):
        feats = rng.uniform(0.5, 2.0, size=N_FEAT)
        p = 2 ** rng.integers(0, 4)
        t = 2 ** rng.integers(0, 6)
        speed = 1.0 + 0.4 * np.log2(t) - 0.1 * np.log2(p) \
            + 0.05 * feats[0] + rng.normal() * 0.02
        X.append(np.concatenate([feats, config_features(p, t)]))
        y.append(max(speed, 0.1))
    return np.asarray(X), np.asarray(y)


def _trained_models():
    X, y = _corpus()
    return {
        "mlp": PerformanceModel.train(X, y, epochs=60),
        "cart": TreeRegressor.train(X, y, depth=6),
        "forest": ForestRegressor.train(X, y, n_trees=3, depth=5),
        "krr": KernelRidgeRBF.train(X, y, max_train=150),
        "heuristic": OverlapHeuristicModel(overhead_s=42e-6),
    }


@pytest.fixture(scope="module")
def models():
    return _trained_models()


@pytest.mark.parametrize("kind", ["mlp", "cart", "forest", "krr",
                                  "heuristic"])
def test_artifact_round_trip_bit_identical(models, kind, tmp_path):
    """save -> load reproduces predict_configs EXACTLY (same bits), for
    a single program and for a batched (B, F) feature matrix."""
    model = models[kind]
    assert model.kind == kind
    assert isinstance(model, Estimator)
    path = save_artifact(model, tmp_path / kind, corpus="cafe0123",
                         cv={"frac_of_oracle": 0.9}, tag="test")
    loaded, manifest = load_artifact(path)
    assert type(loaded) is type(model)
    assert manifest["kind"] == kind
    assert manifest["corpus_fingerprint"] == "cafe0123"
    assert manifest["cv"]["frac_of_oracle"] == 0.9

    rng = np.random.default_rng(7)
    feats = rng.uniform(0.5, 2.0, size=N_FEAT)
    np.testing.assert_array_equal(model.predict_configs(feats, CANDS),
                                  loaded.predict_configs(feats, CANDS))
    batch = rng.uniform(0.5, 2.0, size=(3, N_FEAT))
    np.testing.assert_array_equal(model.predict_configs(batch, CANDS),
                                  loaded.predict_configs(batch, CANDS))


def test_every_registered_kind_is_covered(models):
    """The round-trip matrix above must cover every registered kind —
    a newly registered estimator without a round-trip test fails here."""
    assert set(models) == set(ESTIMATOR_KINDS)


def test_schema_hash_mismatch_refuses_to_load(models, tmp_path):
    path = save_artifact(models["mlp"], tmp_path / "m")
    manifest = json.loads((path / "manifest.json").read_text())
    manifest["feature_schema_hash"] = "deadbeefdeadbeef"
    (path / "manifest.json").write_text(json.dumps(manifest))
    with pytest.raises(SchemaMismatchError, match="feature schema"):
        load_artifact(path)
    # forensics override still works
    model, _ = load_artifact(path, allow_schema_mismatch=True)
    assert isinstance(model, PerformanceModel)


def test_newer_format_version_refuses_to_load(models, tmp_path):
    path = save_artifact(models["heuristic"], tmp_path / "h")
    manifest = json.loads((path / "manifest.json").read_text())
    manifest["format_version"] = 99
    (path / "manifest.json").write_text(json.dumps(manifest))
    with pytest.raises(RuntimeError, match="format_version"):
        load_artifact(path)


def test_loaded_model_refits_independently(models, tmp_path):
    """A loaded MLP artifact keeps the online-refit hook, and refitting
    it never touches the saved artifact or the original."""
    model = models["mlp"]
    path = save_artifact(model, tmp_path / "m")
    loaded, _ = load_artifact(path)
    X, y = _corpus(n=24, seed=3)
    loaded.refit(X, y, epochs=10)
    again, _ = load_artifact(path)
    feats = np.full(N_FEAT, 1.3)
    np.testing.assert_array_equal(model.predict_configs(feats, CANDS),
                                  again.predict_configs(feats, CANDS))
    assert not np.array_equal(loaded.predict_configs(feats, CANDS),
                              again.predict_configs(feats, CANDS))


def test_corpus_fingerprint_is_order_independent():
    class S:
        def __init__(self, program, scale, times):
            self.program, self.scale, self.times = program, scale, times

    a = [S("x", 1, {(1, 1): 0.1}),
         S("y", 2, {(1, 1): 0.2, (2, 4): 0.3})]
    b = list(reversed(a))
    assert corpus_fingerprint(a) == corpus_fingerprint(b)
    assert corpus_fingerprint(a) != corpus_fingerprint(a[:1])
    # a different config GRID of the same size is a different corpus
    c = [a[0], S("y", 2, {(1, 1): 0.2, (4, 2): 0.3})]
    assert corpus_fingerprint(a) != corpus_fingerprint(c)


# -- FeaturePipeline degenerate inputs (regression: used to rely on
# -- nan_to_num masking and emit null-space PCA axes) -----------------------


def test_pipeline_drops_constant_columns():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(40, 5))
    X[:, 2] = 7.0                       # constant column
    y = rng.normal(size=40)
    pipe = FeaturePipeline.fit(X, y, n_components=9)
    assert 2 not in set(pipe.keep_idx.tolist())
    Z = pipe.transform(X)
    assert np.isfinite(Z).all()


def test_pipeline_clamps_components_to_rank():
    """n_samples < n_components: PCA must not emit more components than
    the data's rank (the extra axes were numerical noise)."""
    rng = np.random.default_rng(1)
    X = rng.normal(size=(4, 12))        # rank <= 3 after centering
    y = rng.normal(size=4)
    pipe = FeaturePipeline.fit(X, y, n_components=9)
    assert pipe.pca_components.shape[1] <= 3
    Z = pipe.transform(X)
    assert np.isfinite(Z).all()


def test_pipeline_survives_fully_constant_input():
    X = np.full((10, 4), 3.0)
    y = np.linspace(1, 2, 10)
    pipe = FeaturePipeline.fit(X, y, n_components=9)
    Z = pipe.transform(X)
    assert Z.shape[0] == 10 and Z.shape[1] >= 1
    assert np.isfinite(Z).all()


def test_degenerate_training_still_serves():
    """End-to-end: training on a rank-deficient corpus (constant columns
    + few samples) yields finite config rankings, not NaNs."""
    rng = np.random.default_rng(2)
    n = 6
    feats = np.tile(rng.normal(size=3), (n, 1))       # constant program
    cfgf = np.stack([config_features(2 ** (i % 3), 2 ** i)
                     for i in range(n)])
    X = np.concatenate([feats, cfgf], axis=1)
    y = np.linspace(1.0, 2.0, n)
    m = PerformanceModel.train(X, y, epochs=30)
    preds = m.predict_configs(feats[0], CANDS)
    assert np.isfinite(preds).all()
