"""Checkpointing: atomicity, versioning, GC, async, auto-resume, elastic."""
import os

import jax
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer, _flatten, _unflatten


def _tree(step):
    return {"params": {"w": np.full((4, 4), float(step)),
                       "blocks": (np.arange(3.0), np.ones(2))},
            "meta": {"step": np.int32(step)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(7, _tree(7))
    step, tree = ck.restore()
    assert step == 7
    np.testing.assert_array_equal(tree["params"]["w"], _tree(7)["params"]["w"])
    assert isinstance(tree["params"]["blocks"], tuple)


def test_flatten_unflatten_identity():
    t = _tree(3)
    flat = _flatten(t)
    back = _unflatten(flat)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(a, b)


def test_torn_checkpoint_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, _tree(1))
    # simulate a torn write at a later step: npz without manifest
    with open(os.path.join(tmp_path, "ckpt_00000002.npz"), "wb") as f:
        f.write(b"garbage")
    step, tree = ck.restore()
    assert step == 1  # fell back to the latest VALID checkpoint


def test_gc_keeps_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    for s in range(5):
        ck.save(s, _tree(s))
    assert ck.valid_steps() == [3, 4]


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=True)
    ck.save(11, _tree(11))
    ck.wait()
    assert ck.latest_step() == 11


@pytest.mark.slow
def test_auto_resume_training(tmp_path):
    from repro.launch.train import train_loop
    r1 = train_loop("stablelm-3b", steps=6, batch=2, seq=8,
                    ckpt_dir=str(tmp_path), ckpt_every=3, verbose=False)
    assert r1.steps_run == 6
    # "crash" and resume: loop continues from the checkpoint, runs fewer steps
    r2 = train_loop("stablelm-3b", steps=9, batch=2, seq=8,
                    ckpt_dir=str(tmp_path), ckpt_every=3, verbose=False)
    assert r2.resumed_from is not None
    assert r2.steps_run < 9  # only the remaining steps ran


def test_restore_missing_dir(tmp_path):
    ck = Checkpointer(str(tmp_path / "empty"), async_save=False)
    step, tree = ck.restore()
    assert step is None and tree is None
