"""Fleet wire protocol: framing, schema versioning, and the shared
event-driven wait primitive.

The router ↔ worker data plane moves plain picklable tuples whose first
element is the message kind:

  router → worker (task queue)
    ("serve", [(token, WorkloadRequest), ...])   run a batch
    ("refresh", spec)                            reload model, swap in
    ("ping",)                                    liveness probe
    ("stop",)                                    graceful shutdown

  worker → router (result connection)
    ("ready", label, pid, model_tag)             startup handshake
    ("results", label, version, busy_s, [(token, row), ...])
                                                 one *frame* of terminal
                                                 results (wire v2)
    ("result", label, token, payload_dict)       one terminal request
                                                 (legacy wire, opt-in)
    ("refreshed", label, model_tag, error)       refresh ack
    ("pong", label)
    ("bye", label, {"summary", "metrics", "stats"})  shutdown handshake
    ("fatal", label, error)                      dying; router respawns

Wire v2 is the slim return path: instead of pickling one
``{..., "sample": {27-key dict}}`` payload per request, a worker folds
every result of one engine run into a framed ``("results", ...)``
message whose items are ``(token, row)`` pairs — ``row`` is the
positional :data:`repro.serving.telemetry.WIRE_FIELDS` tuple (no key
strings on the wire).  The router rehydrates rows centrally through
:func:`repro.serving.fleet.aggregate.payload_from_sample`.  Result
receipt doubles as the delivery ack, so acks ride the same frame.

Frames carry an explicit schema version so a router and a worker from
different code versions fail loudly (:class:`WireProtocolError`) instead
of mis-zipping fields.  ``REPRO_FLEET_WIRE=legacy`` (or
``WorkerConfig(wire="legacy")``) is the escape hatch back to per-request
``("result", ...)`` payload dicts.

Coalescing: a frame is flushed at every engine-run boundary (the time
window — results are never held while the worker idles) and split at
``frame_max`` items (the size window) so a single oversized message
never monopolizes the pipe.
"""
from __future__ import annotations

import os
from multiprocessing import connection as _mp_connection
from typing import Iterable, List, Sequence, Tuple

#: bump whenever WIRE_FIELDS or the frame layout changes
WIRE_VERSION = 2
WIRE_MODES = ("v2", "legacy")
WIRE_ENV_VAR = "REPRO_FLEET_WIRE"


class WireProtocolError(RuntimeError):
    """A frame's schema version does not match this process's codec —
    a router and a worker are running different code versions.  Fail
    loudly: silently zipping mismatched positional rows would corrupt
    every field after the first drift."""


def resolve_wire_mode(mode: str = "auto") -> str:
    """Resolve a wire-mode spec: explicit ``"v2"``/``"legacy"`` wins,
    ``"auto"`` (or ``None``) falls back to ``$REPRO_FLEET_WIRE`` and
    then to the current protocol."""
    if mode in (None, "", "auto"):
        mode = os.environ.get(WIRE_ENV_VAR, "") or "v2"
    if mode not in WIRE_MODES:
        raise ValueError(f"unknown fleet wire mode {mode!r}; "
                         f"one of {WIRE_MODES + ('auto',)}")
    return mode


def make_results_frame(label: str, busy_s: float,
                       items: Sequence[Tuple[str, tuple]]) -> tuple:
    """One worker → router result frame: ``items`` are ``(token, row)``
    pairs, ``busy_s`` is the share of engine wall time attributed to
    this frame (the router sums it into per-worker compute wall for
    ``ipc_overhead_fraction``)."""
    return ("results", label, WIRE_VERSION, busy_s, list(items))


def parse_results_frame(msg: tuple) -> Tuple[float, List[Tuple[str, tuple]]]:
    """Validate and unpack a ``("results", ...)`` frame; returns
    ``(busy_s, items)``.  Raises :class:`WireProtocolError` on a schema
    version mismatch."""
    _kind, _label, version, busy_s, items = msg
    if version != WIRE_VERSION:
        raise WireProtocolError(
            f"result frame has wire version {version!r}, this router "
            f"speaks {WIRE_VERSION} — router and worker are running "
            f"different code (set {WIRE_ENV_VAR}=legacy to bridge)")
    return busy_s, items


def split_frames(results: Sequence, frame_max: int) -> Iterable[Sequence]:
    """Size-window coalescing: yield ``results`` in runs of at most
    ``frame_max`` (the whole batch when it fits in one frame)."""
    frame_max = max(1, frame_max)
    for i in range(0, len(results), frame_max):
        yield results[i:i + frame_max]


def wait_any(waitables, timeout: float):
    """The shared event-driven wait primitive: block until any of
    ``waitables`` (result :class:`~multiprocessing.connection.Connection`
    handles and/or :attr:`~multiprocessing.Process.sentinel` fds) is
    ready, or ``timeout`` seconds pass.  Returns the ready subset.

    This is what replaced every sleep-poll in ``fleet/``: the router
    parks in ``select``/``poll`` and wakes the instant a worker flushes
    a frame *or* dies (the process sentinel becomes readable on exit),
    instead of rediscovering both on a 5-10 ms timer.
    """
    if not waitables:
        return []
    return _mp_connection.wait(waitables, timeout=max(0.0, timeout))
