"""Thread-pool host backend: tasks issued from worker threads with a
bounded in-flight window.

Where ``host-pipelined`` overlaps H2D and compute by interleaving async
dispatches from one host thread, this backend overlaps them by issuing
each task (transfer + kernel dispatch + retire) from a pool thread — the
host-side analogue of multiple hardware queues.  JAX dispatch is
thread-safe; concurrent tracing of the same shape serializes on JAX's own
compilation lock, so the first dispatch per shape costs the same as the
single-threaded backends.

Ordering contract: outputs are collected into a task-indexed slot table,
so the returned list is task-major, partition-minor regardless of the
completion order of the workers.

The pool machinery lives in :class:`WindowedPool` so other consumers —
the concurrent serving engine (:mod:`repro.serving.engine`) overlaps
whole *requests* on the same primitive — get the lazy executor and the
bounded-window discipline without reimplementing it.
"""
from __future__ import annotations

import collections
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Optional, Sequence

import jax

from repro.core.backends.base import ExecutionContext, StreamBackend, \
    dispatch_plan, slice_rows


class WindowedPool:
    """A lazily created thread pool plus a bounded in-flight window.

    ``window`` bounds how many submitted items may be un-retired at once
    — the live-buffer bound the pipelined backend gets from its
    depth-``d`` deque, enforced here by blocking the submitting thread on
    the oldest outstanding future.
    """

    def __init__(self, workers: int = 4, window: int = 8,
                 name: str = "windowed-pool"):
        assert workers >= 1 and window >= 1, (workers, window)
        self.workers = workers
        self.window = window
        self.name = name
        self._pool: Optional[ThreadPoolExecutor] = None

    def executor(self) -> ThreadPoolExecutor:
        # lazy: module import registers backend instances, and spawning
        # threads at import time would cost every process that never
        # dispatches
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self.workers, thread_name_prefix=self.name)
        return self._pool

    def submit(self, fn: Callable, *args) -> Future:
        return self.executor().submit(fn, *args)

    def run_ordered(self, fn: Callable, items: Sequence) -> list:
        """``[fn(x) for x in items]`` on the pool: submission order, at
        most ``window`` in flight, results in item order regardless of
        completion order."""
        pool = self.executor()
        results: list = [None] * len(items)
        inflight: collections.deque = collections.deque()
        for i, item in enumerate(items):
            while len(inflight) >= self.window:
                j, fut = inflight.popleft()
                results[j] = fut.result()
            inflight.append((i, pool.submit(fn, item)))
        while inflight:
            j, fut = inflight.popleft()
            results[j] = fut.result()
        return results

    def shutdown(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None


class ThreadedHostBackend(StreamBackend):
    name = "host-threads"
    kind = "runner"

    def __init__(self, workers: int = 4, window: int = 8):
        self.pool = WindowedPool(workers, window, name="host-threads")
        self.workers = workers
        self.window = window

    def dispatch(self, ctx: ExecutionContext, config) -> list:
        n_rows = next(iter(ctx.chunked.values())).shape[0]
        plans = dispatch_plan(n_rows, config)

        def issue(parts):
            devs = [jax.device_put(slice_rows(ctx.chunked, lo, hi),
                                   ctx.device) for lo, hi in parts]
            outs = [ctx.jit_kernel(pd, ctx.shared_dev) for pd in devs]
            # retire inside the worker: a completed future means the
            # task's buffers are no longer accumulating in flight
            jax.block_until_ready(outs)
            return outs

        results = self.pool.run_ordered(issue, plans)
        return [o for task_outs in results for o in task_outs]
