"""Adaptive serving subsystem: the paper's runtime loop under multi-tenant
traffic.

Lifecycle per request (see README "Adaptive serving"):

  submit → queue (fifo / priority / fair) → cache hit? dispatch
                                          : features → model search →
                                            cache → dispatch
  every dispatch → telemetry (predicted vs measured) → drift detector
  drift → refiner: re-profile small candidate set, refresh cache entry,
          incremental model refit
"""
from repro.serving.queue import POLICIES, RequestQueue, WorkloadRequest
from repro.serving.refinement import (DriftDetector, RefinementResult,
                                      Refiner)
from repro.serving.scheduler import (AdaptiveScheduler,
                                     OverlapHeuristicModel, RequestResult,
                                     make_trace)
from repro.serving.telemetry import (TelemetryLog, TelemetrySample,
                                     relative_error)

__all__ = [
    "POLICIES", "RequestQueue", "WorkloadRequest",
    "DriftDetector", "RefinementResult", "Refiner",
    "AdaptiveScheduler", "OverlapHeuristicModel", "RequestResult",
    "make_trace",
    "TelemetryLog", "TelemetrySample", "relative_error",
]
