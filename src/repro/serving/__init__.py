"""Adaptive serving subsystem: the paper's runtime loop under multi-tenant
traffic.

Lifecycle per request (see README "Adaptive serving"):

  submit → queue (fifo / priority / fair) → cache hit? dispatch
                                          : features → model search →
                                            cache → dispatch
  every dispatch → telemetry (predicted vs measured) → drift detector
  drift → refiner: re-profile small candidate set, refresh cache entry,
          incremental model refit

The serial :class:`AdaptiveScheduler` runs that pipeline one request at
a time; :class:`ConcurrentScheduler` (``engine.py``) overlaps up to
``window`` requests on a bounded worker pool with batched cold-path
model searches, pooled execution contexts, and a load-aware drift
signal (``measured_s`` normalized by window occupancy over the host's
calibrated parallel capacity).  ``isolate_tenants=True`` gives every
tenant its own cache namespace, drift windows, and — on first refit — a
private fork of the shared base model (``tenancy.py``).

Fleet serving (``fleet/``): :class:`FleetRouter` shards tenants across
N spawn-isolated worker processes (each one a private
``ConcurrentScheduler`` + tuning cache + telemetry/metrics stream),
respawns dead workers and requeues their un-acked work, and merges the
per-worker streams into one worker-labeled fleet view (README "Fleet
serving").

Fault tolerance (``resilience/``): pass ``resilience=ResiliencePolicy()``
to either scheduler for deadline-aware retries, a per-(tenant, stage)
circuit breaker over the degradation ladder, an execution watchdog, and
individual request failure instead of scheduler crashes; pass
``faults=FaultPlan(...)`` to deterministically inject the failures that
prove it (README "Resilience").
"""
from repro.serving.clock import SystemClock, VirtualClock
from repro.serving.engine import (ConcurrentScheduler, ContextPool,
                                  OrderedRetirer)
from repro.serving.fleet import (FleetRouter, WorkerConfig, fleet_summary,
                                 merge_metrics, merge_samples, shard_for)
from repro.serving.observability import (NULL_METRICS, NULL_TRACER,
                                         HotPathProfiler, MetricsRegistry,
                                         NullMetrics, NullTracer, Tracer,
                                         aggregate_stage_times)
from repro.serving.queue import POLICIES, RequestQueue, WorkloadRequest
from repro.serving.refinement import (DriftDetector, RefinementResult,
                                      Refiner, contention_factor)
from repro.serving.resilience import (NULL_FAULTS, BreakerConfig,
                                      CircuitBreaker, FaultPlan, FaultSpec,
                                      InjectedFault, ResiliencePolicy,
                                      RetryPolicy, atomic_write_json,
                                      call_with_retry, corrupt_json_file,
                                      nearest_bucket_entry, quarantine_file)
from repro.serving.scheduler import (AdaptiveScheduler,
                                     OverlapHeuristicModel, PendingRequest,
                                     RequestResult, make_trace)
from repro.serving.telemetry import (TelemetryLog, TelemetrySample,
                                     latency_stats, percentile,
                                     relative_error)
from repro.serving.tenancy import TenantContext, TenantRegistry
from repro.serving.traces import (ServiceModel, TraceConfig,
                                  generate_trace, simulate_trace)

__all__ = [
    "POLICIES", "RequestQueue", "WorkloadRequest",
    "SystemClock", "VirtualClock",
    "ServiceModel", "TraceConfig", "generate_trace", "simulate_trace",
    "latency_stats", "percentile",
    "DriftDetector", "RefinementResult", "Refiner", "contention_factor",
    "AdaptiveScheduler", "OverlapHeuristicModel", "PendingRequest",
    "RequestResult", "make_trace",
    "ConcurrentScheduler", "ContextPool", "OrderedRetirer",
    "FleetRouter", "WorkerConfig", "shard_for",
    "merge_samples", "merge_metrics", "fleet_summary",
    "TelemetryLog", "TelemetrySample", "relative_error",
    "TenantContext", "TenantRegistry",
    "Tracer", "NullTracer", "NULL_TRACER",
    "MetricsRegistry", "NullMetrics", "NULL_METRICS",
    "HotPathProfiler", "aggregate_stage_times",
    "BreakerConfig", "CircuitBreaker", "FaultPlan", "FaultSpec",
    "InjectedFault", "NULL_FAULTS", "ResiliencePolicy", "RetryPolicy",
    "atomic_write_json", "call_with_retry", "corrupt_json_file",
    "nearest_bucket_entry", "quarantine_file",
]
