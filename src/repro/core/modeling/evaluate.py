"""Leave-one-program-out evaluation (paper §5.3.1) — the score stamped
into every published artifact and the ``--model-eval`` benchmark's core.

For each held-out program the model is trained on every other program
family, then asked to pick a config for each of the held-out program's
profiled (program, dataset) cells; the pick is scored against the cell's
profiled grid (achieved speedup vs the oracle's best).  Already-trained
estimators — including the zero-training heuristic baseline — are scored
on the same cells with :func:`evaluate_model`.
"""
from __future__ import annotations

import sys
from typing import Optional, Sequence

import numpy as np

from repro.core.modeling import dataset as ds
from repro.core.stream_config import StreamConfig


def geomean(xs) -> float:
    xs = np.asarray(list(xs), dtype=np.float64)
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-9)))))


def nearest_profiled(sample: "ds.Sample", cfg: StreamConfig) -> StreamConfig:
    """Snap a predicted config to the nearest profiled grid cell (log2
    distance over both axes) so it can be scored against measurements."""
    if cfg.as_tuple() in sample.times:
        return cfg
    cand = min(sample.times, key=lambda pt: (
        abs(np.log2(pt[0]) - np.log2(cfg.partitions))
        + abs(np.log2(pt[1]) - np.log2(cfg.tasks))))
    return StreamConfig(*cand)


def achieved_speedup(sample: "ds.Sample", cfg: StreamConfig) -> float:
    return sample.speedup(nearest_profiled(sample, cfg))


def pick_config(model, sample: "ds.Sample") -> StreamConfig:
    """The model's choice among the sample's profiled grid — scored by
    the same ``search_best`` serving uses, so the CV number measures the
    exact runtime decision procedure (tie-breaks included)."""
    from repro.core.modeling.search import search_best

    cfgs = [StreamConfig(p, t) for (p, t) in sample.times]
    best, _, _ = search_best(model, sample.features, cfgs)
    return best


def evaluate_model(model, samples: Sequence["ds.Sample"]) -> dict:
    """Score an already-trained estimator on profiled cells: geomean
    achieved speedup, oracle speedup, and their ratio."""
    ach = [achieved_speedup(s, pick_config(model, s)) for s in samples]
    orc = [s.oracle_speedup for s in samples]
    return {
        "mean_speedup": geomean(ach),
        "oracle_speedup": geomean(orc),
        "frac_of_oracle": geomean(ach) / geomean(orc),
        "n_cells": len(samples),
    }


def loo_evaluate(samples: Sequence["ds.Sample"], *,
                 model_cls=None,
                 train_kwargs: Optional[dict] = None,
                 verbose: bool = False) -> dict:
    """Leave-one-program-out CV over the corpus.

    Returns per-program and mean achieved/oracle geomean speedups plus
    ``frac_of_oracle`` — the number the paper reports as "% of oracle
    performance" and the CV score stamped into published artifacts."""
    from repro.core.modeling.perf_model import PerformanceModel

    model_cls = model_cls or PerformanceModel
    train_kwargs = dict(train_kwargs or {})
    programs = sorted({s.program for s in samples})
    per_program = {}
    all_ach, all_orc = [], []
    for prog in programs:
        train, test = ds.loo_split(samples, prog)
        if not train or not test:
            continue
        X, y = ds.training_matrix(train)
        model = model_cls.train(X, y, **train_kwargs)
        ach = [achieved_speedup(s, pick_config(model, s)) for s in test]
        orc = [s.oracle_speedup for s in test]
        all_ach += ach
        all_orc += orc
        per_program[prog] = {
            "achieved": geomean(ach),
            "oracle": geomean(orc),
            "frac_of_oracle": geomean(ach) / geomean(orc),
        }
        if verbose:
            print(f"  loo[{prog:>16s}] achieved={geomean(ach):5.3f}x "
                  f"oracle={geomean(orc):5.3f}x "
                  f"({100 * geomean(ach) / geomean(orc):5.1f}%)",
                  file=sys.stderr, flush=True)
    if not per_program:
        raise ValueError(
            "leave-one-program-out CV needs at least two program "
            f"families; corpus has {sorted({s.program for s in samples})}")
    mean_ach, mean_orc = geomean(all_ach), geomean(all_orc)
    return {
        "per_program": per_program,
        "mean_achieved": mean_ach,
        "mean_oracle": mean_orc,
        "frac_of_oracle": mean_ach / mean_orc,
        "n_programs": len(per_program),
        "n_cells": len(all_ach),
    }
