"""Process-wide named counters, gauges, and histograms for serving.

The telemetry JSONL is per-request and unbounded; these are the cheap
aggregates a dashboard or a Prometheus scrape wants: cache hits/misses
per tenant namespace, cold-search batch sizes, drift fires vs cooldown
suppressions, queue depth, shed count, in-flight occupancy, refinement
latency, and per-stage time histograms.

Design constraints, in order:

  * **hot-path cheap** — instruments are resolved once (the scheduler
    pre-binds them in ``__init__``) so a hot-path update is one method
    call on a pre-fetched object; each instrument carries its own lock
    and the critical section is a couple of arithmetic ops (the GIL
    makes most of them atomic anyway — the lock is for the few that are
    read-modify-write across fields, and for snapshot consistency);
  * **deterministic snapshots** — ``snapshot()`` returns plain sorted
    dicts of ints/floats, so two replays of the same seeded trace
    produce byte-identical snapshots (asserted in the tests);
  * **zero cost when off** — :data:`NULL_METRICS` hands back one shared
    no-op instrument for every request, mirroring the null tracer.

``to_prometheus()`` renders the text exposition format (``# TYPE``
headers, ``{label="..."}`` selectors, ``_bucket``/``_sum``/``_count``
histogram series) so a scrape target needs nothing beyond an HTTP
wrapper around one string.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Optional, Sequence

#: default histogram bucket upper bounds (seconds-flavored: the serving
#: stages span ~10us decisions to ~1s refinements)
DEFAULT_BUCKETS = (1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0)


class Counter:
    """Monotone named count."""

    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """Last-set value (queue depth, in-flight occupancy)."""

    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n

    def dec(self, n: float = 1.0) -> None:
        with self._lock:
            self.value -= n

    def snapshot(self):
        return self.value


class Histogram:
    """Fixed-bucket histogram with sum/count/min/max.

    Buckets are cumulative upper bounds (Prometheus ``le`` semantics);
    everything above the last bound lands in the implicit ``+Inf``
    bucket.  ``observe`` is one bisect + a few adds under the lock."""

    __slots__ = ("_lock", "bounds", "bucket_counts", "count", "sum",
                 "min", "max")

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS):
        self._lock = threading.Lock()
        self.bounds = tuple(sorted(float(b) for b in buckets))
        self.bucket_counts = [0] * (len(self.bounds) + 1)  # +Inf last
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, v: float) -> None:
        v = float(v)
        idx = len(self.bounds)
        for i, b in enumerate(self.bounds):     # len(bounds) is ~7
            if v <= b:
                idx = i
                break
        with self._lock:
            self.bucket_counts[idx] += 1
            self.count += 1
            self.sum += v
            if self.min is None or v < self.min:
                self.min = v
            if self.max is None or v > self.max:
                self.max = v

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.count if self.count else None

    def snapshot(self):
        return {
            "count": self.count, "sum": self.sum,
            "min": self.min, "max": self.max, "mean": self.mean,
            "buckets": {_le_label(b): c for b, c in
                        zip((*self.bounds, float("inf")),
                            self.bucket_counts)},
        }


def _le_label(bound: float) -> str:
    return "+Inf" if bound == float("inf") else repr(bound)


class MetricsRegistry:
    """Named instrument registry.

    ``counter/gauge/histogram(name, **labels)`` get-or-create the
    instrument for that (name, labels) pair — same pair, same object, so
    increments from the scheduler and reads from an exporter meet on one
    value.  A name must keep one instrument kind for the registry's
    lifetime (kind confusion raises).
    """

    enabled = True

    def __init__(self):
        self._lock = threading.Lock()
        # name -> (kind, {sorted-label-items-tuple: instrument})
        self._families: dict[str, tuple[str, dict]] = {}

    def _get(self, kind: str, name: str, labels: dict, factory):
        key = tuple(sorted(labels.items()))
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = (kind, {})
                self._families[name] = fam
            elif fam[0] != kind:
                raise TypeError(
                    f"metric {name!r} is a {fam[0]}, requested {kind}")
            inst = fam[1].get(key)
            if inst is None:
                inst = fam[1][key] = factory()
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        return self._get("histogram", name, labels,
                         lambda: Histogram(buckets))

    # -- export -----------------------------------------------------------

    def snapshot(self) -> dict:
        """Plain-dict view, deterministically ordered: metric name ->
        {"type": kind, "values": [{"labels": {...}, ...payload}]}
        (single unlabeled instruments inline their payload as
        ``"value"``)."""
        out: dict = {}
        with self._lock:
            families = {n: (k, dict(insts))
                        for n, (k, insts) in self._families.items()}
        for name in sorted(families):
            kind, insts = families[name]
            values = [{"labels": dict(key), "value": inst.snapshot()}
                      for key, inst in sorted(insts.items())]
            out[name] = {"type": kind, "values": values}
        return out

    def save(self, path: str) -> None:
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format."""
        lines: list[str] = []
        snap = self.snapshot()
        for name, fam in snap.items():
            pname = _prom_name(name)
            lines.append(f"# TYPE {pname} {fam['type']}")
            for entry in fam["values"]:
                sel = _prom_labels(entry["labels"])
                v = entry["value"]
                if fam["type"] == "histogram":
                    cum = 0
                    for le, c in v["buckets"].items():
                        cum += c
                        bsel = _prom_labels(
                            {**entry["labels"], "le": le})
                        lines.append(f"{pname}_bucket{bsel} {cum}")
                    lines.append(f"{pname}_sum{sel} {v['sum']}")
                    lines.append(f"{pname}_count{sel} {v['count']}")
                else:
                    lines.append(f"{pname}{sel} {v}")
        return "\n".join(lines) + "\n"


def _prom_name(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


def _prom_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{_prom_name(k)}="{v}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class _NullInstrument:
    """One object, every no-op instrument method."""

    __slots__ = ()

    def inc(self, n=1) -> None:
        pass

    def dec(self, n=1) -> None:
        pass

    def set(self, v) -> None:
        pass

    def observe(self, v) -> None:
        pass

    def snapshot(self):
        return None


_NULL_INSTRUMENT = _NullInstrument()


class NullMetrics:
    """Disabled registry: every request resolves to the one shared no-op
    instrument; snapshot is empty.  The schedulers' default."""

    enabled = False

    def counter(self, name: str, **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def gauge(self, name: str, **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def histogram(self, name: str, buckets=DEFAULT_BUCKETS,
                  **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def snapshot(self) -> dict:
        return {}

    def save(self, path: str) -> None:
        pass

    def to_prometheus(self) -> str:
        return ""


NULL_METRICS = NullMetrics()
