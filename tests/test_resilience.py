"""Serving fault tolerance (PR 8): deterministic fault injection,
deadline-aware retry, circuit-breaker degradation ladder, corrupt-file
quarantine round-trips, watchdog reap/requeue, individually-failed
requests (never a scheduler crash), and the shutdown-path contracts."""
import json
import os
import shutil

import numpy as np
import pytest

from repro.core.autotuner import TuneResult, TuningCache
from repro.core.modeling import ModelRegistry, OverlapHeuristicModel
from repro.core.stream_config import StreamConfig
from repro.core.workloads import get_workload
from repro.launch.stats import render
from repro.serving import (AdaptiveScheduler, BreakerConfig,
                           CircuitBreaker, ConcurrentScheduler,
                           DriftDetector, FaultPlan, FaultSpec,
                           InjectedFault, MetricsRegistry, NULL_FAULTS,
                           ResiliencePolicy, RetryPolicy, TelemetryLog,
                           TelemetrySample, WorkloadRequest,
                           atomic_write_json, call_with_retry,
                           corrupt_json_file, nearest_bucket_entry,
                           quarantine_file)
from repro.serving.clock import VirtualClock
from repro.serving.traces import TraceConfig, generate_trace, simulate_trace


class _ConstModel:
    """Constant speedup-1.0 predictor (search picks single-stream)."""

    def predict_configs(self, feats, candidates):
        F = np.atleast_2d(np.asarray(feats))
        preds = np.ones((F.shape[0], len(candidates)))
        return preds[0] if np.ndim(feats) == 1 else preds


class _RaisingModel:
    """Primary model whose every prediction dies — the top of the tune
    ladder is permanently broken."""

    def __init__(self):
        self.calls = 0

    def predict_configs(self, feats, candidates):
        self.calls += 1
        raise RuntimeError("injected model failure")


def _req(workload="vecadd", rows=256, seed=0, **kw):
    wl = get_workload(workload)
    chunked, shared = wl.make_data(rows, np.random.default_rng(seed))
    return WorkloadRequest(workload=workload, chunked=chunked,
                          shared=shared, **kw)


def _counter_total(metrics, name):
    snap = metrics.snapshot()
    return sum(v["value"] for v in snap.get(name, {}).get("values", []))


def _lenient_drift():
    return DriftDetector(threshold=1e9)


# -- fault injection ---------------------------------------------------------


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec(site="compile", at=(0,))
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(site="dispatch", kind="segfault", at=(0,))
    with pytest.raises(ValueError, match="needs at=, every="):
        FaultSpec(site="dispatch")


def test_fault_plan_at_every_times_semantics():
    plan = FaultPlan([
        FaultSpec(site="dispatch", at=(1, 3)),
        FaultSpec(site="retire", every=2, times=2),
    ]).bind(sleep=None)
    hits = []
    for i in range(6):
        try:
            plan.fire("dispatch")
            hits.append(False)
        except InjectedFault:
            hits.append(True)
    assert hits == [False, True, False, True, False, False]
    # every=2 fires on the 2nd and 4th invocation, then times= caps it
    retire_hits = []
    for i in range(8):
        try:
            plan.fire("retire")
            retire_hits.append(False)
        except InjectedFault:
            retire_hits.append(True)
    assert retire_hits == [False, True, False, True, False, False,
                           False, False]
    assert plan.invocations("dispatch") == 6
    assert plan.invocations("retire") == 8
    assert plan.fired == 4


def test_latency_fault_returns_delay_under_virtual_binding():
    plan = FaultPlan([FaultSpec(site="dispatch", kind="latency",
                                at=(0,), delay_s=0.25)])
    slept = []
    plan.bind(sleep=slept.append)
    assert plan.fire("dispatch") == 0.25
    assert slept == [0.25]
    assert plan.fire("dispatch") == 0.0
    # sleep=None (virtual-time harness): the delay is returned, nothing
    # stalls — the simulator charges it to service time
    plan2 = FaultPlan([FaultSpec(site="dispatch", kind="latency",
                                 at=(0,), delay_s=0.25)]).bind(sleep=None)
    assert plan2.fire("dispatch") == 0.25


def test_fault_plan_probability_deterministic_across_reset():
    plan = FaultPlan([FaultSpec(site="decide", probability=0.3)],
                     seed=7).bind(sleep=None)

    def draw():
        out = []
        for _ in range(50):
            try:
                plan.fire("decide")
                out.append(False)
            except InjectedFault:
                out.append(True)
        return out

    first = draw()
    plan.reset()
    assert draw() == first
    assert any(first) and not all(first)


def test_fault_plan_json_roundtrip(tmp_path):
    plan = FaultPlan([
        FaultSpec(site="dispatch", at=(3, 4), message="outage"),
        FaultSpec(site="tune.cold", kind="latency", every=10, times=2,
                  delay_s=0.5),
    ], seed=3)
    path = tmp_path / "plan.json"
    plan.save(path)
    loaded = FaultPlan.load(path)
    assert loaded.seed == 3
    assert loaded.specs == plan.specs
    assert loaded.enabled


def test_fault_plan_counts_injected_metric():
    metrics = MetricsRegistry()
    plan = FaultPlan([FaultSpec(site="dispatch", at=(0,))])
    plan.bind(metrics=metrics, sleep=None)
    with pytest.raises(InjectedFault):
        plan.fire("dispatch")
    assert _counter_total(metrics, "serving.faults.injected") == 1


def test_null_faults_is_disabled_noop():
    assert not NULL_FAULTS.enabled
    assert NULL_FAULTS.fire("dispatch") == 0.0
    assert NULL_FAULTS.invocations("dispatch") == 0


@pytest.mark.parametrize("mode", ["truncate", "garbage", "empty"])
def test_corrupt_json_file_defeats_json_load(tmp_path, mode):
    path = tmp_path / "state.json"
    path.write_text(json.dumps({"entries": {"k": [1, 2, 3]}} | {
        "pad": list(range(64))}))
    corrupt_json_file(path, mode)
    with pytest.raises((json.JSONDecodeError, UnicodeDecodeError)):
        json.load(open(path))


def test_corrupt_json_file_rejects_unknown_mode(tmp_path):
    path = tmp_path / "x.json"
    path.write_text("{}")
    with pytest.raises(ValueError, match="unknown corruption mode"):
        corrupt_json_file(path, "bitflip")


# -- deadline-aware retry ----------------------------------------------------


def test_retry_succeeds_after_transients():
    calls, slept, recovered = [], [], []
    policy = RetryPolicy(attempts=3, base_s=0.01, jitter=0.0)

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"

    out = call_with_retry(flaky, policy=policy,
                          rng=__import__("random").Random(0),
                          sleep=slept.append,
                          on_recover=recovered.append)
    assert out == "ok"
    assert len(calls) == 3
    assert len(slept) == 2
    assert slept[1] > slept[0]          # exponential growth (no jitter)
    assert recovered == [2]


def test_retry_exhausts_attempts_and_reraises():
    policy = RetryPolicy(attempts=3, base_s=0.0, jitter=0.0)
    calls = []

    def dead():
        calls.append(1)
        raise ValueError("permanent")

    with pytest.raises(ValueError, match="permanent"):
        call_with_retry(dead, policy=policy,
                        rng=__import__("random").Random(0),
                        sleep=lambda s: None)
    assert len(calls) == 3


def test_retry_deadline_budget_fails_fast_without_sleeping():
    """A backoff that would land past the request's SLO deadline is
    pointless — the loop re-raises immediately instead of widening the
    violation."""
    clock = VirtualClock(start=10.0)
    slept = []
    policy = RetryPolicy(attempts=5, base_s=0.05, jitter=0.0)

    def dead():
        raise RuntimeError("down")

    with pytest.raises(RuntimeError, match="down"):
        call_with_retry(dead, policy=policy,
                        rng=__import__("random").Random(0),
                        clock=clock, deadline_s=10.01,
                        sleep=slept.append)
    assert slept == []                  # zero budget: never slept


def test_backoff_jitter_bounds_and_cap():
    rng = __import__("random").Random(0)
    policy = RetryPolicy(attempts=5, base_s=0.01, multiplier=2.0,
                         cap_s=0.03, jitter=0.5)
    for attempt in range(6):
        raw = min(0.01 * 2.0 ** attempt, 0.03)
        for _ in range(20):
            b = policy.backoff_s(attempt, rng)
            assert raw <= b <= raw * 1.5 + 1e-12


# -- circuit breaker ---------------------------------------------------------


def test_breaker_trips_after_k_consecutive_failures():
    clock = VirtualClock()
    br = CircuitBreaker(BreakerConfig(k=3, cooldown_s=1.0), clock=clock)
    key = ("t0", "dispatch")
    for _ in range(2):
        br.record_failure(key)
    assert br.state(key) == "closed" and br.allow(key)
    br.record_failure(key)
    assert br.state(key) == "open" and not br.allow(key)


def test_breaker_success_resets_consecutive_count():
    br = CircuitBreaker(BreakerConfig(k=3), clock=VirtualClock())
    key = ("t0", "tune")
    br.record_failure(key)
    br.record_failure(key)
    br.record_success(key)
    br.record_failure(key)
    br.record_failure(key)
    assert br.state(key) == "closed"    # never 3 *consecutive*


def test_breaker_half_open_single_probe_then_recover():
    clock = VirtualClock()
    br = CircuitBreaker(BreakerConfig(k=2, cooldown_s=1.0), clock=clock)
    key = ("t0", "dispatch")
    br.record_failure(key)
    br.record_failure(key)
    assert not br.allow(key)            # open, cooldown not elapsed
    clock.advance(1.5)
    assert br.allow(key)                # THE half-open probe
    assert br.state(key) == "half-open"
    assert not br.allow(key)            # exactly one outstanding probe
    br.record_success(key)
    assert br.state(key) == "closed" and br.allow(key)
    states = [s for _, k, s in br.events if k == key]
    assert states == ["open", "half-open", "closed"]


def test_breaker_half_open_failure_reopens():
    clock = VirtualClock()
    br = CircuitBreaker(BreakerConfig(k=2, cooldown_s=1.0), clock=clock)
    key = ("t0", "dispatch")
    br.record_failure(key)
    br.record_failure(key)
    clock.advance(1.5)
    assert br.allow(key)
    br.record_failure(key)
    assert br.state(key) == "open"
    assert not br.allow(key)            # cooldown restarted at reopen
    clock.advance(1.5)
    assert br.allow(key)


def test_breaker_exports_state_gauge_and_opened_counter():
    metrics = MetricsRegistry()
    clock = VirtualClock()
    br = CircuitBreaker(BreakerConfig(k=1, cooldown_s=1.0),
                        clock=clock, metrics=metrics)
    br.record_failure(("acme", "dispatch"))
    snap = metrics.snapshot()
    entries = snap["serving.breaker.state"]["values"]
    assert entries[0]["labels"] == {"tenant": "acme", "stage": "dispatch"}
    assert entries[0]["value"] == 2     # 2 == open
    assert _counter_total(metrics, "serving.breaker.opened") == 1
    # the stats CLI renders the block without raising
    out = render([], snap)
    assert "== resilience ==" in out and "breaker" in out and "open" in out


# -- nearest-bucket fallback + crash-safe persistence ------------------------


def _cache_with_bucket(cache, rows, config, workload="vecadd",
                       backend="host-sync", seed=0):
    wl = get_workload(workload)
    chunked, shared = wl.make_data(rows, np.random.default_rng(seed))
    key = TuningCache.key(workload, chunked, shared, backend)
    cache.put(key, TuneResult(config, 1.2, 0.0, 0.0, backend=backend))
    return key


def test_nearest_bucket_borrows_closest_comparable_bucket():
    cache = TuningCache()
    _cache_with_bucket(cache, 1024, StreamConfig(partitions=2, tasks=2))
    _cache_with_bucket(cache, 8192, StreamConfig(partitions=4, tasks=4))
    wl = get_workload("vecadd")
    chunked, shared = wl.make_data(512, np.random.default_rng(1))
    want = TuningCache.key("vecadd", chunked, shared, "host-sync")
    got = nearest_bucket_entry(cache, want, n_rows=512)
    assert got is not None
    assert got.config == StreamConfig(partitions=2, tasks=2)  # 1024 wins


def test_nearest_bucket_respects_feasibility_and_key_prefix():
    cache = TuningCache()
    # the only comparable bucket needs 64 rows split — infeasible at 16
    _cache_with_bucket(cache, 1024, StreamConfig(partitions=8, tasks=8))
    # different workload: never comparable
    _cache_with_bucket(cache, 1024, StreamConfig(partitions=2, tasks=2),
                       workload="dotprod")
    wl = get_workload("vecadd")
    chunked, shared = wl.make_data(16, np.random.default_rng(1))
    want = TuningCache.key("vecadd", chunked, shared, "host-sync")
    assert nearest_bucket_entry(cache, want, n_rows=16) is None
    assert nearest_bucket_entry(None, want, n_rows=16) is None


def test_atomic_write_json_replaces_and_leaves_no_tmp(tmp_path):
    path = tmp_path / "state.json"
    path.write_text(json.dumps({"old": True}))
    atomic_write_json(path, {"new": [1, 2, 3]})
    assert json.loads(path.read_text()) == {"new": [1, 2, 3]}
    assert not os.path.exists(str(path) + ".tmp")


def test_quarantine_file_collision_naming(tmp_path):
    path = tmp_path / "cache.json"
    path.write_text("not json")
    first = quarantine_file(path)
    assert first.endswith(".corrupt") and os.path.exists(first)
    path.write_text("still not json")
    second = quarantine_file(path)
    assert second != first and os.path.exists(second)
    assert not path.exists()


def test_corrupt_cache_quarantine_and_rebuild_roundtrip(tmp_path):
    path = tmp_path / "tuning.json"
    cache = TuningCache()
    key = _cache_with_bucket(cache, 1024,
                             StreamConfig(partitions=2, tasks=2))
    cache.save(str(path))
    corrupt_json_file(path, "truncate")
    with pytest.warns(UserWarning, match="unreadable tuning cache"):
        fresh = TuningCache(str(path))
    assert len(fresh) == 0
    assert fresh.quarantined is not None
    assert os.path.exists(fresh.quarantined)
    # the rebuilt cache persists and round-trips on the SAME path
    _cache_with_bucket(fresh, 1024, StreamConfig(partitions=2, tasks=2))
    fresh.save()
    again = TuningCache(str(path))
    assert again.peek(key) is not None and again.quarantined is None


# -- registry dangling-latest fallback (satellite) ---------------------------


def test_dangling_latest_falls_back_to_newest_resolvable(tmp_path):
    metrics = MetricsRegistry()
    reg = ModelRegistry(tmp_path, metrics=metrics)
    reg.publish(OverlapHeuristicModel())
    v2 = reg.publish(OverlapHeuristicModel())
    # a tenant fork is a DIFFERENT lineage — must not be the fallback
    reg.publish(OverlapHeuristicModel(), tenant="acme")
    v3 = reg.publish(OverlapHeuristicModel())
    shutil.rmtree(tmp_path / v3)        # latest now dangles at v3
    with pytest.warns(UserWarning, match="falling back"):
        model, manifest = reg.load("latest")
    assert manifest["artifact_id"] == v2
    assert isinstance(model, OverlapHeuristicModel)
    assert _counter_total(metrics, "serving.registry.latest_fallback") == 1


def test_dangling_latest_with_no_surviving_artifact_still_raises(tmp_path):
    reg = ModelRegistry(tmp_path)
    aid = reg.publish(OverlapHeuristicModel())
    shutil.rmtree(tmp_path / aid)
    with pytest.raises(RuntimeError, match="points at"):
        reg.load("latest")


# -- resilient serial scheduler ----------------------------------------------


def _resilient_scheduler(model=None, *, backend="host-sync", faults=None,
                         policy=None, **kw):
    return AdaptiveScheduler(
        model if model is not None else _ConstModel(),
        backend=backend, drift=_lenient_drift(), faults=faults,
        resilience=policy if policy is not None else ResiliencePolicy(
            retry=RetryPolicy(attempts=3, base_s=1e-4, jitter=0.0)),
        metrics=MetricsRegistry(), **kw)


def test_transient_dispatch_fault_is_retried_and_recovered():
    faults = FaultPlan([FaultSpec(site="dispatch", at=(0,),
                                  message="transient dispatch error")])
    sched = _resilient_scheduler(faults=faults)
    sched.submit_all([_req(seed=i) for i in range(2)])
    results = sched.run()
    assert [r.status for r in results] == ["served", "served"]
    assert all(len(r.outputs) for r in results)
    assert sched.stats.get("failed", 0) == 0
    assert _counter_total(sched.metrics, "serving.faults.recovered") >= 1
    sched.close()


def test_dispatch_outage_fails_requests_individually():
    """An outage longer than the retry budget on a backend with no
    fallback (host-sync IS the fallback) must fail that request alone:
    an error telemetry sample with status/error set, and run() returns
    normally for everything else."""
    faults = FaultPlan([FaultSpec(site="dispatch", at=(0, 1, 2),
                                  message="injected outage")])
    sched = _resilient_scheduler(faults=faults)
    sched.submit_all([_req(seed=i) for i in range(3)])
    results = sched.run()
    assert [r.status for r in results] == ["failed", "served", "served"]
    failed = results[0]
    assert failed.measured_s is None and failed.outputs == []
    assert "InjectedFault" in failed.error and "outage" in failed.error
    assert failed.sample.status == "failed"
    summary = sched.telemetry.summary()
    assert summary["by_status"] == {"failed": 1, "ok": 2}
    assert sched.stats["failed"] == 1
    sched.close()


def test_dispatch_steps_down_to_host_sync_fallback():
    faults = FaultPlan([FaultSpec(site="dispatch", at=(0, 1, 2),
                                  message="primary backend down")])
    sched = _resilient_scheduler(backend="host-threads", faults=faults)
    sched.submit_all([_req(seed=0)])
    (r,) = sched.run()
    assert r.status == "degraded"
    assert r.sample.degraded_via == "backend"
    assert len(r.outputs) and r.measured_s is not None
    assert _counter_total(sched.metrics, "serving.faults.degraded") == 1
    sched.close()


def test_tune_ladder_falls_to_heuristic_and_breaker_opens():
    """Primary model permanently broken: every cold tune steps down to
    the heuristic (requests still serve, marked degraded), and after k
    consecutive failures the (tenant, tune) breaker opens so the dead
    primary stops being retried at all."""
    raising = _RaisingModel()
    sched = _resilient_scheduler(
        raising,
        policy=ResiliencePolicy(
            retry=RetryPolicy(attempts=3, base_s=1e-4, jitter=0.0),
            breaker=BreakerConfig(k=2, cooldown_s=1e9)))
    # three different shape buckets -> three cold tunes
    sched.submit_all([_req(rows=r, seed=i)
                      for i, r in enumerate((256, 1024, 4096))])
    results = sched.run()
    assert [r.status for r in results] == ["degraded"] * 3
    assert {r.sample.degraded_via for r in results} == {"heuristic-model"}
    assert all(len(r.outputs) for r in results)
    assert sched.breaker.state(("default", "tune")) == "open"
    # requests 1-2 each burn the 3-attempt retry budget; request 3 finds
    # the breaker open and never touches the primary
    assert raising.calls == 6
    sched.close()


# -- resilient concurrent engine ---------------------------------------------


def test_concurrent_engine_survives_dispatch_errors():
    faults = FaultPlan([FaultSpec(site="dispatch", every=1, times=6,
                                  message="flaky dispatch")])
    eng = ConcurrentScheduler(
        _ConstModel(), window=2, drift=_lenient_drift(), faults=faults,
        resilience=ResiliencePolicy(
            retry=RetryPolicy(attempts=3, base_s=1e-4, jitter=0.0)),
        metrics=MetricsRegistry())
    n = 5
    eng.submit_all([_req(seed=i) for i in range(n)])
    results = eng.run()
    assert len(results) == n
    assert all(r.status in ("served", "degraded", "failed")
               for r in results)
    assert eng.stats["failed"] >= 1
    assert sum(r.status != "failed" for r in results) >= 1
    assert eng.retirer.held == 0        # nothing wedged in the retirer
    eng.close()


def test_watchdog_reaps_hung_dispatch_and_requeue_serves():
    faults = FaultPlan([FaultSpec(site="dispatch", kind="latency",
                                  at=(0,), delay_s=0.4)])
    eng = ConcurrentScheduler(
        _ConstModel(), window=2, workers=2, drift=_lenient_drift(),
        faults=faults,
        resilience=ResiliencePolicy(watchdog_s=0.08),
        metrics=MetricsRegistry())
    eng.submit_all([_req(seed=0)])
    (r,) = eng.run()
    assert r.status == "served" and len(r.outputs)
    assert eng.stats["watchdog_fired"] == 1
    assert _counter_total(eng.metrics, "serving.watchdog.fired") == 1
    eng.close()                         # joins the abandoned zombie


def test_watchdog_requeue_exhausted_times_out_individually():
    faults = FaultPlan([FaultSpec(site="dispatch", kind="latency",
                                  at=(0, 1), delay_s=0.3)])
    eng = ConcurrentScheduler(
        _ConstModel(), window=2, workers=2, drift=_lenient_drift(),
        faults=faults,
        resilience=ResiliencePolicy(watchdog_s=0.05),
        metrics=MetricsRegistry())
    eng.submit_all([_req(seed=0)])
    (r,) = eng.run()
    assert r.status == "timeout"
    assert "watchdog" in r.error
    assert r.sample.status == "timeout" and r.sample.measured_s is None
    assert eng.stats["watchdog_fired"] == 2
    eng.close()


# -- telemetry contracts + shutdown paths (satellites) -----------------------


def _failed_sample(seq, **kw):
    return TelemetrySample(seq=seq, tenant="t", workload="vecadd",
                           key="k", backend="host-sync", partitions=0,
                           tasks=0, cache_hit=False, predicted_s=None,
                           measured_s=None, rel_error=None,
                           status="failed", error="RuntimeError: boom",
                           **kw)


def test_summary_with_all_requests_failed_is_none_shaped():
    log = TelemetryLog()
    for i in range(4):
        log.append(_failed_sample(i))
    s = log.summary()
    assert s["requests"] == 4
    assert s["latency"] is None
    assert s["total_measured_s"] == 0.0
    assert s["mean_rel_error"] is None
    assert s["slo_violation_rate"] is None
    assert s["by_status"] == {"failed": 4}
    # the stats CLI renders an all-failed window without raising
    out = render(log.samples)
    assert "failed 4" in out and "(no retired requests)" in out


def test_telemetry_close_idempotent_never_fsyncs_closed_file(
        tmp_path, monkeypatch):
    import repro.serving.telemetry as telemetry_mod
    fsyncs = []
    real_fsync = os.fsync
    monkeypatch.setattr(telemetry_mod.os, "fsync",
                        lambda fd: (fsyncs.append(fd), real_fsync(fd)))
    log = TelemetryLog(str(tmp_path / "t.jsonl"))
    log.append(_failed_sample(0))
    log.close()
    assert log.closed and len(fsyncs) == 1
    log.close()                         # double-close: no second fsync,
    log.close()                         # no ValueError on a closed fd
    assert len(fsyncs) == 1
    # append after close reopens the sink (append-only file mode)
    log.append(_failed_sample(1))
    log.close()
    assert len(TelemetryLog.read(str(tmp_path / "t.jsonl"))) == 2


def test_scheduler_close_is_idempotent_and_safe_mid_flight(tmp_path):
    sched = AdaptiveScheduler(
        _ConstModel(), drift=_lenient_drift(),
        telemetry=TelemetryLog(str(tmp_path / "t.jsonl")))
    sched.submit_all([_req(seed=i) for i in range(2)])
    sched.run(max_requests=1)           # one request still queued
    sched.close()
    assert sched.telemetry.closed
    sched.close()                       # idempotent
    eng = ConcurrentScheduler(_ConstModel(), window=2,
                              drift=_lenient_drift())
    eng.submit_all([_req(seed=0)])
    eng.run()
    eng.close()
    eng.close()                         # pool shutdown is idempotent too


# -- virtual-clock trace harness under faults --------------------------------


def test_simulate_trace_with_faults_is_deterministic():
    cfg = TraceConfig(n_requests=800, seed=5, arrival="bursty")
    specs = [FaultSpec(site="dispatch", at=tuple(range(40, 52)),
                       message="outage"),
             FaultSpec(site="dispatch", kind="latency", every=97,
                       delay_s=0.2)]

    def run():
        return simulate_trace(generate_trace(cfg), policy="fifo", seed=5,
                              faults=FaultPlan(specs, seed=5))

    a, b = run(), run()
    assert a == b
    assert a["failed"] > 0
    assert a["faults_injected"] > 0
    clean = simulate_trace(generate_trace(cfg), policy="fifo", seed=5)
    assert clean["failed"] == 0
    assert clean["completed"] >= a["completed"]
