"""The streamed executor — AUTOSTREAMER's runtime.

The execution strategies themselves live in :mod:`repro.core.backends`
(``host-sync``, ``host-pipelined``, ``mesh``, plus anything registered at
runtime).  This module keeps the user-facing runner: one object per
(workload, dataset) pair that can execute, time, and profile arbitrary
stream configs on any registered runner backend.

``streamify_train_step`` is the train-step face of the same idea and
delegates to the ``mesh`` backend.
"""
from __future__ import annotations

import time
from typing import Callable, Union

import jax
import numpy as np

from repro.core.backends import (StreamBackend, ExecutionContext,
                                 get_backend, split_arrays)
from repro.core.stream_config import SINGLE_STREAM, StreamConfig
from repro.core.workloads import Workload

# back-compat alias: tests and older callers import the splitter from here
_split = split_arrays


def readback_outputs(outs: list) -> None:
    """Materialize EVERY output leaf on the host (paper Fig 8c: results
    transferred back).  Reading only the first leaf — the old behavior —
    undercounts D2H time on multi-output kernels, so every measured
    runtime (``run``, the serving execute stage) routes through here."""
    for o in outs:
        for leaf in jax.tree.leaves(o):
            np.asarray(leaf, copy=False)


class StreamedRunner:
    """Executes one workload+dataset under arbitrary stream configs.

    ``backend`` picks the execution strategy by registry name (or a
    :class:`StreamBackend` instance); every runner backend produces
    outputs in the same task-major order, allclose to the single-stream
    reference.
    """

    def __init__(self, wl: Workload, chunked: dict, shared: dict,
                 device=None, backend: Union[str, StreamBackend] = "host-sync",
                 ctx: Union[ExecutionContext, None] = None):
        self.wl = wl
        self.chunked = chunked
        self.shared = shared
        self.backend = (get_backend(backend) if isinstance(backend, str)
                        else backend)
        if self.backend.kind != "runner":
            raise ValueError(
                f"backend {self.backend.name!r} is a {self.backend.kind} "
                f"backend, not a runner")
        # a caller holding a pooled ExecutionContext (the serving engine's
        # per-workload context pool) wraps it instead of paying create()'s
        # shared-buffer upload again
        self.ctx = ctx if ctx is not None else ExecutionContext.create(
            wl.kernel, chunked, shared, device)
        self.device = self.ctx.device
        # legacy attribute names, still used by feature extraction
        self._jit = self.ctx.jit_kernel
        self._shared_dev = self.ctx.shared_dev

    # -- execution -----------------------------------------------------------

    def dispatch(self, config: StreamConfig) -> list:
        """Issue the full iteration space under ``config``; returns the
        per-slice outputs (possibly still in flight — callers block)."""
        return self.backend.dispatch(self.ctx, config)

    # legacy private name, used by older tests
    _dispatch = dispatch

    def warmup(self, config: StreamConfig) -> None:
        """Compile every sub-slice shape before timing."""
        outs = self._dispatch(config)
        jax.block_until_ready(outs)

    def run(self, config: StreamConfig, *, reps: int = 3,
            warmed: bool = False) -> float:
        """Wall-clock seconds (min over reps) incl. H2D, compute, D2H."""
        if not warmed:
            self.warmup(config)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            outs = self._dispatch(config)
            # read back (paper Fig 8c: results transferred to host)
            jax.block_until_ready(outs)
            readback_outputs(outs)
            best = min(best, time.perf_counter() - t0)
        return best

    def run_single_stream(self, *, reps: int = 3) -> float:
        return self.run(SINGLE_STREAM, reps=reps)

    # -- profiling hooks used by feature extraction ---------------------------

    def measure_transfer(self, *, reps: int = 3) -> float:
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            dev = jax.device_put(self.chunked, self.device)
            jax.block_until_ready(dev)
            best = min(best, time.perf_counter() - t0)
        return best

    def measure_compute(self, *, reps: int = 3) -> float:
        dev = jax.device_put(self.chunked, self.device)
        jax.block_until_ready(dev)
        self.warmup(SINGLE_STREAM)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            out = self._jit(dev, self._shared_dev)
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        return best

    def lowered_kernel(self):
        """Lowered+compiled single-chunk kernel for static features."""
        shapes = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), self.chunked)
        sshapes = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), self.shared)
        return jax.jit(self.wl.kernel).lower(shapes, sshapes)


def parallel_capacity(calls, workers: int, *, reps: int = 8,
                      trials: int = 2) -> float:
    """Calibrate the host: how much does issuing ``calls`` from
    ``workers`` threads speed up over serial issue?

    ``calls`` are zero-arg callables that block until their work is
    done (compiled, device-resident kernels — so the ratio is the raw
    hardware scaling ceiling, not compile or H2D noise).  Max over
    ``trials`` serial/threaded pairs, because steal time on shared
    boxes deflates single trials.  This one number is consumed twice:
    the ``--serve-concurrent`` benchmark reports it as the ceiling the
    engine chases, and the concurrent engine's load-aware drift signal
    divides in-flight occupancy by it to normalize contention out of
    ``measured_s`` before drift detection."""
    import concurrent.futures

    n = max(1, reps) * len(calls)

    def one(i: int) -> None:
        calls[i % len(calls)]()

    pool = concurrent.futures.ThreadPoolExecutor(workers)
    try:
        best = 0.0
        for _ in range(max(1, trials)):
            t0 = time.perf_counter()
            for i in range(n):
                one(i)
            t_serial = time.perf_counter() - t0
            t0 = time.perf_counter()
            futs = [pool.submit(one, i) for i in range(n)]
            for f in futs:
                f.result()
            t_threaded = time.perf_counter() - t0
            best = max(best, t_serial / max(t_threaded, 1e-12))
    finally:
        pool.shutdown()
    return best


def probe_host_capacity(workers: int, *, size: int = 384,
                        reps: int = 6) -> float:
    """Capacity probe with a synthetic kernel (one compiled matmul) for
    callers that have no workload in hand yet — the concurrent engine's
    lazy calibration path.  Costs a few milliseconds once."""
    x = np.random.default_rng(0).standard_normal(
        (size, size)).astype(np.float32)
    jitk = jax.jit(lambda a: a @ a)
    dev = jax.device_put(x)
    jax.block_until_ready(jitk(dev))            # compile, untimed
    return parallel_capacity(
        [lambda: jax.block_until_ready(jitk(dev))], workers, reps=reps)


def profile_config_grid(runner: StreamedRunner, configs, *, reps: int = 3,
                        verbose: bool = False) -> dict[StreamConfig, float]:
    """Exhaustive profiling of a config grid (paper §3.1.2)."""
    out = {}
    for cfg in configs:
        out[cfg] = runner.run(cfg, reps=reps)
        if verbose:
            print(f"  {cfg.partitions:3d}x{cfg.tasks:<3d} {out[cfg]*1e3:8.3f} ms")
    return out


def profile_grid_interleaved(runner: StreamedRunner, configs, *,
                             sweeps: int = 3,
                             prior: Union[dict, None] = None
                             ) -> dict[StreamConfig, float]:
    """Min-per-config over round-robin sweeps of the grid.

    Interleaving beats back-to-back reps on shared boxes: a
    neighbor-load spike spans one sweep's worth of configs, not every
    sample of one config, so the per-config min survives it and the
    argmin is not a lottery.  ``prior`` merges a previous profile of the
    same configs (the oracle benchmark's before/after-serving passes).
    This is THE measurement protocol for config selection — the serving
    refiner and the oracle-regret benchmark both use it, so the
    "achieved" and "oracle" sides of the regret ratio are measured
    identically."""
    best = dict(prior) if prior else {c: float("inf") for c in configs}
    for c in configs:
        runner.warmup(c)
    for _ in range(max(1, sweeps)):
        for c in configs:
            best[c] = min(best[c], runner.run(c, reps=1, warmed=True))
    return best


def streamify_train_step(
    loss_fn: Callable,
    config: StreamConfig,
    *,
    unroll: bool = True,
) -> Callable:
    """Microbatched grad-accumulation step — see
    :meth:`repro.core.backends.mesh.MeshBackend.wrap_train_step`."""
    return get_backend("mesh").wrap_train_step(loss_fn, config,
                                               unroll=unroll)
