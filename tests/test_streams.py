"""Streamed executor: correctness (streamed == single-stream results),
buffer-validity, and microbatched gradient-accumulation equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.stream_config import SINGLE_STREAM, StreamConfig, default_space, dense_space
from repro.core.streams import StreamedRunner, _split, streamify_train_step
from repro.core.workloads import get_workload


def _outputs(runner, config):
    outs = runner._dispatch(config)
    return [np.asarray(o) for o in outs]


@pytest.mark.parametrize("name", ["vecadd", "sgemm", "binomial", "histo"])
def test_streamed_equals_single_stream(name):
    wl = get_workload(name)
    rng = np.random.default_rng(0)
    chunked, shared = wl.make_data(wl.datasets[0], rng)
    runner = StreamedRunner(wl, chunked, shared)
    ref = np.concatenate(_outputs(runner, SINGLE_STREAM), axis=0)
    for cfg in [StreamConfig(1, 4), StreamConfig(2, 2), StreamConfig(4, 8)]:
        got = np.concatenate(_outputs(runner, cfg), axis=0)
        # different chunk shapes change XLA's gemm reduction order
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=1e-3)


def test_sum_combine_workloads():
    wl = get_workload("scalarprod")
    rng = np.random.default_rng(1)
    chunked, shared = wl.make_data(wl.datasets[0], rng)
    runner = StreamedRunner(wl, chunked, shared)
    ref = sum(o.sum() for o in _outputs(runner, SINGLE_STREAM))
    got = sum(o.sum() for o in _outputs(runner, StreamConfig(2, 4)))
    np.testing.assert_allclose(got, ref, rtol=1e-4)


def test_split_shapes():
    arrs = {"a": np.arange(12).reshape(12, 1)}
    parts = _split(arrs, 4)
    assert len(parts) == 4
    assert sum(p["a"].shape[0] for p in parts) == 12
    np.testing.assert_array_equal(
        np.concatenate([p["a"] for p in parts]), arrs["a"])


def test_config_spaces():
    space = default_space(32, 64)
    assert StreamConfig(1, 1) in space
    assert all(c.partitions <= 32 and c.tasks <= 64 for c in space)
    dense = dense_space(8, 16)
    assert len(dense) > len(default_space(8, 16))
    assert all(c.tasks >= c.partitions for c in dense)


def test_runner_timing_positive():
    wl = get_workload("vecadd")
    rng = np.random.default_rng(2)
    chunked, shared = wl.make_data(256, rng)
    runner = StreamedRunner(wl, chunked, shared)
    t = runner.run(StreamConfig(1, 2), reps=1)
    assert 0 < t < 10.0


def test_microbatch_grad_equivalence():
    """Grad accumulation over t microbatches == full-batch gradient."""
    key = jax.random.key(0)
    w = {"w": jax.random.normal(key, (8, 4))}
    x = jax.random.normal(jax.random.key(1), (16, 8))
    y = jax.random.normal(jax.random.key(2), (16, 4))

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    base = streamify_train_step(loss_fn, SINGLE_STREAM)
    _, _, g1 = base(w, {"x": x, "y": y})
    for n, unroll in [(2, True), (4, True), (4, False)]:
        micro = streamify_train_step(loss_fn, StreamConfig(1, n),
                                     unroll=unroll)
        loss, _, gn = micro(w, {"x": x, "y": y})
        assert jnp.allclose(g1["w"], gn["w"], atol=1e-5), (n, unroll)
