"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1; unverified].

8 experts < 16-way model axis, so expert-parallelism over 'model' is not
divisible: each expert's d_ff is tensor-parallel-sharded instead
(``sharding="tp"``; see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig, MoEConfig, register

GROK1_314B = register(
    ArchConfig(
        name="grok-1-314b",
        family="moe",
        num_layers=64,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=32768,
        vocab_size=131072,
        moe=MoEConfig(
            num_experts=8,
            top_k=2,
            expert_d_ff=32768,
            sharding="tp",
        ),
        source="hf:xai-org/grok-1",
    )
)
