"""Multi-device semantics (8 forced host devices, separate subprocess —
jax locks the device count at first init, so these scenarios each run via
a child interpreter)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(snippet: str, n_dev: int = 8) -> str:
    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(ROOT, "src"),
        XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
        JAX_PLATFORMS="cpu",
    )
    r = subprocess.run([sys.executable, "-c", snippet], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-4000:]
    return r.stdout


@pytest.mark.slow
def test_sharded_decode_matches_local():
    out = _run("""
import jax, jax.numpy as jnp
from repro.models.attention import decode_attention_local, decode_attention_sharded
mesh = jax.make_mesh((2, 4), ("data", "model"))
B, S, H, KV, hd = 4, 32, 4, 2, 16
ks = jax.random.split(jax.random.key(0), 5)
q = jax.random.normal(ks[0], (B, 1, H, hd))
kn = jax.random.normal(ks[1], (B, 1, KV, hd))
vn = jax.random.normal(ks[2], (B, 1, KV, hd))
kc = jax.random.normal(ks[3], (B, S, KV, hd))
vc = jax.random.normal(ks[4], (B, S, KV, hd))
t = jnp.int32(17)
ref, kr, vr = decode_attention_local(q, kn, vn, kc, vc, 17)
with mesh:
    got, kg, vg = jax.jit(lambda *a: decode_attention_sharded(
        *a, mesh=mesh, dp_axes=("data",)))(q, kn, vn, kc, vc, t)
import numpy as np
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)
np.testing.assert_allclose(np.asarray(kg), np.asarray(kr), atol=1e-6)
print("OK sharded-decode")
""")
    assert "OK sharded-decode" in out


@pytest.mark.slow
def test_compressed_psum_close_to_exact():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.optim.grad_compression import compressed_psum_tree
mesh = jax.make_mesh((8,), ("data",))
g = jax.random.normal(jax.random.key(0), (8, 64))  # row i on device i

def body(g_loc):
    grads = {"w": g_loc[0]}
    err = {"w": jnp.zeros_like(g_loc[0])}
    red, new_err = compressed_psum_tree(grads, err, mesh=mesh,
                                        dp_axes=("data",))
    return red["w"]

with mesh:
    got = shard_map(body, mesh=mesh, in_specs=P("data", None),
                    out_specs=P(None), check_rep=False)(g)
exact = g.mean(0)
err = float(jnp.max(jnp.abs(got - exact)))
scale = float(jnp.max(jnp.abs(g))) / 127.0
assert err < 3 * scale, (err, scale)
print("OK compressed-psum", err)
""")
    assert "OK compressed-psum" in out


def test_elastic_remesh_after_failure():
    out = _run("""
import jax, numpy as np
from repro.launch.elastic import plan_remesh, build_mesh, simulate_failure_and_remesh
mesh = build_mesh(plan_remesh(8, prefer_model=4))
host = {"w": np.arange(32.0).reshape(8, 4)}
axes = {"w": ("batch", "ff")}
new_mesh, tree = simulate_failure_and_remesh(
    host, axes, old_mesh=mesh, lost_devices=2, prefer_model=4)
assert new_mesh.size == 6, new_mesh.size
assert dict(zip(new_mesh.axis_names, new_mesh.devices.shape))["model"] in (2, 3)
np.testing.assert_array_equal(np.asarray(tree["w"]), host["w"])
print("OK elastic", new_mesh.devices.shape)
""")
    assert "OK elastic" in out


def test_small_mesh_dryrun_end_to_end():
    """The dry-run driver machinery on a small (2,4) mesh with a reduced
    model: lower + compile + roofline terms all produced."""
    out = _run("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.model_zoo import build_model
from repro.models.transformer import RunConfig
from repro.parallel.sharding_rules import AxisRules, tree_specs
from repro.roofline.analysis import collective_bytes
from repro.roofline.jaxpr_cost import step_cost
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = AxisRules.pod()
rcfg = RunConfig(rules=rules, attn_expand_kv=True, mesh=mesh,
                 q_block=8, kv_block=8)
m = build_model("yi-9b", rcfg, reduced=True)
param_sds, axes = m.abstract_params()
pshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                      tree_specs(axes, rules))
batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
bshard = {k: NamedSharding(mesh, P("data", None)) for k in batch}
with mesh:
    fn = jax.jit(lambda p, b: m.loss(p, b)[0],
                 in_shardings=(pshard, bshard))
    compiled = fn.lower(param_sds, batch).compile()
    cost = step_cost(fn, param_sds, batch)
coll = collective_bytes(compiled.as_text())
assert cost.flops > 0 and coll["total"] > 0
mem = compiled.memory_analysis()
assert mem.temp_size_in_bytes >= 0
print("OK dryrun-small", int(cost.flops), coll["total"] > 0)
""")
    assert "OK dryrun-small" in out
