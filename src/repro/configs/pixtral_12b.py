"""pixtral-12b [vlm] — pixtral-ViT frontend (STUB) + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409; unverified].

The vision frontend is a stub per assignment: ``input_specs()`` provides
precomputed patch embeddings of shape (B, S, frontend_dim); the backbone
(specified here) projects and decodes them.
"""
from repro.configs.base import ArchConfig, register

PIXTRAL_12B = register(
    ArchConfig(
        name="pixtral-12b",
        family="vlm",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=131072,
        frontend="vision_patches",
        frontend_dim=1024,  # pixtral ViT hidden size
        rope_theta=1_000_000_000.0,
        source="hf:mistralai/Pixtral-12B-2409",
    )
)
