"""Fleet router: tenant-sharded admission over N worker processes.

The router is the fleet's front end.  It owns admission — the same
fifo / priority / fair / deadline :class:`RequestQueue` policies the
single-process schedulers use — and drains the queue in policy order
into per-worker batches: each request goes to the worker its *tenant*
hashes to (stable CRC32, so a tenant's tuning-cache namespace, drift
windows, and model fork all live in exactly one process, and a respawn
reuses the slot so the mapping survives worker death).

The data plane is event-driven end to end (``fleet/wire.py``): the
router parks in :func:`~repro.serving.fleet.wire.wait_any` over every
live slot's result-pipe read end *and* process sentinel, so it wakes
the moment any worker flushes a result frame or dies — there are no
sleep-polls anywhere in ``fleet/``.  Workers batch their return path
into framed ``("results", ...)`` messages of slim positional rows
(schema-versioned; ``REPRO_FLEET_WIRE=legacy`` restores per-request
payload dicts), and the router adapts its dispatch chunk to the
observed admission-queue depth so a deep queue crosses the task pipe in
a few large messages instead of many small ones.

Delivery is at-least-once with explicit handoff: the router keeps every
un-acked request (token → request) per slot, and when a worker dies —
crash, OOM, SIGKILL — it respawns the slot and re-sends the un-acked
work in original admission order.  Because the router closes its copy
of each result pipe's write end at spawn, a frame truncated by a
SIGKILL mid-``send`` surfaces as a clean ``EOFError`` on the read end
(never a hang), and the un-acked remainder is requeued.  Inside the
worker, the PR 8 resilience path makes bad *requests* fail
individually; the router makes bad *processes* fail individually.  A
slot that exceeds its respawn budget fails its remaining requests
terminally (synthetic ``failed`` telemetry) instead of looping — a
submitted request always reaches a terminal status, the same contract
the chaos harness gates.

Telemetry and metrics aggregate centrally: every result carries its
worker-labeled sample, appended live to the router's fleet
:class:`TelemetryLog` (and observed by a fleet-level
:class:`DriftDetector` — the cross-worker drift view; refinement itself
stays in the workers, which own the caches).  Each ``run()`` also
accounts the IPC tax explicitly: workers report their engine wall per
frame, and ``last_run["ipc_overhead_fraction"]`` is the fraction of
router wall NOT covered by the busiest worker's compute — the number
``--serve-fleet`` reports and CI gates.  At shutdown each worker ships
its ``MetricsRegistry`` snapshot in the goodbye handshake and
:func:`merge_metrics` folds them into one worker-labeled snapshot, so
``launch/stats.py`` renders a fleet exactly like a single process.
"""
from __future__ import annotations

import collections
import dataclasses
import multiprocessing
import os
import signal
import time
import zlib
from typing import Dict, List, Optional

from repro.serving.clock import SystemClock
from repro.serving.fleet.aggregate import (fleet_summary, merge_metrics,
                                           payload_from_sample)
from repro.serving.fleet.wire import parse_results_frame, wait_any
from repro.serving.fleet.worker import WorkerConfig, worker_main
from repro.serving.observability import NULL_METRICS
from repro.serving.queue import RequestQueue, WorkloadRequest
from repro.serving.refinement import DriftDetector
from repro.serving.telemetry import TelemetryLog, TelemetrySample

#: floor of the adaptive dispatch chunk: a shallow queue still sends
#: runs of a few requests so delivery pipelines with worker compute
DISPATCH_FLOOR = 4

#: ceiling of router-side dispatch coalescing: even a very deep queue
#: never puts more than this many requests in one task-pipe message
#: (bounds both the pickle spike and the blast radius of a send racing
#: a dying worker)
MAX_DISPATCH_CHUNK = 64

#: safety-net heartbeat for the event-driven collect loop.  Progress
#: never waits on it — frames and deaths both wake ``wait_any``
#: immediately — it only bounds how stale a missed-edge diagnosis can go
COLLECT_HEARTBEAT_S = 0.25


def shard_for(tenant: str, n_workers: int) -> int:
    """Stable tenant → worker-slot mapping.  CRC32, not ``hash()``:
    Python string hashing is salted per process, and the mapping must
    agree between a router, its respawned workers, and tests."""
    return zlib.crc32(tenant.encode("utf-8")) % max(1, n_workers)


def _ensure_child_pythonpath() -> None:
    """Spawn children re-import ``repro`` from scratch and do NOT
    inherit the parent's ``sys.path`` edits (the ``sys.path.insert``
    that ``PYTHONPATH=src``-less entry points rely on) — so pin the
    package root into the environment the children will inherit."""
    import repro
    # ``repro`` may be a namespace package (no __init__.py), where
    # __file__ is None — __path__ always holds the package directory
    pkg_dir = (os.path.dirname(os.path.abspath(repro.__file__))
               if getattr(repro, "__file__", None)
               else os.path.abspath(list(repro.__path__)[0]))
    pkg_root = os.path.dirname(pkg_dir)
    existing = os.environ.get("PYTHONPATH", "")
    if pkg_root not in existing.split(os.pathsep):
        os.environ["PYTHONPATH"] = (
            pkg_root + (os.pathsep + existing if existing else ""))


@dataclasses.dataclass
class _Slot:
    """One worker seat.  The seat (index) is stable; the process in it
    is replaceable."""
    index: int
    cfg: WorkerConfig
    proc: multiprocessing.process.BaseProcess
    task_q: object
    #: read end of this worker's result pipe; the write end lives only
    #: in the child (the router closes its copy at spawn), so worker
    #: death EOFs the channel instead of wedging it
    conn: object
    pid: Optional[int] = None
    model_tag: Optional[str] = None
    respawns: int = 0
    #: un-acked work: token → the router's retained request copy,
    #: insertion == admission order (dicts preserve it) for fair requeue
    outstanding: Dict[str, WorkloadRequest] = dataclasses.field(
        default_factory=dict)
    bye: Optional[dict] = None
    fatal: Optional[str] = None
    refresh_acks: int = 0
    abandoned: bool = False      # respawn budget exhausted

    @property
    def label(self) -> str:
        return self.cfg.label


class FleetRouter:
    """Front-end for N spawn-isolated serving workers.

    ``worker`` is the :class:`WorkerConfig` template; the router stamps
    ``worker_id`` per slot and derives per-slot telemetry/cache paths
    from the template's (``path`` → ``path.w<i>``) so namespaces never
    collide.  ``telemetry_path`` is the *merged* fleet JSONL.
    ``dispatch_chunk=None`` (default) enables adaptive dispatch
    coalescing (see :meth:`_chunk_for_depth`); an explicit int pins a
    fixed chunk — tests and experiments that need exact framing opt out
    of adaptivity.  ``metrics`` (a
    :class:`~repro.serving.MetricsRegistry`) turns on router-side
    data-plane instrumentation — frame counts/sizes and the per-run
    ``fleet.ipc.overhead_fraction`` gauge.  Use as a context manager,
    or ``start() … run() … close()``; ``close()`` is idempotent and
    leaves no live children behind (graceful stop → join → terminate →
    kill escalation).
    """

    def __init__(self, n_workers: int, *,
                 worker: Optional[WorkerConfig] = None,
                 policy: str = "fifo",
                 telemetry_path: Optional[str] = None,
                 drift: Optional[DriftDetector] = None,
                 clock=None,
                 metrics=None,
                 max_respawns: int = 3,
                 spawn_timeout_s: float = 120.0,
                 shutdown_grace_s: float = 15.0,
                 dispatch_chunk: Optional[int] = None):
        assert n_workers >= 1, n_workers
        self.n_workers = n_workers
        self.worker_template = worker if worker is not None else WorkerConfig()
        self.clock = clock if clock is not None else SystemClock()
        self.queue = RequestQueue(policy, clock=self.clock)
        self.telemetry = TelemetryLog(telemetry_path)
        # fleet-level drift observer over the merged stream (refinement
        # stays worker-local where the caches live); threshold follows
        # the worker template so the two views judge by the same bar
        self.drift = drift if drift is not None else DriftDetector(
            threshold=self.worker_template.drift_threshold,
            load_discount=0.5)
        self.max_respawns = max_respawns
        self.spawn_timeout_s = spawn_timeout_s
        self.shutdown_grace_s = shutdown_grace_s
        self.dispatch_chunk = (None if dispatch_chunk is None
                               else max(1, dispatch_chunk))
        self.metrics = metrics if metrics is not None else NULL_METRICS
        self._m_dispatch_frames = self.metrics.counter(
            "fleet.dispatch.frames")
        self._m_dispatch_chunk = self.metrics.histogram(
            "fleet.dispatch.chunk")
        self._m_result_frames = self.metrics.counter("fleet.result.frames")
        self._m_frame_size = self.metrics.histogram("fleet.result.frame_size")
        self._m_ipc_fraction = self.metrics.gauge(
            "fleet.ipc.overhead_fraction")
        self.stats: collections.Counter = collections.Counter()
        self.worker_metrics: Dict[str, Optional[dict]] = {}
        self.worker_summaries: Dict[str, dict] = {}
        #: data-plane accounting of the most recent :meth:`run` —
        #: ``{"wall_s", "requests", "worker_busy_s", "ipc_overhead_fraction"}``
        self.last_run: dict = {}
        self._ctx = multiprocessing.get_context("spawn")
        self._slots: List[_Slot] = []
        #: worker-reported engine wall per label, reset per run() — the
        #: compute side of the ipc_overhead_fraction ledger
        self._run_busy: Dict[str, float] = {}
        #: terminal payloads for the *current* run() only — handed back
        #: and dropped when run() returns, so a long-lived router does
        #: not accumulate every historical result in memory
        self._results: Dict[str, dict] = {}
        #: all tokens ever acked (strings only) — survives across runs
        #: so a late replay from a respawned worker is still suppressed
        self._seen: set = set()
        self._kill_plan: Optional[tuple] = None  # (slot_idx, after_n)
        self._started = False
        self._closed = False

    # -- lifecycle ------------------------------------------------------------

    def start(self) -> "FleetRouter":
        if self._started:
            return self
        _ensure_child_pythonpath()
        for i in range(self.n_workers):
            self._slots.append(self._spawn(i))
        for slot in self._slots:
            self._wait_ready(slot)
        self._started = True
        return self

    def _derived_cfg(self, index: int) -> WorkerConfig:
        def suffix(path: Optional[str]) -> Optional[str]:
            return f"{path}.w{index}" if path else None
        t = self.worker_template
        return dataclasses.replace(
            t, worker_id=index,
            telemetry_path=suffix(t.telemetry_path),
            cache_path=suffix(t.cache_path))

    def _spawn(self, index: int, respawns: int = 0) -> _Slot:
        cfg = self._derived_cfg(index)
        task_q = self._ctx.Queue()
        recv_conn, send_conn = self._ctx.Pipe(duplex=False)
        proc = self._ctx.Process(
            target=worker_main, args=(cfg, task_q, send_conn),
            name=f"fleet-{cfg.label}", daemon=True)
        proc.start()
        # the child owns the ONLY write end from here on: when it dies,
        # the pipe EOFs and a half-sent frame raises EOFError in
        # _drain_slot instead of blocking a read forever
        send_conn.close()
        return _Slot(index=index, cfg=cfg, proc=proc,
                     task_q=task_q, conn=recv_conn, respawns=respawns)

    def _wait_ready(self, slot: _Slot) -> None:
        deadline = time.monotonic() + self.spawn_timeout_s
        while True:
            timeout = deadline - time.monotonic()
            if timeout <= 0:
                raise TimeoutError(
                    f"fleet worker {slot.label} not ready within "
                    f"{self.spawn_timeout_s:.0f}s")
            wait_any([slot.conn, slot.proc.sentinel], timeout=timeout)
            msg = None
            try:
                if slot.conn.poll():
                    msg = slot.conn.recv()
            except (EOFError, OSError):
                pass
            if msg is not None:
                if msg[0] == "ready":
                    slot.pid = msg[2]
                    slot.model_tag = msg[3]
                    return
                if msg[0] == "fatal":
                    raise RuntimeError(
                        f"fleet worker {slot.label} failed to start: "
                        f"{msg[2]}")
                continue    # stale kind: keep draining
            if not slot.proc.is_alive():
                raise RuntimeError(
                    f"fleet worker {slot.label} died during startup "
                    f"(exitcode {slot.proc.exitcode})")

    # -- admission ------------------------------------------------------------

    def shard_for(self, tenant: str) -> int:
        return shard_for(tenant, self.n_workers)

    def submit(self, request: WorkloadRequest) -> WorkloadRequest:
        if request.arrival_s is None:
            request.arrival_s = self.clock.now()
        self.stats[f"tenant.{request.tenant}.submitted"] += 1
        return self.queue.push(request)

    def submit_all(self, requests) -> None:
        for r in requests:
            self.submit(r)

    # -- serving --------------------------------------------------------------

    def run(self) -> List[dict]:
        """Drain the admission queue through the fleet; returns one
        terminal result payload per admitted request, in admission
        (policy) order.  Requests a deadline policy sheds at pop time
        are dropped here exactly as the single-process schedulers drop
        them — counted on ``queue.shed``, no result entry."""
        if not self._started:
            self.start()
        t0 = time.perf_counter()
        self._run_busy = {}
        depth = len(self.queue)
        chunk = self._chunk_for_depth(depth)
        order: List[str] = []
        batches: List[List[tuple]] = [[] for _ in self._slots]
        while len(self.queue):
            try:
                req = self.queue.pop()
            except IndexError:
                break                 # deadline policy shed the rest
            slot_i = self.shard_for(req.tenant)
            slot = self._slots[slot_i]
            token = req.trace_id
            order.append(token)
            if slot.abandoned:
                # seat already failed for good — don't route new work
                # into closed queues; fail it terminally at admission
                self._on_result(slot, token, self._terminal_failure(slot, req))
                continue
            slot.outstanding[token] = req
            batches[slot_i].append((token, req))
        for slot, batch in zip(self._slots, batches):
            self._send_batch(slot, batch, chunk=chunk)
        self._collect()
        wall = time.perf_counter() - t0
        out = [self._results[t] for t in order]
        for t in order:                # scope payloads to this run
            self._results.pop(t, None)
        busiest = max(self._run_busy.values(), default=0.0)
        fraction = (max(0.0, wall - busiest) / wall
                    if order and wall > 0 else None)
        self.last_run = {
            "wall_s": wall,
            "requests": len(order),
            "worker_busy_s": dict(sorted(self._run_busy.items())),
            # None in legacy wire mode (workers don't report busy wall)
            # and on empty runs — consumers must treat it as "unknown"
            "ipc_overhead_fraction": (fraction if self._run_busy else None),
        }
        if self.last_run["ipc_overhead_fraction"] is not None:
            self._m_ipc_fraction.set(self.last_run["ipc_overhead_fraction"])
        return out

    def _chunk_for_depth(self, depth: int) -> int:
        """Adaptive dispatch coalescing: target one task-pipe message
        per worker when the admission queue is deep (an even share of
        the depth each), floored at :data:`DISPATCH_FLOOR` so a shallow
        queue still pipelines, and capped at :data:`MAX_DISPATCH_CHUNK`
        so one message never carries an unbounded pickle.  An explicit
        ``dispatch_chunk`` pins the chunk instead."""
        if self.dispatch_chunk is not None:
            return self.dispatch_chunk
        share = -(-depth // max(1, len(self._slots) or self.n_workers))
        return max(DISPATCH_FLOOR, min(MAX_DISPATCH_CHUNK, share))

    def _send_batch(self, slot: _Slot, batch: List[tuple],
                    chunk: Optional[int] = None) -> None:
        # chunked sends keep delivery pipelined (the worker folds queued
        # chunks back into one engine window) and bound the blast radius
        # of a send racing a dying worker
        chunk = chunk if chunk is not None else self._chunk_for_depth(
            len(batch))
        for j in range(0, len(batch), chunk):
            try:
                slot.task_q.put(("serve", batch[j:j + chunk]))
            except (OSError, ValueError):
                break   # dead queue; the death handler requeues
            self.stats["dispatch_frames"] += 1
            self._m_dispatch_frames.inc()
            self._m_dispatch_chunk.observe(len(batch[j:j + chunk]))

    def _collect(self) -> None:
        """Event-driven result collection: drain every slot, then park
        in ``wait_any`` over the live result pipes AND process sentinels
        until something actually happens — a flushed frame or a death
        both wake the loop immediately.  The heartbeat timeout is a
        safety net, not a poll interval: no progress path depends on
        it."""
        while any(s.outstanding for s in self._slots):
            progressed = False
            for slot in self._slots:
                if not slot.abandoned:   # abandoned ⇒ channels are closed
                    progressed |= self._drain_slot(slot)
            self._maybe_fire_kill()
            for slot in self._slots:
                if slot.outstanding and not slot.proc.is_alive():
                    # final drain: results the worker flushed before
                    # dying are still valid
                    self._drain_slot(slot)
                    if slot.outstanding:
                        self._handle_death(slot)
                        progressed = True
            if progressed:
                continue
            waitables = []
            for slot in self._slots:
                if slot.abandoned:
                    continue
                if slot.outstanding or slot.proc.is_alive():
                    waitables.append(slot.conn)
                    waitables.append(slot.proc.sentinel)
            if not waitables:
                # every seat is abandoned; outstanding was terminally
                # failed in _handle_death — nothing left to wait for
                break
            wait_any(waitables, timeout=COLLECT_HEARTBEAT_S)

    def _drain_slot(self, slot: _Slot) -> bool:
        progressed = False
        while True:
            try:
                if not slot.conn.poll():
                    return progressed
                msg = slot.conn.recv()
            except (EOFError, OSError, ValueError, BrokenPipeError):
                # EOFError: pipe torn down with the worker — including a
                # frame truncated by SIGKILL mid-send (the router holds
                # no write end, so a partial frame EOFs instead of
                # hanging); OSError/ValueError: the connection itself
                # was close()d (abandoned slot) — same meaning, nothing
                # more will ever arrive
                return progressed
            progressed = True
            kind = msg[0]
            if kind == "results":
                busy_s, items = parse_results_frame(msg)
                self._run_busy[slot.label] = \
                    self._run_busy.get(slot.label, 0.0) + busy_s
                self.stats["result_frames"] += 1
                self._m_result_frames.inc()
                self._m_frame_size.observe(len(items))
                for token, row in items:
                    sample = TelemetrySample.from_row(row)
                    self._on_result(slot, token,
                                    payload_from_sample(sample),
                                    sample=sample)
            elif kind == "result":       # legacy wire: one payload per
                self.stats["result_frames"] += 1     # request
                self._m_result_frames.inc()
                self._m_frame_size.observe(1)
                self._on_result(slot, msg[2], msg[3])
            elif kind == "bye":
                slot.bye = msg[2]
            elif kind == "fatal":
                slot.fatal = msg[2]
                self.stats["worker_fatals"] += 1
            elif kind == "refreshed":
                slot.refresh_acks += 1
                slot.model_tag = msg[2] or slot.model_tag
                if msg[3]:
                    self.stats["refresh_failures"] += 1
            # "pong"/"ready" need no bookkeeping here

    def _on_result(self, slot: _Slot, token: str, payload: dict,
                   sample: Optional[TelemetrySample] = None) -> None:
        # at-least-once delivery: a respawn may replay work whose result
        # the dead worker already flushed — first ack wins, replays drop
        # (the token set, not the payload map: payloads are scoped to
        # one run() but a replay may straggle in much later)
        if token in self._seen:
            self.stats["duplicate_results"] += 1
            slot.outstanding.pop(token, None)
            return
        self._seen.add(token)
        slot.outstanding.pop(token, None)
        self._results[token] = payload
        if sample is None:
            sample = TelemetrySample.from_json(payload["sample"])
        self.telemetry.append(sample)
        if sample.rel_error is not None:
            if self.drift.observe(sample.key, sample.rel_error,
                                  load_factor=sample.load_factor):
                # cross-worker drift view: observational (workers refine
                # locally); reset so one fleet event is counted once
                self.stats["fleet_drift_fired"] += 1
                self.drift.reset(sample.key)

    # -- failure handling -----------------------------------------------------

    def inject_kill(self, slot_index: int, after_results: int = 1) -> None:
        """Chaos hook for benchmarks/tests: SIGKILL the process in
        ``slot_index`` once ``after_results`` results of the current
        ``run()`` have been collected fleet-wide.  Counted on
        ``stats['injected_kills']`` so harnesses can separate planned
        kills from real crashes."""
        self._kill_plan = (slot_index, after_results)

    def _maybe_fire_kill(self) -> None:
        if self._kill_plan is None:
            return
        slot_i, after = self._kill_plan
        if len(self._results) < after:
            return
        self._kill_plan = None
        slot = self._slots[slot_i]
        if slot.proc.is_alive() and slot.pid:
            os.kill(slot.pid, signal.SIGKILL)
            self.stats["injected_kills"] += 1

    def _handle_death(self, slot: _Slot) -> None:
        """Respawn the slot and requeue its un-acked work; past the
        respawn budget, fail the remainder terminally."""
        self.stats["worker_deaths"] += 1
        pending = list(slot.outstanding.items())   # admission order
        self._discard_channels(slot)
        if slot.respawns >= self.max_respawns:
            self.stats["abandoned_slots"] += 1
            slot.abandoned = True
            for token, req in pending:
                self._on_result(slot, token,
                                self._terminal_failure(slot, req))
            slot.outstanding.clear()
            return
        fresh = self._spawn(slot.index, respawns=slot.respawns + 1)
        self._wait_ready(fresh)
        fresh.outstanding = dict(pending)
        fresh.fatal = slot.fatal
        self._slots[slot.index] = fresh
        self.stats["worker_respawns"] += 1
        self.stats["requeued_requests"] += len(pending)
        self._send_batch(fresh, pending)

    def _terminal_failure(self, slot: _Slot, req: WorkloadRequest) -> dict:
        error = (f"worker {slot.label} died "
                 f"(respawn budget {self.max_respawns} exhausted)")
        sample = TelemetrySample(
            seq=req.seq, tenant=req.tenant, workload=req.workload,
            key=req.workload, backend=self.worker_template.backend,
            partitions=0, tasks=0, cache_hit=False, predicted_s=None,
            measured_s=None, rel_error=None, status="failed", error=error,
            t_enqueue_s=req.arrival_s, deadline_s=req.deadline_s,
            trace_id=req.trace_id, worker=slot.label)
        return {"status": "failed", "error": error,
                "workload": req.workload, "tenant": req.tenant,
                "config": None, "measured_s": None, "predicted_s": None,
                "cache_hit": False, "refined": False,
                "sample": sample.to_json()}

    @staticmethod
    def _discard_channels(slot: _Slot) -> None:
        # a SIGKILL mid-put can leave the task queue's pipe mid-frame;
        # cancel_join_thread so the feeder thread never blocks exit on
        # bytes nobody will read.  The result connection just closes —
        # the read end is ours alone
        try:
            slot.task_q.close()
            slot.task_q.cancel_join_thread()
        except (OSError, ValueError):
            pass
        try:
            slot.conn.close()
        except (OSError, ValueError):
            pass

    # -- model distribution ---------------------------------------------------

    def refresh_model(self, spec: str = "latest",
                      timeout_s: float = 60.0) -> Dict[str, Optional[str]]:
        """Broadcast a model refresh (registry ``load(spec)`` +
        ``swap_model`` in every worker) and wait for the acks — parked
        in the shared event-driven wait, woken per ack or death."""
        live = [s for s in self._slots if s.proc.is_alive()]
        baseline = {s.label: s.refresh_acks for s in live}
        for slot in live:
            slot.task_q.put(("refresh", spec))
        deadline = time.monotonic() + timeout_s
        pending = {s.label for s in live}
        while pending:
            for slot in live:
                self._drain_slot(slot)
                if slot.label in pending and (
                        slot.refresh_acks > baseline[slot.label]
                        or not slot.proc.is_alive()):
                    pending.discard(slot.label)
            remaining = deadline - time.monotonic()
            if not pending or remaining <= 0:
                break
            wait_any([w for slot in live if slot.label in pending
                      for w in (slot.conn, slot.proc.sentinel)],
                     timeout=remaining)
        return {s.label: s.model_tag for s in self._slots}

    # -- shutdown -------------------------------------------------------------

    def close(self) -> None:
        """Graceful, idempotent teardown: stop → goodbye handshake →
        join, escalating to terminate/kill for anything that lingers.
        No child of this router survives close()."""
        if self._closed:
            return
        self._closed = True
        for slot in self._slots:
            if slot.proc.is_alive():
                try:
                    slot.task_q.put(("stop",))
                except (OSError, ValueError):
                    pass
        deadline = time.monotonic() + self.shutdown_grace_s
        for slot in self._slots:
            while (slot.bye is None and slot.proc.is_alive()
                   and time.monotonic() < deadline):
                # event-driven: the goodbye frame or the process exit
                # wakes this immediately; the deadline only bounds a
                # worker that is wedged mid-request
                wait_any([slot.conn, slot.proc.sentinel],
                         timeout=deadline - time.monotonic())
                self._drain_slot(slot)
            if not slot.abandoned:       # abandoned ⇒ channels are closed
                self._drain_slot(slot)
            slot.proc.join(max(0.1, deadline - time.monotonic()))
            if slot.proc.is_alive():
                slot.proc.terminate()
                slot.proc.join(2.0)
            if slot.proc.is_alive():
                slot.proc.kill()
                slot.proc.join(2.0)
            if slot.bye is not None:
                self.worker_metrics[slot.label] = slot.bye.get("metrics")
                self.worker_summaries[slot.label] = slot.bye.get("summary")
            else:
                self.worker_metrics.setdefault(slot.label, None)
            self._discard_channels(slot)
        self.telemetry.close()

    def __enter__(self) -> "FleetRouter":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- fleet view -----------------------------------------------------------

    @property
    def closed(self) -> bool:
        return self._closed

    def metrics_snapshot(self) -> dict:
        """Worker-labeled merged metrics (populated at close())."""
        return merge_metrics(self.worker_metrics)

    def summary(self) -> dict:
        s = fleet_summary(self.telemetry.samples)
        s["workers"] = self.n_workers
        s["worker_deaths"] = self.stats.get("worker_deaths", 0)
        s["worker_respawns"] = self.stats.get("worker_respawns", 0)
        s["injected_kills"] = self.stats.get("injected_kills", 0)
        s["requeued_requests"] = self.stats.get("requeued_requests", 0)
        s["duplicate_results"] = self.stats.get("duplicate_results", 0)
        s["fleet_drift_fired"] = self.stats.get("fleet_drift_fired", 0)
        s["dispatch_frames"] = self.stats.get("dispatch_frames", 0)
        s["result_frames"] = self.stats.get("result_frames", 0)
        s["ipc_overhead_fraction"] = self.last_run.get(
            "ipc_overhead_fraction")
        s["shed"] = len(self.queue.shed)
        if self.worker_metrics and any(self.worker_metrics.values()):
            s["metrics"] = self.metrics_snapshot()
        return s
