"""§Perf hillclimbs: run candidate variants for the three selected cells,
record hypothesis -> before -> after (per-variant dry-run JSONs land in
benchmarks/data/dryrun/ with tags).

Cells (per the assignment's selection rule):
  A. xlstm-350m   train_4k — worst baseline roofline fraction (0.032)
  B. arctic-480b  train_4k — most collective-bound (coll = 3.6x compute)
  C. codeqwen1.5-7b train_4k — most representative of the paper's
     technique: the spatial (#partitions -> mesh-factorization/TP-degree)
     x temporal (#tasks -> microbatches) choice is searched and the
     roofline cost function ranks the candidates (autotuner at pod scale).
"""
from __future__ import annotations

import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from benchmarks.dryrun_sweep import run_one  # noqa: E402

# (arch, shape, tag, extra_flags, hypothesis)
VARIANTS = [
    # --- Cell A: xlstm-350m train_4k ------------------------------------
    ("xlstm-350m", "train_4k", "dpall",
     ["--dp-over-model"],
     "350M params 16-way TP is all-communication (coll 29x compute): "
     "pure DP over all 256 chips + 256-way FSDP should cut the collective "
     "term to ~param-traffic only (~2*0.7GB bf16 / 50GB/s ~ 30ms)"),
    ("xlstm-350m", "train_4k", "dpall_mb4",
     ["--dp-over-model", "--microbatches", "4"],
     "with DP-all, 4 microbatches shrink activation temp 4x for fit; "
     "collective volume unchanged"),
    # --- Cell B: arctic-480b train_4k -----------------------------------
    ("arctic-480b", "train_4k", "nofsdp_bf16opt",
     ["--no-fsdp", "--opt-dtype", "bf16"],
     "FSDP gathers are 18GB/chip of the 501GB; removing FSDP also lets "
     "XLA keep weights resident (params replicated over data) - predict "
     "~5-10% collective cut, big temp cut; bf16 opt halves opt memory"),
    ("arctic-480b", "train_4k", "remat_none",
     ["--remat", "none"],
     "remat-dots recomputes the fwd TP psums in the bwd: remat=none "
     "should remove the recompute all-reduces (~1/3 of collective) at "
     "the price of temp memory"),
    ("arctic-480b", "train_4k", "cf1_mb4",
     ["--capacity-factor", "1.0", "--microbatches", "4"],
     "capacity 1.25->1.0 cuts expert compute & combine traffic ~20%; "
     "4 microbatches cut activation temp ~4x (fit) with no volume change"),
    ("arctic-480b", "train_4k", "remat_none_cf1",
     ["--remat", "none", "--capacity-factor", "1.0"],
     "combine the two confirmed winners"),
    # --- Cell C: codeqwen1.5-7b train_4k — candidate set the autotuner
    #     ranks (spatial x temporal grid, paper Fig. 4 at pod scale) -----
    ("codeqwen1.5-7b", "train_4k", "dpall",
     ["--dp-over-model"],
     "7B params: TP16 costs 4.9s/chip of psums; DP-all costs only FSDP "
     "param gathers (2x14.5GB bf16 = 580ms) + grad reduce -> predict "
     "collective 4.9s -> ~0.9s, bound flips to compute (1.16s), "
     "fraction 0.21 -> ~0.8"),
    ("codeqwen1.5-7b", "train_4k", "dpall_mb2",
     ["--dp-over-model", "--microbatches", "2"],
     "temporal knob: 2 microbatches, overlap + halve temp"),
    ("codeqwen1.5-7b", "train_4k", "dpall_mb4",
     ["--dp-over-model", "--microbatches", "4"],
     "4 microbatches: more overlap slack, temp /4"),
    ("codeqwen1.5-7b", "train_4k", "tp16_mb4",
     ["--microbatches", "4"],
     "keep TP16 but microbatch (control: does granularity alone help?)"),
]


def main():
    results = []
    for arch, shape, tag, flags, hyp in VARIANTS:
        rec = run_one(arch, shape, False, extra=tuple(flags), tag=tag)
        r = rec.get("roofline", {})
        m = rec.get("memory_analysis", {})
        results.append({
            "arch": arch, "shape": shape, "tag": tag, "hypothesis": hyp,
            "compute_s": r.get("compute_s"), "memory_s": r.get("memory_s"),
            "collective_s": r.get("collective_s"),
            "dominant": r.get("dominant"),
            "roofline_fraction": r.get("roofline_fraction"),
            "temp_GB": round(m.get("temp_size_in_bytes", 0) / 2**30, 1),
            "error": rec.get("error", "")[:200] if "error" in rec else "",
        })
        print(json.dumps(results[-1], indent=None), flush=True)
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "data", "hillclimb_results.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
