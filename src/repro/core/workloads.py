"""The streamed-benchmark suite: 39 programs mirroring the paper's Table 4
(NVIDIA SDK / AMD SDK / Parboil / POLYBENCH), as chunkable JAX kernels.

Each workload is a data-parallel kernel over a leading "iteration space"
axis (the paper's outer parallel loop).  The streamed executor
(repro.core.streams) splits that axis into #tasks transfer/compute chunks
and #partitions kernel sub-slices.  ``chunked`` arrays are partitioned;
``shared`` arrays are transferred once (the paper's buffer-validity
tracking elides their re-transfer).

Like the paper's convolutionFFT2d / convolutionSeparable, the conv/fft
entries carry algorithm-dependent parameters and count as separate
programs (fftx2y2 is the third FFT aspect variant, bringing the suite to
exactly 39 programs).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    suite: str
    kernel: Callable          # kernel(chunk: dict, shared: dict) -> array
    make_data: Callable       # make_data(scale, rng) -> (chunked, shared)
    datasets: tuple           # scale parameters (>= ~10 per workload)
    sequential_inner: bool = False
    # how per-chunk results relate to the unsplit run:
    #   concat — row-independent (result rows concatenate)
    #   sum    — chunks yield partial reductions that add up
    #   local  — chunk-local statistics (paper's generator would keep the
    #            reduction on one stream); only executability is asserted
    combine: str = "concat"


_REGISTRY: dict[str, Workload] = {}


def register(wl: Workload) -> Workload:
    assert wl.name not in _REGISTRY
    _REGISTRY[wl.name] = wl
    return wl


def get_workload(name: str) -> Workload:
    return _REGISTRY[name]


def list_workloads() -> list[str]:
    return sorted(_REGISTRY)


def _scales(lo: int, hi: int, n: int = 10) -> tuple:
    """Dataset sizes: {2^k} U {3*2^k} in [lo, hi].  Power-of-two-friendly
    sizes keep the streamed chunk shapes equal across task splits, so the
    jit cache stays small during exhaustive profiling."""
    out = set()
    v = 1
    while v <= hi:
        if v >= lo:
            out.add(v)
        if lo <= 3 * v <= hi:
            out.add(3 * v)
        v *= 2
    return tuple(sorted(out))


def _f32(rng, *shape):
    return rng.standard_normal(shape, dtype=np.float32)


# ---------------------------------------------------------------------------
# NVIDIA SDK (11 programs)
# ---------------------------------------------------------------------------

register(Workload(
    "vecadd", "nvidia",
    kernel=lambda c, s: c["a"] + c["b"],
    make_data=lambda n, rng: (
        {"a": _f32(rng, n, 256), "b": _f32(rng, n, 256)}, {}),
    datasets=_scales(256, 8192),
))

register(Workload(
    "dotprod", "nvidia",
    kernel=lambda c, s: jnp.sum(c["a"] * c["b"], axis=1),
    make_data=lambda n, rng: (
        {"a": _f32(rng, n, 512), "b": _f32(rng, n, 512)}, {}),
    datasets=_scales(128, 4096),
))

register(Workload(
    "scalarprod", "nvidia",
    kernel=lambda c, s: jnp.sum(c["a"] * c["b"], axis=(0, 1))[None],
    make_data=lambda n, rng: (
        {"a": _f32(rng, n, 1024), "b": _f32(rng, n, 1024)}, {}),
    datasets=_scales(128, 4096),
    combine="sum",
))

register(Workload(
    "transpose", "nvidia",
    kernel=lambda c, s: jnp.swapaxes(c["x"], 1, 2) * 1.0,
    make_data=lambda n, rng: ({"x": _f32(rng, n, 64, 64)}, {}),
    datasets=_scales(32, 1024),
))

register(Workload(
    "mvmult", "nvidia",
    kernel=lambda c, s: c["A"] @ s["v"],
    make_data=lambda n, rng: (
        {"A": _f32(rng, n, 768)}, {"v": _f32(rng, 768)}),
    datasets=_scales(128, 8192),
))


def _fwt_kernel(c, s):
    x = c["x"]
    n = x.shape[-1]
    h = 1
    while h < n:
        x = x.reshape(x.shape[0], -1, 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2).reshape(x.shape[0], n)
        h *= 2
    return x


register(Workload(
    "fwt", "nvidia",
    kernel=_fwt_kernel,
    make_data=lambda n, rng: ({"x": _f32(rng, n, 512)}, {}),
    datasets=_scales(64, 2048),
))


def _montecarlo_kernel(c, s):
    # European call payoff over per-row precomputed gaussian paths.
    S0, K, r, sig, T = 100.0, 100.0, 0.05, 0.2, 1.0
    z = c["z"]
    st = S0 * jnp.exp((r - 0.5 * sig**2) * T + sig * np.sqrt(T) * z)
    payoff = jnp.maximum(st - K, 0.0)
    return jnp.exp(-r * T) * jnp.mean(payoff, axis=1)


register(Workload(
    "montecarlo", "nvidia",
    kernel=_montecarlo_kernel,
    make_data=lambda n, rng: ({"z": _f32(rng, n, 512)}, {}),
    datasets=_scales(64, 2048),
))


def _convsep_kernel_radius(radius):
    def kern(c, s):
        img = c["img"]
        k = s["k"]
        # separable conv: rows then cols, via shift-and-add
        out = jnp.zeros_like(img)
        for i in range(-radius, radius + 1):
            out = out + k[i + radius] * jnp.roll(img, i, axis=2)
        out2 = jnp.zeros_like(out)
        for i in range(-radius, radius + 1):
            out2 = out2 + k[i + radius] * jnp.roll(out, i, axis=1)
        return out2
    return kern


register(Workload(
    "convsepr1", "nvidia",
    kernel=_convsep_kernel_radius(1),
    make_data=lambda n, rng: (
        {"img": _f32(rng, n, 64, 64)}, {"k": _f32(rng, 3)}),
    datasets=_scales(16, 512),
))

register(Workload(
    "convsepr8", "nvidia",
    kernel=_convsep_kernel_radius(8),
    make_data=lambda n, rng: (
        {"img": _f32(rng, n, 64, 64)}, {"k": _f32(rng, 17)}),
    datasets=_scales(16, 512),
))


def _fft_kernel(c, s):
    return jnp.abs(jnp.fft.fft2(c["img"]))


def _register_fft(name, h, w):
    register(Workload(
        name, "nvidia",
        kernel=_fft_kernel,
        make_data=lambda n, rng, h=h, w=w: ({"img": _f32(rng, n, h, w)}, {}),
        datasets=_scales(16, 512),
    ))


_register_fft("fftx1y1", 64, 64)
_register_fft("fftx4y3", 128, 32)
_register_fft("fftx2y2", 32, 128)

# ---------------------------------------------------------------------------
# AMD SDK (4 programs)
# ---------------------------------------------------------------------------


def _binomial_kernel(c, s):
    # T-step binomial option pricing per row (sequential backward induction).
    T = 48
    S0, K_, r, sig = c["S0"], 100.0, 0.05, 0.2
    dt = 1.0 / T
    u = np.exp(0.2 * np.sqrt(dt))
    d = 1.0 / u
    p = (np.exp(r * dt) - d) / (u - d)
    disc = np.exp(-r * dt)
    j = jnp.arange(T + 1, dtype=jnp.float32)
    st = S0[:, None] * (u ** j) * (d ** (T - j))
    vals = jnp.maximum(st - K_, 0.0)

    def step(v, _):
        v = disc * (p * v[:, 1:] + (1 - p) * v[:, :-1])
        v = jnp.pad(v, ((0, 0), (0, 1)))
        return v, None

    vals, _ = jax.lax.scan(step, vals, None, length=T)
    return vals[:, 0]


register(Workload(
    "binomial", "amd",
    kernel=_binomial_kernel,
    make_data=lambda n, rng: (
        {"S0": 90 + 20 * rng.random(n).astype(np.float32)}, {}),
    datasets=_scales(256, 16384),
    sequential_inner=True,
))


def _blackscholes_kernel(c, s):
    S, K, T = c["S"], c["K"], c["T"]
    r, sig = 0.05, 0.2
    d1 = (jnp.log(S / K) + (r + 0.5 * sig**2) * T) / (sig * jnp.sqrt(T))
    d2 = d1 - sig * jnp.sqrt(T)
    cdf = lambda x: 0.5 * (1.0 + jax.lax.erf(x / np.sqrt(2.0)))
    call = S * cdf(d1) - K * jnp.exp(-r * T) * cdf(d2)
    put = K * jnp.exp(-r * T) * cdf(-d2) - S * cdf(-d1)
    return jnp.stack([call, put], axis=1)


register(Workload(
    "blackscholes", "amd",
    kernel=_blackscholes_kernel,
    make_data=lambda n, rng: (
        {"S": 80 + 40 * rng.random((n, 64)).astype(np.float32),
         "K": 80 + 40 * rng.random((n, 64)).astype(np.float32),
         "T": 0.1 + rng.random((n, 64)).astype(np.float32)}, {}),
    datasets=_scales(64, 4096),
))

register(Workload(
    "dct", "amd",
    kernel=lambda c, s: jnp.einsum(
        "ij,njk,lk->nil", s["D"], c["img"], s["D"]),
    make_data=lambda n, rng: (
        {"img": _f32(rng, n, 32, 32)},
        {"D": np.cos(np.pi / 32 * np.outer(
            np.arange(32) + 0.5, np.arange(32))).astype(np.float32)}),
    datasets=_scales(32, 1024, 16),
))

register(Workload(
    "prefix", "amd",
    kernel=lambda c, s: jnp.cumsum(c["x"], axis=1),
    make_data=lambda n, rng: ({"x": _f32(rng, n, 2048)}, {}),
    datasets=_scales(64, 2048),
))

# ---------------------------------------------------------------------------
# Parboil (8 programs)
# ---------------------------------------------------------------------------


def _bfs_kernel(c, s):
    frontier = c["frontier"]
    A = s["adj"]
    visited = frontier
    for _ in range(4):  # fixed-depth level-synchronous expansion
        frontier = jnp.clip(frontier @ A, 0.0, 1.0) * (1.0 - visited)
        visited = jnp.clip(visited + frontier, 0.0, 1.0)
    return visited


register(Workload(
    "bfs", "parboil",
    kernel=_bfs_kernel,
    make_data=lambda n, rng: (
        {"frontier": (rng.random((n, 256)) < 0.01).astype(np.float32)},
        {"adj": (rng.random((256, 256)) < 0.02).astype(np.float32)}),
    datasets=_scales(32, 1024),
))


def _lbm_kernel(c, s):
    f = c["f"]  # (n, 9, H, W) distribution functions
    w = jnp.asarray([4 / 9] + [1 / 9] * 4 + [1 / 36] * 4, jnp.float32)
    shifts = [(0, 0), (1, 0), (-1, 0), (0, 1), (0, -1),
              (1, 1), (-1, -1), (1, -1), (-1, 1)]
    rho = jnp.sum(f, axis=1, keepdims=True)
    streamed = jnp.stack(
        [jnp.roll(f[:, i], s_, axis=(1, 2)) for i, s_ in enumerate(shifts)],
        axis=1)
    feq = w[None, :, None, None] * rho
    return streamed + 0.6 * (feq - streamed)


register(Workload(
    "lbm", "parboil",
    kernel=_lbm_kernel,
    make_data=lambda n, rng: ({"f": _f32(rng, n, 9, 32, 32)}, {}),
    datasets=_scales(16, 256),
))

register(Workload(
    "histo", "parboil",
    kernel=lambda c, s: jax.vmap(
        lambda r: jnp.zeros(256, jnp.float32).at[r].add(1.0))(c["x"]),
    make_data=lambda n, rng: (
        {"x": rng.integers(0, 256, (n, 1024)).astype(np.int32)}, {}),
    datasets=_scales(32, 1024),
))


def _mriq_kernel(c, s):
    phase = 2 * np.pi * (c["x"] @ s["k"].T)  # (n, K)
    return jnp.stack([jnp.sum(s["phi"] * jnp.cos(phase), axis=1),
                      jnp.sum(s["phi"] * jnp.sin(phase), axis=1)], axis=1)


register(Workload(
    "mri-q", "parboil",
    kernel=_mriq_kernel,
    make_data=lambda n, rng: (
        {"x": _f32(rng, n, 3)},
        {"k": _f32(rng, 512, 3), "phi": _f32(rng, 512)}),
    datasets=_scales(128, 8192),
))


def _mrigrid_kernel(c, s):
    grid = jnp.zeros((64 * 64,), jnp.float32)
    return grid.at[c["idx"].reshape(-1)].add(c["val"].reshape(-1))[None]


register(Workload(
    "mri-gridding", "parboil",
    kernel=_mrigrid_kernel,
    make_data=lambda n, rng: (
        {"idx": rng.integers(0, 64 * 64, (n, 64)).astype(np.int32),
         "val": _f32(rng, n, 64)}, {}),
    datasets=_scales(64, 2048),
    combine="sum",
))


def _sad_kernel(c, s):
    blocks = c["blk"]  # (n, 16, 16)
    ref = s["ref"]     # (24, 24) search window
    outs = []
    for dy in range(0, 9, 4):
        for dx in range(0, 9, 4):
            win = jax.lax.dynamic_slice(ref, (dy, dx), (16, 16))
            outs.append(jnp.sum(jnp.abs(blocks - win), axis=(1, 2)))
    return jnp.stack(outs, axis=1)


register(Workload(
    "sad", "parboil",
    kernel=_sad_kernel,
    make_data=lambda n, rng: (
        {"blk": _f32(rng, n, 16, 16)}, {"ref": _f32(rng, 24, 24)}),
    datasets=_scales(128, 8192),
))

register(Workload(
    "sgemm", "parboil",
    kernel=lambda c, s: c["A"] @ s["B"],
    make_data=lambda n, rng: (
        {"A": _f32(rng, n, 384)}, {"B": _f32(rng, 384, 384)}),
    datasets=_scales(64, 2048),
))

register(Workload(
    "spmv", "parboil",
    kernel=lambda c, s: jnp.sum(c["val"] * s["v"][c["idx"]], axis=1),
    make_data=lambda n, rng: (
        {"val": _f32(rng, n, 64),
         "idx": rng.integers(0, 4096, (n, 64)).astype(np.int32)},
        {"v": _f32(rng, 4096)}),
    datasets=_scales(256, 16384),
))

# ---------------------------------------------------------------------------
# POLYBENCH (15 programs)
# ---------------------------------------------------------------------------

register(Workload(
    "2mm", "polybench",
    kernel=lambda c, s: 1.5 * (c["A"] @ s["B"]) @ s["C"] + 1.2 * c["D"],
    make_data=lambda n, rng: (
        {"A": _f32(rng, n, 256), "D": _f32(rng, n, 256)},
        {"B": _f32(rng, 256, 256), "C": _f32(rng, 256, 256)}),
    datasets=_scales(64, 2048),
))

register(Workload(
    "3mm", "polybench",
    kernel=lambda c, s: (c["A"] @ s["B"]) @ (s["C"] @ s["D"]),
    make_data=lambda n, rng: (
        {"A": _f32(rng, n, 256)},
        {"B": _f32(rng, 256, 256), "C": _f32(rng, 256, 256),
         "D": _f32(rng, 256, 256)}),
    datasets=_scales(64, 2048),
))


def _adi_kernel(c, s):
    u = c["u"]  # (n, H, W)
    for _ in range(2):
        u = u + 0.1 * (jnp.roll(u, 1, axis=2) - 2 * u + jnp.roll(u, -1, axis=2))
        u = u + 0.1 * (jnp.roll(u, 1, axis=1) - 2 * u + jnp.roll(u, -1, axis=1))
    return u


register(Workload(
    "adi", "polybench",
    kernel=_adi_kernel,
    make_data=lambda n, rng: ({"u": _f32(rng, n, 48, 48)}, {}),
    datasets=_scales(16, 512),
))


def _correlation_kernel(c, s):
    x = c["x"]  # (n, M)
    xm = x - jnp.mean(x, axis=0, keepdims=True)
    sd = jnp.sqrt(jnp.mean(xm**2, axis=0, keepdims=True)) + 1e-6
    xn = xm / sd
    return (xn.T @ xn) / x.shape[0]


register(Workload(
    "correlation", "polybench",
    kernel=_correlation_kernel,
    make_data=lambda n, rng: ({"x": _f32(rng, n, 128)}, {}),
    datasets=_scales(256, 8192),
    combine="local",
))

register(Workload(
    "covariance", "polybench",
    kernel=lambda c, s: ((c["x"] - jnp.mean(c["x"], axis=0, keepdims=True)).T
                         @ (c["x"] - jnp.mean(c["x"], axis=0, keepdims=True))
                         ) / c["x"].shape[0],
    make_data=lambda n, rng: ({"x": _f32(rng, n, 128)}, {}),
    datasets=_scales(256, 8192),
    combine="local",
))


def _deriche_kernel(c, s):
    # recursive (IIR) smoothing along rows: sequential scan per row
    x = c["img"]  # (n, H, W)
    a = 0.7

    def step(carry, col):
        y = a * carry + (1 - a) * col
        return y, y

    _, ys = jax.lax.scan(step, jnp.zeros_like(x[..., 0]),
                         jnp.moveaxis(x, -1, 0))
    fwd = jnp.moveaxis(ys, 0, -1)
    _, ys2 = jax.lax.scan(step, jnp.zeros_like(x[..., 0]),
                          jnp.moveaxis(fwd[..., ::-1], -1, 0))
    return jnp.moveaxis(ys2, 0, -1)[..., ::-1]


register(Workload(
    "deriche", "polybench",
    kernel=_deriche_kernel,
    make_data=lambda n, rng: ({"img": _f32(rng, n, 32, 64)}, {}),
    datasets=_scales(16, 512),
    sequential_inner=True,
))

register(Workload(
    "gemm", "polybench",
    kernel=lambda c, s: 1.5 * c["A"] @ s["B"] + 1.2 * c["C"],
    make_data=lambda n, rng: (
        {"A": _f32(rng, n, 320), "C": _f32(rng, n, 320)},
        {"B": _f32(rng, 320, 320)}),
    datasets=_scales(64, 2048),
))


def _gemver_kernel(c, s):
    A = c["A"] + jnp.outer(c["u1"], s["v1"]) + jnp.outer(c["u2"], s["v2"])
    x = A @ s["y"]
    return A * 1.2 + x[:, None]


register(Workload(
    "gemver", "polybench",
    kernel=_gemver_kernel,
    make_data=lambda n, rng: (
        {"A": _f32(rng, n, 256), "u1": _f32(rng, n), "u2": _f32(rng, n)},
        {"v1": _f32(rng, 256), "v2": _f32(rng, 256), "y": _f32(rng, 256)}),
    datasets=_scales(64, 2048),
))

register(Workload(
    "gesummv", "polybench",
    kernel=lambda c, s: 1.5 * (c["A"] @ s["x"]) + 1.2 * (c["B"] @ s["x"]),
    make_data=lambda n, rng: (
        {"A": _f32(rng, n, 512), "B": _f32(rng, n, 512)},
        {"x": _f32(rng, 512)}),
    datasets=_scales(128, 4096),
))


def _heat3d_kernel(c, s):
    u = c["u"]  # (n, D, H, W)
    for _ in range(2):
        lap = (jnp.roll(u, 1, 1) + jnp.roll(u, -1, 1)
               + jnp.roll(u, 1, 2) + jnp.roll(u, -1, 2)
               + jnp.roll(u, 1, 3) + jnp.roll(u, -1, 3) - 6 * u)
        u = u + 0.1 * lap
    return u


register(Workload(
    "heat-3d", "polybench",
    kernel=_heat3d_kernel,
    make_data=lambda n, rng: ({"u": _f32(rng, n, 16, 16, 16)}, {}),
    datasets=_scales(16, 512),
))

register(Workload(
    "jacobi-1d", "polybench",
    kernel=lambda c, s: 0.333 * (jnp.roll(c["x"], 1, 1) + c["x"]
                                 + jnp.roll(c["x"], -1, 1)),
    make_data=lambda n, rng: ({"x": _f32(rng, n, 4096)}, {}),
    datasets=_scales(32, 1024),
))


def _jacobi2d_kernel(c, s):
    u = c["u"]
    for _ in range(2):
        u = 0.2 * (u + jnp.roll(u, 1, 1) + jnp.roll(u, -1, 1)
                   + jnp.roll(u, 1, 2) + jnp.roll(u, -1, 2))
    return u


register(Workload(
    "jacobi-2d", "polybench",
    kernel=_jacobi2d_kernel,
    make_data=lambda n, rng: ({"u": _f32(rng, n, 48, 48)}, {}),
    datasets=_scales(16, 512),
))


def _mvt_kernel(c, s):
    x1 = c["A"] @ s["y1"]
    x2 = c["A"].T @ s["y2"][:c["A"].shape[0]]
    return jnp.concatenate([x1, x2])


register(Workload(
    "mvt", "polybench",
    kernel=_mvt_kernel,
    make_data=lambda n, rng: (
        {"A": _f32(rng, n, 512)},
        {"y1": _f32(rng, 512), "y2": _f32(rng, 65536)}),
    datasets=_scales(128, 4096),
    combine="local",
))

register(Workload(
    "syrk", "polybench",
    kernel=lambda c, s: c["A"] @ s["Afull"].T,
    make_data=lambda n, rng: (
        {"A": _f32(rng, n, 256)}, {"Afull": _f32(rng, 512, 256)}),
    datasets=_scales(64, 2048),
))

register(Workload(
    "syr2k", "polybench",
    kernel=lambda c, s: c["A"] @ s["Bfull"].T + c["B"] @ s["Afull"].T,
    make_data=lambda n, rng: (
        {"A": _f32(rng, n, 256), "B": _f32(rng, n, 256)},
        {"Afull": _f32(rng, 512, 256), "Bfull": _f32(rng, 512, 256)}),
    datasets=_scales(64, 2048),
))


assert len(_REGISTRY) == 39, len(_REGISTRY)
