"""Run the full dry-run grid: every (arch x applicable shape x mesh) cell
in its own subprocess (jax device-count lock + memory hygiene), writing
JSON records to benchmarks/data/dryrun/.

Usage: python benchmarks/dryrun_sweep.py [--only-single-pod] [--archs a,b]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_DIR = os.path.join(ROOT, "benchmarks", "data", "dryrun")


def cells(archs=None):
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro.configs.base import get_arch, list_archs
    for arch in archs or list_archs():
        cfg = get_arch(arch)
        for shape in cfg.shapes():
            for multi in (False, True):
                yield arch, shape.name, multi


def run_one(arch: str, shape: str, multi: bool, extra=(),
            tag: str = "") -> dict:
    name = f"{arch}__{shape}__{'pod2' if multi else 'pod1'}"
    if tag:
        name += f"__{tag}"
    out = os.path.join(OUT_DIR, name + ".json")
    if os.path.exists(out):
        with open(out) as f:
            return json.load(f)
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out", out, *extra]
    if multi:
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    t0 = time.time()
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=3000)
    if r.returncode != 0:
        err = {"arch": arch, "shape": shape, "multi_pod": multi,
               "error": r.stderr[-3000:], "wall_s": time.time() - t0}
        with open(out + ".err", "w") as f:
            json.dump(err, f, indent=1)
        print(f"FAIL {name} ({time.time()-t0:.0f}s)", flush=True)
        return err
    print(f"ok   {name} ({time.time()-t0:.0f}s)", flush=True)
    with open(out) as f:
        return json.load(f)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default=None)
    ap.add_argument("--single-pod-only", action="store_true")
    args = ap.parse_args()
    os.makedirs(OUT_DIR, exist_ok=True)
    archs = args.archs.split(",") if args.archs else None
    n_ok = n_fail = 0
    for arch, shape, multi in cells(archs):
        if args.single_pod_only and multi:
            continue
        rec = run_one(arch, shape, multi)
        if "error" in rec:
            n_fail += 1
        else:
            n_ok += 1
    print(f"done: {n_ok} ok, {n_fail} failed")


if __name__ == "__main__":
    main()
