"""ModelRegistry lifecycle: version allocation, ``latest`` pinning,
hot-swap refresh, tenant-tagged fork persistence, serving's model
resolution, and the scheduler's ``swap_model`` hook."""
import numpy as np
import pytest

from repro.core.features import RAW_FEATURE_NAMES, config_features
from repro.core.modeling import (ModelRegistry, OverlapHeuristicModel,
                                 PerformanceModel)
from repro.core.stream_config import SINGLE_STREAM, default_space
from repro.launch.serve import resolve_serving_model
from repro.serving import AdaptiveScheduler, DriftDetector, TenantRegistry

N_FEAT = len(RAW_FEATURE_NAMES)


def _tiny_model(seed=0, epochs=25) -> PerformanceModel:
    rng = np.random.default_rng(seed)
    n = 60
    X = np.concatenate(
        [rng.uniform(0.5, 2.0, size=(n, N_FEAT)),
         np.stack([config_features(2 ** (i % 3), 2 ** (i % 5))
                   for i in range(n)])], axis=1)
    y = rng.uniform(0.5, 3.0, size=n)
    return PerformanceModel.train(X, y, epochs=epochs)


@pytest.fixture(scope="module")
def base_model():
    return _tiny_model()


def test_publish_allocates_versions_and_pins_latest(base_model, tmp_path):
    reg = ModelRegistry(tmp_path)
    assert reg.list() == [] and reg.latest_id() is None
    a1 = reg.publish(base_model)
    a2 = reg.publish(base_model)
    assert [a1, a2] == ["mlp-v001", "mlp-v002"]
    assert reg.latest_id() == a2
    model, manifest = reg.load("latest")
    assert manifest["artifact_id"] == a2
    assert isinstance(model, PerformanceModel)
    # explicit id and filesystem path both resolve
    assert reg.load(a1)[1]["artifact_id"] == a1
    assert reg.load(str(tmp_path / a1))[1]["artifact_id"] == a1


def test_tenant_publish_never_auto_pins(base_model, tmp_path):
    reg = ModelRegistry(tmp_path)
    fleet = reg.publish(base_model)
    fork_id = reg.publish(base_model.fork(), tenant="tenant-a")
    assert fork_id == "mlp-tenant-a-v001"
    assert reg.latest_id() == fleet
    assert reg.manifest(fork_id)["tenant"] == "tenant-a"
    # tenant lineage versions independently of the fleet lineage
    assert reg.publish(base_model.fork(),
                       tenant="tenant-a") == "mlp-tenant-a-v002"


def test_refresh_hot_swaps_only_on_pointer_move(base_model, tmp_path):
    reg = ModelRegistry(tmp_path)
    a1 = reg.publish(base_model)
    model, manifest = reg.load("latest")
    assert reg.refresh(manifest["artifact_id"]) is None   # unchanged
    a2 = reg.publish(_tiny_model(seed=1))
    swapped = reg.refresh(manifest["artifact_id"])
    assert swapped is not None
    new_model, new_manifest = swapped
    assert new_manifest["artifact_id"] == a2 != a1
    assert reg.refresh(a2) is None


def test_load_missing_artifact_raises(tmp_path):
    reg = ModelRegistry(tmp_path)
    with pytest.raises(FileNotFoundError, match="no 'latest'"):
        reg.load("latest")
    with pytest.raises(FileNotFoundError, match="no artifact"):
        reg.load("mlp-v999")


def test_dangling_latest_pointer_is_corruption_not_empty(base_model,
                                                         tmp_path):
    """latest -> a deleted artifact must raise RuntimeError, NOT
    FileNotFoundError: serving's empty-registry bootstrap would
    otherwise silently train a fresh model over the corruption."""
    import shutil

    reg = ModelRegistry(tmp_path)
    aid = reg.publish(base_model)
    shutil.rmtree(tmp_path / aid)
    with pytest.raises(RuntimeError, match="points at"):
        reg.load("latest")
    with pytest.raises(RuntimeError, match="points at"):
        resolve_serving_model("latest", tmp_path, verbose=False)


def test_tenant_registry_draws_base_from_model_registry(base_model,
                                                        tmp_path):
    reg = ModelRegistry(tmp_path)
    aid = reg.publish(base_model)
    tenants = TenantRegistry.from_model_registry(
        reg, DriftDetector(), isolate=True)
    assert tenants.base_artifact_id == aid
    ctx = tenants.get("tenant-a")
    feats = np.full(N_FEAT, 1.2)
    preds = ctx.active_model.predict_configs(feats, [SINGLE_STREAM])
    assert np.isfinite(preds).all()


def test_persist_forks_publishes_tenant_tagged_artifacts(base_model,
                                                         tmp_path):
    reg = ModelRegistry(tmp_path)
    reg.publish(base_model)
    tenants = TenantRegistry.from_model_registry(
        reg, DriftDetector(), isolate=True)
    # tenant-a refits (forks); tenant-b never does
    ctx = tenants.get("tenant-a")
    fork = ctx.fork_for_refit()
    assert ctx.forked and fork is not tenants.base_model
    tenants.get("tenant-b")
    published = tenants.persist_forks(reg, tag="drift-corrected")
    assert list(published) == ["tenant-a"]
    fork_id = published["tenant-a"]
    loaded, manifest = reg.load(fork_id)
    assert manifest["tenant"] == "tenant-a"
    assert manifest["tag"] == "drift-corrected"
    assert reg.latest_id() != fork_id
    feats = np.full(N_FEAT, 0.8)
    cands = list(default_space(4, 8))
    np.testing.assert_array_equal(fork.predict_configs(feats, cands),
                                  loaded.predict_configs(feats, cands))


def test_hot_swap_updates_unforked_contexts_only(base_model):
    old, new = base_model, _tiny_model(seed=2)
    tenants = TenantRegistry(old, DriftDetector(), isolate=True)
    forked_ctx = tenants.get("tenant-a")
    fork = forked_ctx.fork_for_refit()
    fresh_ctx = tenants.get("tenant-b")
    tenants.hot_swap(new)
    assert tenants.base_model is new
    assert fresh_ctx.active_model is new
    assert forked_ctx.active_model is fork     # fork survives the swap
    assert tenants.get("tenant-c").active_model is new


def test_scheduler_swap_model_rotates_model_and_tag(base_model):
    new = _tiny_model(seed=3)
    sched = AdaptiveScheduler(base_model, model_tag="mlp-v001")
    try:
        sched.swap_model(new, model_tag="mlp-v002")
        assert sched.model is new
        assert sched.refiner.model is new
        assert sched.tenancy.base_model is new
        assert sched.model_tag == "mlp-v002"
        # the non-isolated shared context serves the new base too
        assert sched.tenancy.get("anyone").active_model is new
    finally:
        sched.close()


def test_resolve_serving_model_heuristic_and_artifact(base_model,
                                                      tmp_path):
    model, info = resolve_serving_model("heuristic", tmp_path,
                                        verbose=False)
    assert isinstance(model, OverlapHeuristicModel)
    assert info["artifact_id"] == "heuristic"

    reg = ModelRegistry(tmp_path)
    aid = reg.publish(base_model, cv={"frac_of_oracle": 0.88})
    model, info = resolve_serving_model("latest", tmp_path, verbose=False)
    assert isinstance(model, PerformanceModel)
    assert info["artifact_id"] == aid
    assert info["cv_frac_of_oracle"] == 0.88

    # the default path refuses silently falling back to the heuristic:
    # an empty registry without bootstrap is an error, not a stand-in
    with pytest.raises(FileNotFoundError):
        resolve_serving_model("latest", tmp_path / "empty",
                              bootstrap=False, verbose=False)
