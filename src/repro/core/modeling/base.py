"""The :class:`Estimator` protocol every performance predictor follows,
plus the estimator-kind registry that artifact loading dispatches on.

The serving stack, the autotuner, and the benchmark harness all talk to
models through the same small surface:

  ``predict_configs(prog_feats, configs)``  rank a candidate grid for one
      ``(F,)`` program or a ``(B, F)`` batch of programs;
  ``assemble_rows(prog_feats, configs)``    the raw training/inference row
      layout (program features ++ config encoding);
  ``refit(X, y)``       *optional* incremental online correction hook
      (absent on immutable estimators such as the heuristic);
  ``fork()``            a refit-isolated copy (per-tenant copy-on-refit);
  ``save(path)`` / ``load(path)``  versioned artifact round-trip
      (:mod:`repro.core.modeling.artifacts`).

Concrete estimators register themselves under a short ``kind`` string
(``mlp``, ``cart``, ``forest``, ``krr``, ``heuristic``); the artifact
manifest records the kind so :func:`load_artifact` can rebuild the right
class without the caller knowing it.
"""
from __future__ import annotations

import copy
from typing import TYPE_CHECKING, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.features import config_feature_matrix

if TYPE_CHECKING:  # pragma: no cover
    from pathlib import Path

    from repro.core.stream_config import StreamConfig


def assemble_rows(prog_feats: np.ndarray, configs) -> np.ndarray:
    """Program features ++ config encodings, vectorized: ``(F,)`` input
    yields ``(C, F+3)`` rows; ``(B, F)`` input yields ``(B*C, F+3)`` rows
    grouped program-major."""
    P = np.atleast_2d(np.asarray(prog_feats, dtype=np.float64))
    C = config_feature_matrix(configs)
    return np.concatenate([np.repeat(P, len(configs), axis=0),
                           np.tile(C, (P.shape[0], 1))], axis=1)


@runtime_checkable
class Estimator(Protocol):
    """Structural type of everything the serving/tuning layers accept as
    a model.  ``refit`` is deliberately absent: it is optional, and
    callers feature-test it with ``hasattr`` (the heuristic and the
    closed-form learners are immutable under serving)."""

    kind: str

    def predict_configs(self, prog_feats: np.ndarray,
                        configs: Sequence["StreamConfig"]) -> np.ndarray:
        ...

    def fork(self) -> "Estimator":
        ...

    def save(self, path: "str | Path", **meta) -> "Path":
        ...


#: kind string -> estimator class; artifact loading dispatches on this
ESTIMATOR_KINDS: dict[str, type] = {}


def register_estimator(cls):
    """Class decorator: file the estimator under its ``kind`` string."""
    assert getattr(cls, "kind", None), f"{cls.__name__} has no kind"
    ESTIMATOR_KINDS[cls.kind] = cls
    return cls


def get_estimator_kind(kind: str) -> type:
    try:
        return ESTIMATOR_KINDS[kind]
    except KeyError:
        raise KeyError(f"unknown estimator kind {kind!r}; "
                       f"registered: {sorted(ESTIMATOR_KINDS)}") from None


class EstimatorBase:
    """Shared implementation of the :class:`Estimator` surface.

    Subclasses provide ``kind``, ``predict(rows)`` (row-wise regression),
    and the ``to_state`` / ``from_state`` serialization pair; everything
    else — batched config ranking, forking, artifact save/load — is
    inherited."""

    kind: str = ""

    def predict(self, X_raw: np.ndarray) -> np.ndarray:  # pragma: no cover
        raise NotImplementedError

    assemble_rows = staticmethod(assemble_rows)

    def predict_configs(self, prog_feats: np.ndarray,
                        configs) -> np.ndarray:
        """Rank many configs for one or many programs (the runtime search
        core).  ``prog_feats`` may be a single ``(F,)`` feature vector —
        returns ``(C,)`` predictions — or a ``(B, F)`` matrix of programs
        — returns ``(B, C)``, one forward pass for the whole batch (the
        serving engine's batched cold path)."""
        P = np.atleast_2d(np.asarray(prog_feats, dtype=np.float64))
        rows = assemble_rows(P, configs)
        preds = self.predict(rows).reshape(P.shape[0], len(configs))
        return preds[0] if np.ndim(prog_feats) == 1 else preds

    def fork(self):
        """A refit-isolated copy.  Estimators with cheap shareable state
        (e.g. the MLP's frozen feature pipeline) override this."""
        return copy.deepcopy(self)

    # -- versioned artifact round-trip ---------------------------------------

    def to_state(self) -> tuple[dict, dict]:  # pragma: no cover
        """Returns ``(arrays, extras)``: numpy arrays for the ``.npz``
        payload and JSON-safe scalars for the manifest."""
        raise NotImplementedError

    @classmethod
    def from_state(cls, arrays: dict, extras: dict):  # pragma: no cover
        raise NotImplementedError

    def save(self, path, **meta):
        """Write this estimator as a versioned artifact directory
        (``manifest.json`` + ``weights.npz``); see
        :func:`repro.core.modeling.artifacts.save_artifact`."""
        from repro.core.modeling.artifacts import save_artifact
        return save_artifact(self, path, **meta)

    @classmethod
    def load(cls, path):
        """Load an artifact directory saved by any estimator kind; when
        called on a concrete subclass the kind must match."""
        from repro.core.modeling.artifacts import load_artifact
        model, _ = load_artifact(path)
        if cls is not EstimatorBase and not isinstance(model, cls):
            raise TypeError(f"artifact at {path} holds kind "
                            f"{model.kind!r}, not {cls.kind!r}")
        return model
