"""End-to-end behaviour tests: the full training/serving systems plus the
paper's pipeline (profile -> features -> model -> search -> config) on
live workloads."""
import numpy as np
import pytest

from repro.core import dataset as ds
from repro.core.autotuner import AutoTuner
from repro.core.perf_model import PerformanceModel
from repro.core.search import search_best, simulated_annealing
from repro.core.stream_config import StreamConfig
from repro.core.streams import StreamedRunner
from repro.core.workloads import get_workload
from repro.launch.serve import serve
from repro.launch.train import train_loop


@pytest.mark.slow
def test_training_loss_goes_down():
    res = train_loop("stablelm-3b", steps=25, batch=4, seq=16,
                     verbose=False, lr=3e-3)
    assert res.steps_run == 25
    first = float(np.mean(res.losses[:5]))
    last = float(np.mean(res.losses[-5:]))
    assert last < first, (first, last)


@pytest.mark.slow
def test_training_with_microbatches_matches_shapes():
    res = train_loop("yi-9b", steps=6, batch=8, seq=16, microbatches=4,
                     verbose=False)
    assert res.steps_run == 6
    assert np.isfinite(res.losses).all()


def test_serving_generates_tokens():
    res = serve("stablelm-3b", n_requests=4, batch_slots=2,
                prompt_len=8, gen_len=6, verbose=False)
    assert res.tokens_generated == 4 * 6
    assert all(o.shape == (6,) for o in res.outputs)
    assert res.tokens_per_s > 0


def test_serving_greedy_deterministic():
    r1 = serve("musicgen-medium", n_requests=2, batch_slots=2,
               prompt_len=8, gen_len=4, verbose=False)
    r2 = serve("musicgen-medium", n_requests=2, batch_slots=2,
               prompt_len=8, gen_len=4, verbose=False)
    for a, b in zip(r1.outputs, r2.outputs):
        np.testing.assert_array_equal(a, b)


# --------------------------------------------------------------------------
# The paper's pipeline end-to-end (small live profile) — slow tier: the
# fixture exhaustively profiles 8 (program, dataset) cells.  The fast tier
# covers the same path via test_backends.py / test_tuning_cache.py.
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mini_samples(tmp_path_factory):
    cache = str(tmp_path_factory.mktemp("cache") / "profile.json")
    progs = ["vecadd", "binomial", "jacobi-1d", "sgemm"]
    return ds.generate(progs, datasets_per_program=2, reps=1,
                       cache_path=cache, verbose=False)


@pytest.mark.slow
def test_pipeline_profiles_and_caches(mini_samples):
    assert len(mini_samples) == 8
    for s in mini_samples:
        assert np.isfinite(s.features).all()
        assert s.oracle_speedup >= 1.0
        assert s.times[(1, 1)] > 0


@pytest.mark.slow
def test_model_trained_on_profiles_beats_worst_config(mini_samples):
    X, y = ds.training_matrix(mini_samples)
    model = PerformanceModel.train(X, y, epochs=300)
    s = mini_samples[0]
    cfgs = [StreamConfig(p, t) for (p, t) in s.times]
    best, preds, dt = search_best(model, s.features, cfgs)
    achieved = s.speedup(best)
    worst = min(s.t_single / v for v in s.times.values())
    assert achieved > worst
    assert dt < 1.0  # search overhead: the paper's "few milliseconds"


@pytest.mark.slow
def test_autotuner_end_to_end(mini_samples):
    X, y = ds.training_matrix(mini_samples)
    model = PerformanceModel.train(X, y, epochs=200)
    wl = get_workload("dotprod")  # unseen program
    rng = np.random.default_rng(0)
    chunked, shared = wl.make_data(wl.datasets[0], rng)
    tuner = AutoTuner(model)
    result = tuner.tune(wl, chunked, shared)
    assert result.config.partitions >= 1
    assert result.search_seconds < 1.0


@pytest.mark.slow
def test_loo_split_excludes_family(mini_samples):
    train, test = ds.loo_split(mini_samples, "vecadd")
    assert all(s.program != "vecadd" for s in train)
    assert all(s.program == "vecadd" for s in test)


@pytest.mark.slow
def test_simulated_annealing_on_measured_objective():
    wl = get_workload("vecadd")
    rng = np.random.default_rng(0)
    chunked, shared = wl.make_data(512, rng)
    runner = StreamedRunner(wl, chunked, shared)
    calls = []

    def obj(cfg):
        t = runner.run(cfg, reps=1)
        calls.append(cfg)
        return t

    best, cost = simulated_annealing(obj, iters=8, seed=0)
    assert len(calls) == 9 and cost > 0  # initial config + 8 iterations
