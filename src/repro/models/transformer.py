"""Composable decoder: attention / mamba / sLSTM / mLSTM blocks interleaved
by ``ArchConfig.layer_pattern``, scanned over pattern repeats so compile time
is O(1) in depth.  One code path serves all 10 assigned architectures.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import layers, mamba as mamba_lib, moe as moe_lib
from repro.models import xlstm as xlstm_lib
from repro.parallel.sharding_rules import AxisRules


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Runtime knobs orthogonal to the architecture."""

    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    cache_dtype: Any = jnp.float32
    rules: AxisRules = dataclasses.field(default_factory=AxisRules.null)
    q_block: int = 512
    kv_block: int = 512
    remat: str = "none"           # none | full | dots
    capacity_factor: float = 1.25
    decode_attn: str = "local"    # local | sharded
    mesh: Any = None              # required for decode_attn == "sharded"
    dp_axes: tuple = ("data",)
    scan_layers: bool = True
    moe_aux_weight: float = 0.01
    moe_group_size: int = 512
    attn_expand_kv: bool = False  # True for the TP pod path (see attention.py)


# ---------------------------------------------------------------------------
# Per-block init
# ---------------------------------------------------------------------------


def _has_ffn(cfg: ArchConfig, pos: int) -> bool:
    b = cfg.layer_pattern[pos]
    if cfg.ffn_on == "none":
        return False
    if cfg.ffn_on == "attn" and b != "attn":
        return False
    return cfg.d_ff > 0 or cfg.moe is not None


def _is_moe(cfg: ArchConfig, pos: int) -> bool:
    if cfg.moe is None or not _has_ffn(cfg, pos):
        return False
    moe_set = set(cfg.moe_layer_indices)
    return (not moe_set) or (pos in moe_set)


def _attn_init(key, cfg: ArchConfig, dtype) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": layers.dense_init(ks[0], (d, H, hd), ("embed", "heads", "head_dim"), dtype),
        "wk": layers.dense_init(ks[1], (d, KV, hd), ("embed", "kv_heads", "head_dim"), dtype),
        "wv": layers.dense_init(ks[2], (d, KV, hd), ("embed", "kv_heads", "head_dim"), dtype),
        "wo": layers.dense_init(ks[3], (H, hd, d), ("heads", "head_dim", "embed"),
                                dtype, fan_in=H * hd),
    }


def _block_init(key, cfg: ArchConfig, pos: int, dtype) -> dict:
    btype = cfg.layer_pattern[pos]
    ks = jax.random.split(key, 3)
    p: dict = {"norm1": layers.rmsnorm_init(cfg.d_model, dtype)}
    if btype == "attn":
        p["attn"] = _attn_init(ks[0], cfg, dtype)
    elif btype == "mamba":
        p["mamba"] = mamba_lib.mamba_init(ks[0], cfg.d_model, cfg.ssm, dtype)
    elif btype == "slstm":
        p["cell"] = xlstm_lib.slstm_init(ks[0], cfg.d_model, cfg.num_heads,
                                         cfg.xlstm, dtype)
    elif btype == "mlstm":
        p["cell"] = xlstm_lib.mlstm_init(ks[0], cfg.d_model, cfg.num_heads,
                                         cfg.xlstm, dtype)
    else:
        raise ValueError(btype)
    if _has_ffn(cfg, pos):
        p["norm2"] = layers.rmsnorm_init(cfg.d_model, dtype)
        if _is_moe(cfg, pos):
            p["ffn"] = moe_lib.moe_init(ks[1], cfg.d_model, cfg.moe,
                                        gated=cfg.gated_mlp, dtype=dtype)
        else:
            p["ffn"] = layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                       gated=cfg.gated_mlp, dtype=dtype)
    return p


def init_params(key, cfg: ArchConfig, rcfg: RunConfig):
    ks = jax.random.split(key, 4)
    dtype = rcfg.param_dtype
    params: dict = {
        "embed": layers.embedding_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": layers.rmsnorm_init(cfg.d_model, dtype),
        "lm_head": layers.dense_init(
            ks[1], (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), dtype,
            fan_in=cfg.d_model),
    }
    if cfg.frontend:
        params["frontend_proj"] = layers.dense_init(
            ks[2], (cfg.frontend_dim, cfg.d_model), (None, "embed"), dtype,
            fan_in=cfg.frontend_dim)

    def group_init(gkey):
        gks = jax.random.split(gkey, len(cfg.layer_pattern))
        return {
            f"pos{i}": _block_init(gk, cfg, i, dtype)
            for i, gk in enumerate(gks)
        }

    R = cfg.num_pattern_repeats
    gkeys = jax.random.split(ks[3], R)
    stacked = jax.vmap(group_init)(gkeys)
    # vmap strips Leaf axes metadata is wrong: rebuild Leafs with "layers" axis
    template = group_init(gkeys[0])

    def relabel(st, tp):
        return layers.Leaf(st.value, ("layers",) + tp.axes)

    params["blocks"] = jax.tree.map(
        relabel, stacked, template, is_leaf=layers.is_leaf)
    return params


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, rcfg: RunConfig, batch: int, max_seq: int):
    """Per-pattern-position state stacked over repeats (leading R dim)."""
    R = cfg.num_pattern_repeats
    cache: dict = {}
    for i, b in enumerate(cfg.layer_pattern):
        if b == "attn":
            shape = (R, batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
            cache[f"pos{i}"] = {
                "k": jnp.zeros(shape, rcfg.cache_dtype),
                "v": jnp.zeros(shape, rcfg.cache_dtype),
            }
        elif b == "mamba":
            sh = mamba_lib.mamba_state_shapes(batch, cfg.d_model, cfg.ssm)
            cache[f"pos{i}"] = {
                "ssm": jnp.zeros((R,) + sh["ssm"], jnp.float32),
                "conv": jnp.zeros((R,) + sh["conv"], rcfg.cache_dtype),
            }
        elif b == "slstm":
            st = xlstm_lib.slstm_init_state(batch, cfg.d_model)
            cache[f"pos{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (R,) + a.shape), st)
        elif b == "mlstm":
            E = xlstm_lib._round64(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
            st = xlstm_lib.mlstm_init_state(batch, cfg.num_heads,
                                            E // cfg.num_heads)
            cache[f"pos{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (R,) + a.shape), st)
    return cache


def cache_logical_axes(cfg: ArchConfig):
    """Logical axes for the cache pytree (for sharding specs)."""
    axes: dict = {}
    for i, b in enumerate(cfg.layer_pattern):
        if b == "attn":
            a = ("layers", "cache_batch", "cache_seq", "cache_heads", None)
            axes[f"pos{i}"] = {"k": a, "v": a}
        elif b == "mamba":
            axes[f"pos{i}"] = {
                "ssm": ("layers", "cache_batch", "inner", None),
                "conv": ("layers", "cache_batch", None, "inner"),
            }
        elif b == "slstm":
            a = ("layers", "cache_batch", None)
            axes[f"pos{i}"] = {"c": a, "n": a, "h": a, "m": a}
        elif b == "mlstm":
            axes[f"pos{i}"] = {
                "C": ("layers", "cache_batch", "heads", None, None),
                "n": ("layers", "cache_batch", "heads", None),
                "m": ("layers", "cache_batch", "heads"),
            }
    return axes


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _attn_apply(p, x, cfg: ArchConfig, rcfg: RunConfig, *, positions,
                cache=None, t=None, build_cache=False):
    rules = rcfg.rules
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = rules.constrain(q, "batch", "seq", "heads", "head_dim")
    k = rules.constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = rules.constrain(v, "batch", "seq", "kv_heads", "head_dim")
    q = attn_lib.apply_rope(q, positions, cfg.rope_theta)
    k = attn_lib.apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is None:
        o = attn_lib.flash_attention(
            q, k, v, causal=True, q_block=rcfg.q_block, kv_block=rcfg.kv_block,
            gqa_grouped=not rcfg.attn_expand_kv)
        if build_cache:
            new_cache = {"k": k.astype(rcfg.cache_dtype),
                         "v": v.astype(rcfg.cache_dtype)}
    else:
        assert S == 1 and t is not None
        kc, vc = cache["k"], cache["v"]
        kn = k.astype(kc.dtype)
        vn = v.astype(vc.dtype)
        if rcfg.decode_attn == "sharded":
            o, kc, vc = attn_lib.decode_attention_sharded(
                q, kn, vn, kc, vc, t, mesh=rcfg.mesh, dp_axes=rcfg.dp_axes)
        else:
            o, kc, vc = attn_lib.decode_attention_local(q, kn, vn, kc, vc, t)
        new_cache = {"k": kc, "v": vc}
    out = jnp.einsum("bshk,hkd->bsd", o.astype(x.dtype), p["wo"])
    return rules.constrain(out, "batch", "seq", "embed_act"), new_cache


def _block_apply(p, x, cfg: ArchConfig, rcfg: RunConfig, pos: int, *,
                 positions, cache=None, t=None, build_cache=False):
    """Returns (x, aux_loss, new_cache)."""
    btype = cfg.layer_pattern[pos]
    rules = rcfg.rules
    aux = jnp.zeros((), jnp.float32)
    xn = layers.rmsnorm_apply(p["norm1"], x, eps=cfg.norm_eps)
    decode = cache is not None
    want_state = decode or build_cache
    if btype == "attn":
        h, new_cache = _attn_apply(p["attn"], xn, cfg, rcfg,
                                   positions=positions, cache=cache, t=t,
                                   build_cache=build_cache)
    elif btype == "mamba":
        if want_state:
            h, ssm, conv = mamba_lib.mamba_apply(
                p["mamba"], xn, cfg.ssm, rules,
                ssm_state=cache["ssm"] if decode else None,
                conv_state=cache["conv"] if decode else None,
                return_state=True)
            new_cache = {"ssm": ssm, "conv": conv}
        else:
            h = mamba_lib.mamba_apply(p["mamba"], xn, cfg.ssm, rules)
            new_cache = None
    elif btype == "slstm":
        if want_state:
            h, st = xlstm_lib.slstm_apply(
                p["cell"], xn, cfg.num_heads, rules,
                state=cache if decode else None, return_state=True)
            new_cache = st
        else:
            h = xlstm_lib.slstm_apply(p["cell"], xn, cfg.num_heads, rules)
            new_cache = None
    elif btype == "mlstm":
        if want_state:
            h, st = xlstm_lib.mlstm_apply(
                p["cell"], xn, cfg.num_heads, cfg.xlstm, rules,
                state=cache if decode else None, return_state=True)
            new_cache = st
        else:
            h = xlstm_lib.mlstm_apply(p["cell"], xn, cfg.num_heads,
                                      cfg.xlstm, rules)
            new_cache = None
    else:
        raise ValueError(btype)
    x = x + h
    if _has_ffn(cfg, pos):
        xn2 = layers.rmsnorm_apply(p["norm2"], x, eps=cfg.norm_eps)
        if _is_moe(cfg, pos):
            y, aux = moe_lib.moe_apply(
                p["ffn"], xn2, cfg.moe, rules,
                capacity_factor=rcfg.capacity_factor,
                group_size=rcfg.moe_group_size)
        else:
            y = layers.mlp_apply(p["ffn"], xn2, rules)
        x = x + y
    return x, aux, new_cache


def _group_apply(gp, x, gcache, *, cfg, rcfg, positions, t=None,
                 build_cache=False):
    aux_total = jnp.zeros((), jnp.float32)
    new_gcache = {} if (gcache is not None or build_cache) else None
    for i in range(len(cfg.layer_pattern)):
        key = f"pos{i}"
        c = gcache[key] if gcache is not None else None
        x, aux, nc = _block_apply(gp[key], x, cfg, rcfg, i,
                                  positions=positions, cache=c, t=t,
                                  build_cache=build_cache)
        aux_total = aux_total + aux
        if new_gcache is not None:
            new_gcache[key] = nc
    return x, aux_total, new_gcache


# ---------------------------------------------------------------------------
# Full model forward
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch, cfg: ArchConfig, rcfg: RunConfig):
    rules = rcfg.rules
    x = layers.embedding_lookup(params["embed"], batch["tokens"], rules)
    if cfg.frontend:
        fe = jnp.einsum("bsf,fd->bsd", batch["embeds"].astype(x.dtype),
                        params["frontend_proj"])
        x = x + fe
    return x.astype(rcfg.compute_dtype)


def forward(params, batch, cfg: ArchConfig, rcfg: RunConfig, *,
            cache=None, t=None, build_cache=False):
    """Full forward. cache None => train/prefill over (B, S); else one-step
    decode at position ``t``.  ``build_cache`` makes the full-sequence pass
    also emit the populated decoding cache (serving prefill).
    Returns (logits, aux_loss, new_cache)."""
    x = _embed_inputs(params, batch, cfg, rcfg)
    B, S = x.shape[:2]
    if cache is None:
        positions = jnp.arange(S)
    else:
        positions = t + jnp.arange(1)

    group_fn = functools.partial(
        _group_apply, cfg=cfg, rcfg=rcfg, positions=positions, t=t,
        build_cache=build_cache)
    if rcfg.remat == "full":
        group_fn = jax.checkpoint(group_fn)
    elif rcfg.remat == "dots":
        group_fn = jax.checkpoint(
            group_fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    blocks = params["blocks"]
    if rcfg.scan_layers:
        if cache is None:
            def body(carry, gp):
                xx, aux = carry
                xx, aux_g, ngc = group_fn(gp, xx, None)
                return (xx, aux + aux_g), ngc
            (x, aux), new_cache = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)), blocks)
        else:
            def body(carry, xs):
                xx, aux = carry
                gp, gc = xs
                xx, aux_g, ngc = group_fn(gp, xx, gc)
                return (xx, aux + aux_g), ngc
            (x, aux), new_cache = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)), (blocks, cache))
    else:
        aux = jnp.zeros((), jnp.float32)
        new_cache = None
        R = cfg.num_pattern_repeats
        caches = []
        for r in range(R):
            gp = jax.tree.map(lambda a: a[r], blocks)
            gc = jax.tree.map(lambda a: a[r], cache) if cache is not None else None
            x, aux_g, ngc = group_fn(gp, x, gc)
            aux = aux + aux_g
            caches.append(ngc)
        if cache is not None or build_cache:
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)

    x = layers.rmsnorm_apply(params["final_norm"], x, eps=cfg.norm_eps)
    logits = layers.lm_head_apply(params["lm_head"], x, rcfg.rules)
    return logits, aux, new_cache


def loss_fn(params, batch, cfg: ArchConfig, rcfg: RunConfig):
    logits, aux, _ = forward(params, batch, cfg, rcfg)
    ce = layers.softmax_cross_entropy(
        logits, batch["labels"], batch.get("mask"))
    return ce + rcfg.moe_aux_weight * aux, {"ce": ce, "moe_aux": aux}
