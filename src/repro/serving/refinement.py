"""Drift detection and online refinement — the offline-learn / online-
correct loop the paper leaves open.

The paper's model is trained offline and applied once per program at
runtime (§3.3).  Under sustained serving the prediction can drift away
from reality: the data distribution shifts within a shape bucket, the
machine's load changes, or the model was simply wrong for this workload.
:class:`DriftDetector` watches the rolling relative prediction error per
workload bucket; past a threshold, :class:`Refiner` closes the loop:

  1. evict the stale cache entry,
  2. re-profile a *small* candidate set — the model's current top-k, the
     incumbent config, and the single-stream baseline (measured ground
     truth, a handful of runs, not the full grid),
  3. write back a cache entry whose "predicted" speedup is the measured
     one (``source="refined"``), so subsequent hits predict accurately,
  4. feed the measured (features ++ config, speedup) rows to the model's
     incremental ``refit`` hook, nudging future *cold* searches too.

Memeti & Pllana (arXiv:2106.01441) show exactly this measured-feedback
re-planning beating static offline decisions on heterogeneous systems.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.core.autotuner import TuneResult, TuningCache
from repro.core.modeling.base import assemble_rows
from repro.core.modeling.search import search_best
from repro.core.stream_config import SINGLE_STREAM, StreamConfig, \
    default_space
from repro.core.streams import StreamedRunner, profile_grid_interleaved


class DriftDetector:
    """Rolling prediction-error window per workload bucket.

    ``observe(key, rel_error, load_factor=...)`` pushes one sample and
    returns True when the bucket's mean error over the window crosses
    ``threshold`` (with at least ``min_samples`` observed).  After a
    refinement the caller ``reset``s the bucket: the window clears and a
    ``cooldown`` of subsequent observations is ignored entirely, so one
    drift event yields one refinement, not a burst.

    Cooldown observations are NOT accumulated into the window.  They
    cover the refreshed entry's settling period — recompile stutter,
    host-noise spikes on the first warm hits — and letting them pile up
    meant the first post-cooldown observation was judged against a mean
    of exactly the samples the cooldown existed to ignore, double-firing
    the drift→refine loop under timing noise (the ``refinements == 2``
    tier-1 failure this fixed).  A re-trigger now requires
    ``min_samples`` fresh post-cooldown observations over threshold.

    ``load_factor`` is the contention stamp the scheduler already
    records per sample (window occupancy / host parallel capacity).
    ``measured_s`` is normalized by it *before* the error is computed,
    but the normalization is a model — the residual error it leaves
    grows with contention.  ``load_discount`` (default 0: off) divides
    each sample's contribution by ``1 + load_discount*(load_factor-1)``,
    so a window full of occupancy-8 samples needs proportionally more
    evidence to fire than an idle one, and contention at deep windows
    cannot masquerade as model drift over a 10^5-request trace.  Genuine
    drift still fires: a real 3x misprediction dwarfs the discount.
    """

    def __init__(self, *, window: int = 8, threshold: float = 1.0,
                 min_samples: int = 2, cooldown: int = 2,
                 load_discount: float = 0.0):
        assert window >= min_samples >= 1
        self.window = window
        self.threshold = threshold
        self.min_samples = min_samples
        self.cooldown = cooldown
        self.load_discount = load_discount
        self._errors: dict[str, collections.deque] = {}
        self._cooldowns: dict[str, int] = {}
        self.triggers = 0
        #: observations swallowed by a post-refinement cooldown — the
        #: "suppressions" half of the drift fires-vs-suppressions metric
        self.suppressed = 0

    def observe(self, key: str, rel_error: Optional[float],
                load_factor: float = 1.0) -> bool:
        if rel_error is None:
            return False
        if self._cooldowns.get(key, 0) > 0:
            # settling period after a refinement: ignored AND not
            # accumulated — see the class docstring
            self._cooldowns[key] -= 1
            self.suppressed += 1
            return False
        discount = 1.0 + self.load_discount * max(0.0, load_factor - 1.0)
        dq = self._errors.setdefault(
            key, collections.deque(maxlen=self.window))
        dq.append(float(rel_error) / discount)
        if len(dq) >= self.min_samples and \
                sum(dq) / len(dq) > self.threshold:
            self.triggers += 1
            return True
        return False

    def rolling_error(self, key: str) -> Optional[float]:
        dq = self._errors.get(key)
        return (sum(dq) / len(dq)) if dq else None

    def reset(self, key: str) -> None:
        self._errors.pop(key, None)
        self._cooldowns[key] = self.cooldown

    def clone(self) -> "DriftDetector":
        """A fresh detector with the same thresholds and EMPTY windows —
        the per-tenant template instantiation: every tenant judges drift
        by the same rules but over only its own samples."""
        return DriftDetector(window=self.window, threshold=self.threshold,
                             min_samples=self.min_samples,
                             cooldown=self.cooldown,
                             load_discount=self.load_discount)


def contention_factor(inflight: int, capacity: Optional[float],
                      workers: Optional[int] = None) -> float:
    """Expected wall-time inflation of one request that shared the host
    with ``inflight - 1`` others (itself included in ``inflight``).

    If aggregate kernel throughput scales by ``capacity`` when issued
    from many threads (the :func:`repro.core.streams.parallel_capacity`
    ceiling), then ``k`` concurrently executing requests each run
    ``k / capacity`` slower than they would alone.  ``workers`` caps
    ``k`` — only that many execute at once regardless of window
    occupancy.  Clamped at 1.0: overlap never *deflates* a measurement,
    and the serial scheduler (``inflight=1``) is always factor 1.

    This is the load-aware drift signal's core arithmetic: dividing
    ``measured_s`` by this factor before computing relative prediction
    error stops concurrent-mode contention from masquerading as model
    drift.

    ``workers=0`` is a degenerate pool — nothing can overlap, so the
    factor is exactly 1.0.  It used to silently mean "uncapped" (a
    falsy-check bug): a caller probing an empty pool got its window
    occupancy treated as concurrency and every measurement deflated.
    ``workers=None`` (unknown pool size) remains uncapped on purpose."""
    if capacity is None:
        return 1.0
    if workers is not None and workers < 0:
        raise ValueError(f"workers must be >= 0, got {workers}")
    if workers == 0:
        return 1.0
    eff = min(inflight, workers) if workers is not None else inflight
    return max(1.0, eff / max(capacity, 1e-9))


@dataclasses.dataclass
class RefinementResult:
    key: str
    old_config: Optional[StreamConfig]
    new_config: StreamConfig
    measured: dict                 # StreamConfig -> seconds
    t_single_s: float
    speedup: float                 # measured, of new_config
    refit_loss: Optional[float]    # None when the model has no refit hook
    seconds: float                 # wall time of the whole refinement


class Refiner:
    """Re-profiles a small candidate set and refreshes cache + model."""

    def __init__(self, model, cache: TuningCache, *,
                 candidates: Optional[Sequence[StreamConfig]] = None,
                 top_k: int = 3, reps: int = 1,
                 refit_epochs: int = 150, refit_lr: float = 3e-3,
                 clock=None):
        self.model = model
        self.cache = cache
        self.candidates = list(candidates or default_space())
        self.top_k = top_k
        self.reps = reps
        self.refit_epochs = refit_epochs
        self.refit_lr = refit_lr
        self.history: list[RefinementResult] = []
        # the owning scheduler binds its own clock here (one time source
        # per scheduler — clock.py); an unbound standalone refiner falls
        # back to perf_counter
        self.clock = clock

    def _now(self) -> float:
        return (self.clock.now() if self.clock is not None
                else time.perf_counter())

    def refine(self, runner: StreamedRunner, key: str,
               prog_feats: Optional[np.ndarray],
               current: Optional[TuneResult], *,
               model=None) -> RefinementResult:
        """Re-profile and refresh ``key``.  ``model`` overrides the
        refiner's default for both the top-k search and the refit — the
        tenancy hook: an isolating scheduler passes the drifting
        tenant's own (forked) model so measured feedback never refits a
        model other tenants serve from."""
        model = model if model is not None else self.model
        t0 = self._now()
        if prog_feats is None:
            # hit on a persisted cache from a previous process: the raw
            # features were never extracted here, so re-profile them
            from repro.core.features import extract_features
            prog_feats = extract_features(runner, profile_reps=1).values

        n_rows = next(iter(runner.chunked.values())).shape[0]
        # empty would make search_best fall back to the full default grid
        cands = [c for c in self.candidates
                 if c.partitions * c.tasks <= n_rows] or [SINGLE_STREAM]
        k = min(self.top_k, len(cands))
        picks, _, _ = search_best(model, prog_feats, cands, top_k=k)
        if k == 1:
            picks = [picks]
        probe = list(dict.fromkeys(
            [SINGLE_STREAM]
            + [*picks]
            + ([current.config] if current is not None else [])))

        self.cache.invalidate(key)
        # interleaved sweeps, not back-to-back reps — the shared
        # spike-resistant protocol (see streams.profile_grid_interleaved)
        measured = profile_grid_interleaved(runner, probe,
                                            sweeps=self.reps)
        t_single = measured[SINGLE_STREAM]
        best = min(measured, key=measured.get)
        speedup = t_single / max(measured[best], 1e-12)

        self.cache.put(key, TuneResult(
            best, float(speedup), 0.0, 0.0,
            backend=runner.backend.name, source="refined"))

        refit_loss = None
        if hasattr(model, "refit"):
            rows = assemble_rows(prog_feats, list(measured))
            ys = np.array([t_single / max(measured[c], 1e-12)
                           for c in measured])
            refit_loss = model.refit(rows, ys,
                                     epochs=self.refit_epochs,
                                     lr=self.refit_lr)

        result = RefinementResult(
            key=key,
            old_config=current.config if current is not None else None,
            new_config=best, measured=measured, t_single_s=t_single,
            speedup=float(speedup), refit_loss=refit_loss,
            seconds=self._now() - t0)
        self.history.append(result)
        return result
