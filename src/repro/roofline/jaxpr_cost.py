"""Jaxpr-level cost walker: logical FLOPs + memory traffic with EXACT
control-flow accounting.

XLA's HloCostAnalysis visits each while-loop body once, so a scanned-layer
model under-reports flops by the trip count (measured 13x on a 32-layer
model).  Unrolling fixes fidelity but costs ~2 min/cell of compile time on
this 1-core container.  This walker instead traverses the *jaxpr* of the
step function, multiplying scan/while bodies by their trip counts —
measured agreement with XLA cost analysis on fully-unrolled modules is
~±10% (see tests/test_roofline.py).

Conventions:
  flops: dot_general = 2*M*N*K*batch; conv = 2*spatial*filter; elementwise
  ops = max operand size; reduces = input size; everything else free.
  bytes: every equation reads its inputs and writes its outputs once
  (logical traffic — a fusion-independent roofline proxy).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.extend import core as jcore


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0           # raw logical traffic (upper bound)
    transcendentals: float = 0.0
    bytes_fused: float = -1.0    # carry-resident estimate (TPU-kernel-like)

    def __post_init__(self):
        if self.bytes_fused < 0:
            self.bytes_fused = self.bytes

    def __add__(self, o: "Cost") -> "Cost":
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.transcendentals + o.transcendentals,
                    self.bytes_fused + o.bytes_fused)

    def __mul__(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k,
                    self.transcendentals * k, self.bytes_fused * k)


def _aval_bytes(aval) -> float:
    if not hasattr(aval, "shape"):
        return 0.0
    try:
        return float(np.prod(aval.shape, dtype=np.float64)
                     * np.dtype(aval.dtype).itemsize)
    # jaxpr avals are duck-typed across jax versions; any aval
    # that won't yield a byte size costs 0, never a crash
    except Exception:  # noqa: BLE001
        return 0.0


def _aval_size(aval) -> float:
    if not hasattr(aval, "shape"):
        return 0.0
    return float(np.prod(aval.shape, dtype=np.float64)) if aval.shape else 1.0


_TRANS = {"exp", "log", "tanh", "logistic", "erf", "sin", "cos", "rsqrt",
          "sqrt", "pow", "erf_inv", "expm1", "log1p", "cbrt"}

_FREE = {"reshape", "broadcast_in_dim", "squeeze", "transpose", "slice",
         "concatenate", "convert_element_type", "bitcast_convert_type",
         "iota", "rev", "pad", "dynamic_slice", "dynamic_update_slice",
         "gather", "scatter", "scatter-add", "copy", "stop_gradient",
         "split"}


def _dot_flops(eqn) -> float:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    batch = np.prod([a.shape[i] for i in lb], dtype=np.float64) if lb else 1.0
    contract = np.prod([a.shape[i] for i in lc], dtype=np.float64) if lc else 1.0
    m = np.prod([a.shape[i] for i in range(a.ndim)
                 if i not in lc and i not in lb], dtype=np.float64)
    n = np.prod([b.shape[i] for i in range(b.ndim)
                 if i not in rc and i not in rb], dtype=np.float64)
    return 2.0 * batch * m * n * contract


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    fg = eqn.params.get("feature_group_count", 1)
    kernel_size = np.prod(rhs.shape, dtype=np.float64)
    out_spatial = _aval_size(out)
    # flops ~= 2 * output elements * (kernel elems / out_channels) — rough
    return 2.0 * out_spatial * kernel_size / max(rhs.shape[0], 1) / max(fg, 1) * fg


def jaxpr_cost(jaxpr) -> Cost:
    total = Cost()
    for eqn in jaxpr.eqns:
        total = total + _eqn_cost(eqn)
    return total


def _sub_jaxprs(params: dict):
    for v in params.values():
        if isinstance(v, jcore.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jcore.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, jcore.ClosedJaxpr):
                    yield x.jaxpr
                elif isinstance(x, jcore.Jaxpr):
                    yield x


def _eqn_cost(eqn) -> Cost:
    prim = eqn.primitive.name
    io_bytes = (sum(_aval_bytes(v.aval) for v in eqn.invars
                    if hasattr(v, "aval"))
                + sum(_aval_bytes(v.aval) for v in eqn.outvars))

    if prim == "scan":
        length = float(eqn.params["length"])
        body = jaxpr_cost(eqn.params["jaxpr"].jaxpr)
        total = body * length
        # carry-residency: a TPU kernel (or donated XLA loop buffer) keeps
        # the scan carry resident across iterations — e.g. flash-attention
        # online-softmax accumulators live in VMEM, not HBM.  Remove the
        # per-iteration carry read+write from the fused-bytes estimate.
        n_carry = eqn.params.get("num_carry", 0)
        carry_bytes = sum(_aval_bytes(v.aval)
                          for v in eqn.outvars[:n_carry])
        saved = 2.0 * carry_bytes * max(length - 1.0, 0.0)
        total.bytes_fused = max(total.bytes_fused - saved, 0.0)
        return total
    if prim == "while":
        # unknown trip count statically; count once (jax.lax.scan covers
        # the model's loops — plain while appears only in adamw bc powers)
        body = jaxpr_cost(eqn.params["body_jaxpr"].jaxpr)
        cond = jaxpr_cost(eqn.params["cond_jaxpr"].jaxpr)
        return body + cond
    if prim == "cond":
        branches = [jaxpr_cost(b.jaxpr) for b in eqn.params["branches"]]
        return max(branches, key=lambda c: c.flops) if branches else Cost()
    if prim in ("shard_map", "smap"):
        # body avals are per-shard: scale by the mapped device count so the
        # walker's global-cost convention holds
        n_dev = 1
        mesh = eqn.params.get("mesh")
        if mesh is not None:
            try:
                n_dev = int(np.prod(list(dict(mesh.shape).values())))
            # mesh.shape layout varies across jax versions
            except Exception:  # noqa: BLE001
                n_dev = getattr(mesh, "size", 1)
        sub = Cost()
        for j in _sub_jaxprs(eqn.params):
            sub = sub + jaxpr_cost(j)
        return sub * n_dev
    if prim in ("pjit", "closed_call", "core_call", "remat_call",
                "custom_jvp_call", "custom_vjp_call",
                "custom_vjp_call_jaxpr", "checkpoint", "remat", "remat2"):
        sub = Cost()
        for j in _sub_jaxprs(eqn.params):
            sub = sub + jaxpr_cost(j)
        return sub
    if prim == "dot_general":
        return Cost(_dot_flops(eqn), io_bytes)
    if prim == "conv_general_dilated":
        return Cost(_conv_flops(eqn), io_bytes)
    if prim in ("gather", "scatter", "scatter-add", "dynamic_slice",
                "dynamic_update_slice"):
        return Cost(0.0, io_bytes)  # irregular access: stays HBM traffic
    if prim in _FREE:
        return Cost(0.0, io_bytes, bytes_fused=0.0)
    if prim in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                "reduce_and", "reduce_or", "argmax", "argmin",
                "reduce_precision", "cumsum", "cumlogsumexp", "cummax"):
        n = sum(_aval_size(v.aval) for v in eqn.invars if hasattr(v, "aval"))
        return Cost(n, io_bytes, bytes_fused=0.0)
    if prim in ("sort", "top_k"):
        n = max((_aval_size(v.aval) for v in eqn.invars
                 if hasattr(v, "aval")), default=0.0)
        return Cost(n * max(np.log2(max(n, 2)), 1.0), io_bytes)
    # unknown call-like primitives: recurse into any held jaxprs
    subs = list(_sub_jaxprs(eqn.params))
    if subs:
        sub = Cost()
        for j in subs:
            sub = sub + jaxpr_cost(j)
        return sub
    # elementwise & everything else: flops counted, but a fused TPU program
    # keeps these chains in registers/VMEM — no HBM traffic (bytes_fused=0;
    # the raw `bytes` field keeps the unfused upper bound).
    n = max((_aval_size(v.aval) for v in eqn.outvars), default=0.0)
    trans = n if prim in _TRANS else 0.0
    return Cost(n, io_bytes, trans, bytes_fused=0.0)


def step_cost(fn, *args, **kwargs) -> Cost:
    """Cost of fn(*args) from its closed jaxpr (args may be SDS)."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return jaxpr_cost(closed.jaxpr)
