"""Feature-importance analysis (paper §3.2.3, Fig. 6): Varimax-rotated
PCA loadings over the profiled corpus quantify each raw feature's
contribution to the model's input space.

    PYTHONPATH=src python benchmarks/feature_importance.py
"""
from __future__ import annotations

import os
import sys

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.core import dataset as ds  # noqa: E402
from repro.core.features import RAW_FEATURE_NAMES  # noqa: E402
from repro.core.perf_model import FeaturePipeline  # noqa: E402


def varimax(loadings: np.ndarray, *, gamma: float = 1.0, iters: int = 100,
            tol: float = 1e-6) -> np.ndarray:
    """Classic Varimax rotation of a (features x components) loading
    matrix (Kaiser 1958)."""
    p, k = loadings.shape
    R = np.eye(k)
    var = 0.0
    for _ in range(iters):
        L = loadings @ R
        u, s, vt = np.linalg.svd(
            loadings.T @ (L**3 - (gamma / p) * L @ np.diag(
                np.sum(L**2, axis=0))))
        R = u @ vt
        new_var = np.sum(s)
        if new_var - var < tol:
            break
        var = new_var
    return loadings @ R


def main() -> None:
    samples = ds.generate(None, datasets_per_program=3, reps=2,
                          verbose=False)
    X, y = ds.training_matrix(samples)
    pipe = FeaturePipeline.fit(X, y, n_components=9)

    # loadings of the kept raw features on the PCA components
    names = [
        (RAW_FEATURE_NAMES + ["cfg_log2_partitions", "cfg_log2_tasks",
                              "cfg_log2_tasks_per_part"])[i]
        for i in pipe.keep_idx]
    rotated = varimax(pipe.pca_components)
    # importance = total squared rotated loading (variance carried)
    importance = np.sum(rotated**2, axis=1)
    importance = importance / importance.sum()

    print("feature,importance  (Varimax-rotated PCA variance share; "
          "paper Fig. 6 analogue)")
    order = np.argsort(-importance)
    for i in order:
        bar = "#" * int(round(importance[i] * 200))
        print(f"{names[i]:26s} {importance[i]:6.3f} {bar}")
    print(f"\npruned (|rho|>0.7): "
          f"{sorted(set(range(X.shape[1])) - set(pipe.keep_idx.tolist()))}")


if __name__ == "__main__":
    main()
