"""Deadline-aware retry with capped exponential backoff.

Wrapped around the two serving stages that are worth repeating —
cold-path model search and backend dispatch.  The budget is the
request's SLO deadline (PR 6 ``WorkloadRequest.deadline_s``): a retry
whose backoff sleep would land past the deadline is pointless work that
only *widens* the violation, so the loop re-raises the original error
instead of sleeping through the budget.

Jitter is drawn from the caller's RNG (seeded per request), keeping
replays deterministic while still de-correlating concurrent retries —
the same reason PR 6 indexes service-model noise by arrival.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Optional, TypeVar

T = TypeVar("T")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff: ``base * multiplier**attempt``,
    clipped at ``cap_s``, stretched by up to ``jitter`` fraction."""

    attempts: int = 3
    base_s: float = 0.005
    multiplier: float = 2.0
    cap_s: float = 0.1
    jitter: float = 0.5

    def backoff_s(self, attempt: int, rng: random.Random) -> float:
        raw = min(self.base_s * self.multiplier ** attempt, self.cap_s)
        return raw * (1.0 + self.jitter * rng.random())


def call_with_retry(fn: Callable[[], T], *,
                    policy: RetryPolicy,
                    rng: random.Random,
                    clock=None,
                    deadline_s: Optional[float] = None,
                    sleep: Callable[[float], None] = time.sleep,
                    retry_on: tuple = (Exception,),
                    on_retry: Optional[Callable[[int, BaseException], None]] = None,
                    on_recover: Optional[Callable[[int], None]] = None) -> T:
    """Run ``fn`` up to ``policy.attempts`` times.

    The retry budget is bounded by ``deadline_s`` (on ``clock``'s
    timeline): if the next backoff sleep would end past the deadline,
    the last error is re-raised immediately — failing fast inside the
    SLO beats succeeding after it.  ``on_recover(n_failures)`` fires
    when a success follows at least one failure (the scheduler counts
    it on ``serving.faults.recovered``).
    """
    failures = 0
    while True:
        try:
            result = fn()
        except retry_on as e:
            failures += 1
            if failures >= policy.attempts:
                raise
            backoff = policy.backoff_s(failures - 1, rng)
            if deadline_s is not None and clock is not None \
                    and clock.now() + backoff >= deadline_s:
                raise  # no budget left: retrying can only widen the miss
            if on_retry is not None:
                on_retry(failures, e)
            sleep(backoff)
        else:
            if failures and on_recover is not None:
                on_recover(failures)
            return result
