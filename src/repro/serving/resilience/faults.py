"""Deterministic fault injection for the serving path.

A :class:`FaultPlan` is a committed, seeded schedule of failures that
the schedulers (and the discrete-event replay in ``serving.traces``)
evaluate at named *sites* on the hot path::

    decide, tune.cold, dispatch, retire, refine, registry.load

Each :class:`FaultSpec` matches site invocations either by explicit
0-based invocation index (``at=(3, 4, 5)``) or by period
(``every=50`` fires on the 50th, 100th, ... invocation), optionally
capped by ``times``.  Two kinds exist:

``error``
    :meth:`FaultPlan.fire` raises :class:`InjectedFault` — the layer
    under test must contain it (retry, degrade, or fail the request
    individually; never the scheduler).
``latency``
    :meth:`FaultPlan.fire` stalls for ``delay_s`` (a hung backend /
    co-tenant interference spike).  Under the virtual-clock harness the
    plan is bound with ``sleep=None`` and ``fire`` *returns* the delay
    so the simulator can charge it to the service time instead.

Matching is pure counter arithmetic on the per-site invocation count —
no wall clock, no RNG draw unless ``probability`` is set (and then from
the plan's own seeded RNG) — so a (plan, workload) pair replays
identically, which is what makes chaos results gateable in CI.

Fired faults are counted on the PR 7 metrics registry as
``serving.faults.injected{site=..., kind=...}``.
"""
from __future__ import annotations

import dataclasses
import json
import random
import threading
import time
from typing import Callable, Optional, Sequence

#: the stage names schedulers evaluate; kept in one place so typos in a
#: committed schedule are caught at load time, not silently ignored
SITES = ("decide", "tune.cold", "dispatch", "retire", "refine",
         "registry.load")


class InjectedFault(RuntimeError):
    """The exception an ``error``-kind fault raises at its site."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic failure rule for a named site."""

    site: str
    kind: str = "error"                # "error" | "latency"
    at: tuple[int, ...] = ()           # explicit 0-based invocation idxs
    every: int = 0                     # fire each Nth invocation (1-based)
    times: int = 0                     # max fires (0 = unlimited)
    probability: float = 0.0           # seeded coin-flip gate (0 = off)
    delay_s: float = 0.05              # latency-kind stall
    message: str = ""

    def __post_init__(self) -> None:
        if self.site not in SITES:
            raise ValueError(
                f"unknown fault site {self.site!r}; valid: {SITES}")
        if self.kind not in ("error", "latency"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if not self.at and not self.every and not self.probability:
            raise ValueError(
                "FaultSpec needs at=, every= or probability= to match")

    def to_json(self) -> dict:
        out = dataclasses.asdict(self)
        out["at"] = list(self.at)
        return {k: v for k, v in out.items()
                if v not in ((), [], 0, 0.0, "") or k in ("site", "kind")}

    @staticmethod
    def from_json(payload: dict) -> "FaultSpec":
        payload = dict(payload)
        payload["at"] = tuple(payload.get("at", ()))
        return FaultSpec(**payload)


class FaultPlan:
    """A seeded, replayable schedule of :class:`FaultSpec` rules.

    One plan instance carries mutable per-site invocation counters, so
    use a fresh plan (or :meth:`reset`) per run.  ``bind`` attaches the
    run's metrics registry and, for virtual-time harnesses, disables
    real sleeping (``sleep=None``).
    """

    def __init__(self, specs: Sequence[FaultSpec] = (), seed: int = 0):
        self.specs = tuple(specs)
        self.seed = seed
        self._rng = random.Random(seed)
        self._counts: dict[str, int] = {}
        self._fires: dict[int, int] = {}   # spec index -> fire count
        self._lock = threading.Lock()
        self._sleep: Optional[Callable[[float], None]] = time.sleep
        self._m_injected = None
        self.enabled = bool(self.specs)

    def reset(self) -> None:
        self._rng = random.Random(self.seed)
        self._counts.clear()
        self._fires.clear()

    def bind(self, *, metrics=None,
             sleep: Optional[Callable[[float], None]] = time.sleep) -> "FaultPlan":
        """Attach a metrics registry; ``sleep=None`` makes latency
        faults *return* their delay instead of stalling (virtual time).
        """
        self._m_injected = metrics
        self._sleep = sleep
        return self

    def _matches(self, spec: FaultSpec, idx: int, fired: int) -> bool:
        if spec.times and fired >= spec.times:
            return False
        if spec.at and idx in spec.at:
            return True
        if spec.every and (idx + 1) % spec.every == 0:
            return True
        if spec.probability and self._rng.random() < spec.probability:
            return True
        return False

    def fire(self, site: str) -> float:
        """Evaluate one invocation of ``site``.

        Raises :class:`InjectedFault` for a matched ``error`` spec;
        stalls (or returns) the summed delay for matched ``latency``
        specs; returns 0.0 when nothing matches.
        """
        if not self.enabled:
            return 0.0
        with self._lock:
            idx = self._counts.get(site, 0)
            self._counts[site] = idx + 1
            error: Optional[FaultSpec] = None
            delay = 0.0
            for i, spec in enumerate(self.specs):
                if spec.site != site:
                    continue
                if not self._matches(spec, idx, self._fires.get(i, 0)):
                    continue
                self._fires[i] = self._fires.get(i, 0) + 1
                if self._m_injected is not None:
                    self._m_injected.counter(
                        "serving.faults.injected",
                        site=site, kind=spec.kind).inc()
                if spec.kind == "error" and error is None:
                    error = spec
                elif spec.kind == "latency":
                    delay += spec.delay_s
        if delay > 0.0 and self._sleep is not None:
            self._sleep(delay)
        if error is not None:
            raise InjectedFault(
                error.message
                or f"injected fault at {site} (invocation {idx})")
        return delay

    def invocations(self, site: str) -> int:
        with self._lock:
            return self._counts.get(site, 0)

    @property
    def fired(self) -> int:
        """Total faults fired so far (all specs)."""
        with self._lock:
            return sum(self._fires.values())

    def to_json(self) -> dict:
        return {"seed": self.seed,
                "specs": [s.to_json() for s in self.specs]}

    @staticmethod
    def from_json(payload: dict) -> "FaultPlan":
        return FaultPlan(
            [FaultSpec.from_json(s) for s in payload.get("specs", ())],
            seed=payload.get("seed", 0))

    @staticmethod
    def load(path) -> "FaultPlan":
        with open(path) as f:
            return FaultPlan.from_json(json.load(f))

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")


#: shared no-op plan: ``enabled`` is False so ``fire`` is one attribute
#: check on the hot path when no chaos is configured
NULL_FAULTS = FaultPlan(())


def corrupt_json_file(path, mode: str = "truncate",
                      rng: Optional[random.Random] = None) -> str:
    """Deterministically damage a persisted JSON file in place.

    ``truncate`` cuts the file mid-token, ``garbage`` overwrites a span
    with non-JSON bytes from ``rng``, ``empty`` leaves a zero-byte file
    — the three corruption shapes crash-interrupted writes actually
    produce.
    """
    rng = rng or random.Random(0)
    with open(path, "rb") as f:
        data = f.read()
    if mode == "truncate":
        data = data[: max(1, len(data) // 2)]
    elif mode == "garbage":
        lo = len(data) // 4
        hi = max(lo + 1, len(data) // 2)
        junk = bytes(rng.randrange(0x80, 0xFF) for _ in range(hi - lo))
        data = data[:lo] + junk + data[hi:]
    elif mode == "empty":
        data = b""
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    with open(path, "wb") as f:
        f.write(data)
    return str(path)
