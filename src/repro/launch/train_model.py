"""Offline performance-model training CLI — the "at the factory" half of
the paper's train-offline / predict-at-runtime split (§3.1).

    PYTHONPATH=src python -m repro.launch.train_model \
        [--programs a,b,c] [--datasets N] [--kind mlp] [--epochs 600] \
        [--model-dir models/] [--tag nightly] [--no-cv]

Pipeline: profile the workload corpus (every (program, dataset,
stream-config) cell, reusing — and extending — the persistent profile
cache), assemble the (features ++ config) -> speedup training matrix,
leave-one-program-out cross-validate (§5.3.1), train on the full corpus,
and publish the artifact into the :class:`ModelRegistry`, which repoints
``latest`` so serving picks it up on its next load/refresh.

The published manifest is stamped with the feature-schema hash, the
corpus fingerprint, and the CV score, so a serving box can tell exactly
what it is running and a schema drift refuses to load at all.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Optional, Sequence

from repro.core.modeling import dataset as ds
from repro.core.modeling.artifacts import corpus_fingerprint
from repro.core.modeling.evaluate import loo_evaluate
from repro.core.modeling.learners import (ForestRegressor, KernelRidgeRBF,
                                          TreeRegressor)
from repro.core.modeling.perf_model import PerformanceModel
from repro.core.modeling.registry import ModelRegistry

#: a compact mixed corpus: transfer-bound, compute-bound, and in-between
#: programs so leave-one-out folds always train on both regimes
DEFAULT_TRAIN_PROGRAMS = ("vecadd", "dotprod", "mvmult", "binomial",
                          "blackscholes", "jacobi-1d")

#: the small corpus serving bootstraps from when no artifact exists yet:
#: exactly the default adaptive-serving workloads
BOOTSTRAP_PROGRAMS = ("vecadd", "dotprod", "mvmult")

TRAINERS = {
    "mlp": PerformanceModel,
    "cart": TreeRegressor,
    "forest": ForestRegressor,
    "krr": KernelRidgeRBF,
}


def _train_kwargs(kind: str, *, epochs: int, n_components: int,
                  seed: int) -> dict:
    kw = {"n_components": n_components, "seed": seed}
    if kind == "mlp":
        kw["epochs"] = epochs
    return kw


def train_and_publish(
    programs: Optional[Sequence[str]] = None,
    *,
    kind: str = "mlp",
    datasets_per_program: int = 2,
    reps: int = 1,
    epochs: int = 600,
    n_components: int = 9,
    seed: int = 0,
    cache_path=None,
    registry: Optional[ModelRegistry] = None,
    model_dir=None,
    tag: str = "",
    run_cv: bool = True,
    verbose: bool = True,
) -> dict:
    """Profile -> (CV) -> train -> publish; returns the run summary."""
    cls = TRAINERS[kind]
    programs = list(programs or DEFAULT_TRAIN_PROGRAMS)
    registry = registry or ModelRegistry(model_dir)
    kw = _train_kwargs(kind, epochs=epochs, n_components=n_components,
                       seed=seed)

    t0 = time.perf_counter()
    samples = ds.generate(programs, datasets_per_program=datasets_per_program,
                          reps=reps, cache_path=cache_path, verbose=verbose)
    t_profile = time.perf_counter() - t0
    corpus = corpus_fingerprint(samples)

    cv = None
    t_cv = 0.0
    if run_cv:
        t0 = time.perf_counter()
        cv = loo_evaluate(samples, model_cls=cls, train_kwargs=kw,
                          verbose=verbose)
        t_cv = time.perf_counter() - t0

    t0 = time.perf_counter()
    X, y = ds.training_matrix(samples)
    model = cls.train(X, y, **kw)
    t_train = time.perf_counter() - t0

    artifact_id = registry.publish(model, corpus=corpus, cv=cv, tag=tag)
    summary = {
        "artifact_id": artifact_id,
        "registry": str(registry.root),
        "kind": kind,
        "programs": programs,
        "n_samples": len(samples),
        "n_rows": int(X.shape[0]),
        "corpus_fingerprint": corpus,
        "cv": cv,
        "profile_s": t_profile,
        "cv_s": t_cv,
        "train_s": t_train,
    }
    if verbose:
        frac = cv["frac_of_oracle"] if cv else None
        print(f"published {artifact_id} -> {registry.root} "
              f"(rows={X.shape[0]}, corpus={corpus}"
              + (f", loo_frac_of_oracle={frac:.3f}" if frac else "")
              + ")", file=sys.stderr, flush=True)
    return summary


def bootstrap_artifact(registry: ModelRegistry, *, verbose: bool = True,
                       epochs: int = 400) -> str:
    """Train-and-publish a minimal fleet artifact when the registry is
    empty — the zero-to-serving path.  Uses the default adaptive-serving
    workloads at two dataset scales each; the profile cache makes every
    run after the first take seconds, not minutes."""
    if verbose:
        print("model registry is empty — bootstrapping a trained "
              "artifact (profiling the bootstrap corpus; cached for "
              "next time)...", file=sys.stderr, flush=True)
    summary = train_and_publish(
        BOOTSTRAP_PROGRAMS, kind="mlp", datasets_per_program=2, reps=1,
        epochs=epochs, registry=registry, tag="bootstrap",
        run_cv=True, verbose=verbose)
    return summary["artifact_id"]


def main() -> None:
    ap = argparse.ArgumentParser(
        description="profile the corpus, train a performance model, "
                    "cross-validate leave-one-program-out, publish the "
                    "artifact")
    ap.add_argument("--programs", default=None,
                    help="comma-separated workload names "
                         f"(default: {','.join(DEFAULT_TRAIN_PROGRAMS)})")
    ap.add_argument("--datasets", type=int, default=2,
                    help="dataset scales per program")
    ap.add_argument("--reps", type=int, default=1,
                    help="profiling repetitions per grid cell")
    ap.add_argument("--kind", default="mlp", choices=sorted(TRAINERS),
                    help="estimator kind to train")
    ap.add_argument("--epochs", type=int, default=600,
                    help="MLP training epochs (mlp kind only)")
    ap.add_argument("--n-components", type=int, default=9,
                    help="PCA components in the feature pipeline")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--profile-cache", default=None,
                    help="profile cache path (default: "
                         "REPRO_PROFILE_CACHE or "
                         "benchmarks/data/profile_cache.json)")
    ap.add_argument("--model-dir", default=None,
                    help="registry root (default: REPRO_MODEL_DIR or "
                         "<repo>/models)")
    ap.add_argument("--tag", default="", help="free-form artifact tag")
    ap.add_argument("--no-cv", action="store_true",
                    help="skip leave-one-program-out cross-validation")
    args = ap.parse_args()

    summary = train_and_publish(
        args.programs.split(",") if args.programs else None,
        kind=args.kind, datasets_per_program=args.datasets,
        reps=args.reps, epochs=args.epochs,
        n_components=args.n_components, seed=args.seed,
        cache_path=args.profile_cache, model_dir=args.model_dir,
        tag=args.tag, run_cv=not args.no_cv)
    print(json.dumps(summary, indent=2))


if __name__ == "__main__":
    main()
