"""Blocked flash attention (jnp) vs naive reference + decode paths."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import (decode_attention_local, flash_attention,
                                    reference_attention, apply_rope)


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (2, 64, 8, 2, 32), (1, 96, 4, 4, 16), (2, 128, 6, 3, 64),
    (1, 33, 4, 1, 8),  # ragged sequence (padding path)
])
def test_flash_matches_reference(B, S, H, KV, hd):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = flash_attention(q, k, v, q_block=32, kv_block=32)
    ref = reference_attention(q, k, v)
    assert jnp.allclose(out, ref, atol=2e-5), float(jnp.abs(out - ref).max())


def test_flash_expand_kv_matches_grouped():
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (2, 64, 8, 32))
    k = jax.random.normal(ks[1], (2, 64, 2, 32))
    v = jax.random.normal(ks[2], (2, 64, 2, 32))
    a = flash_attention(q, k, v, gqa_grouped=True, q_block=16, kv_block=16)
    b = flash_attention(q, k, v, gqa_grouped=False, q_block=16, kv_block=16)
    assert jnp.allclose(a, b, atol=2e-5)


def test_flash_non_causal():
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (1, 48, 4, 16))
    k = jax.random.normal(ks[1], (1, 48, 4, 16))
    v = jax.random.normal(ks[2], (1, 48, 4, 16))
    out = flash_attention(q, k, v, causal=False, q_block=16, kv_block=16)
    ref = reference_attention(q, k, v, causal=False)
    assert jnp.allclose(out, ref, atol=2e-5)


def test_decode_matches_full_attention():
    """decode at position t over a cache == row t of full attention."""
    B, S, H, KV, hd = 2, 24, 4, 2, 16
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    full = reference_attention(q, k, v)

    t = S - 1
    kc = jnp.zeros((B, S, KV, hd)).at[:, :t].set(k[:, :t])
    vc = jnp.zeros((B, S, KV, hd)).at[:, :t].set(v[:, :t])
    out, _, _ = decode_attention_local(
        q[:, t:t + 1], k[:, t:t + 1], v[:, t:t + 1], kc, vc, t)
    assert jnp.allclose(out[:, 0], full[:, t], atol=2e-5)


def test_rope_properties():
    x = jax.random.normal(jax.random.key(4), (1, 8, 2, 16))
    pos = jnp.arange(8)
    y = apply_rope(x, pos, 10_000.0)
    # norm-preserving rotation
    assert jnp.allclose(jnp.linalg.norm(y, axis=-1),
                        jnp.linalg.norm(x, axis=-1), atol=1e-4)
    # relative property: shifting positions preserves q.k products
    q = jax.random.normal(jax.random.key(5), (1, 8, 2, 16))
    q1, x1 = apply_rope(q, pos, 1e4), apply_rope(x, pos, 1e4)
    q2, x2 = apply_rope(q, pos + 7, 1e4), apply_rope(x, pos + 7, 1e4)
    dots1 = jnp.einsum("bshd,bshd->bsh", q1, x1)
    dots2 = jnp.einsum("bshd,bshd->bsh", q2, x2)
    assert jnp.allclose(dots1, dots2, atol=1e-3)
