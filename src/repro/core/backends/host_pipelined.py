"""Pipelined double-buffered host backend.

Three fixes over the synchronous backend, all of which the predecessor
streams work (Zhang et al. 1802.02760; Li et al. 1603.08619) shows matter
as much as choosing the right (partitions, tasks) point:

  1. **Partition slicing happens on the host, before transfer.**  The
     sync backend splits the *device* chunk with numpy, which silently
     round-trips every task through host memory (a D2H per partition).
     Here each partition slice is cut from the host array and shipped
     exactly once.
  2. **Depth-``d`` in-flight window (double buffering at d=2).**  Task
     i+1's H2D transfer is staged while task i's compute is in flight;
     the oldest task is retired (blocked on) before a new one is issued,
     so at most ``depth`` tasks' buffers exist concurrently instead of
     the whole dataset's.
  3. **Buffer donation.**  The kernel runs as
     ``jax.jit(kernel, donate_argnums=0)``, recycling a retired task's
     input buffers for its outputs on backends that support donation
     (GPU/TPU; a silent no-op on CPU).
"""
from __future__ import annotations

import collections
import warnings

import jax

from repro.core.backends.base import ExecutionContext, StreamBackend, \
    dispatch_plan, slice_rows


class PipelinedHostBackend(StreamBackend):
    name = "host-pipelined"
    kind = "runner"

    def __init__(self, depth: int = 2):
        assert depth >= 1, depth
        self.depth = depth

    def dispatch(self, ctx: ExecutionContext, config) -> list:
        # host-side slicing plan: tasks x partitions, memoized boundaries,
        # each slice a view cut straight from the host arrays
        n_rows = next(iter(ctx.chunked.values())).shape[0]
        plans = dispatch_plan(n_rows, config)
        kernel = ctx.donating_jit

        staged: collections.deque = collections.deque()
        inflight: collections.deque = collections.deque()
        outs: list = []

        def stage(idx: int) -> None:
            staged.append([jax.device_put(slice_rows(ctx.chunked, lo, hi),
                                          ctx.device)  # async H2D
                           for lo, hi in plans[idx]])

        with warnings.catch_warnings():
            # CPU ignores donation; silence its per-call warning.
            warnings.filterwarnings(
                "ignore", message=".*[Dd]onat.*", category=UserWarning)
            # prime the pipeline: H2D for the first `depth` tasks
            for idx in range(min(self.depth, len(plans))):
                stage(idx)
            next_stage = min(self.depth, len(plans))
            for _ in range(len(plans)):
                part_devs = staged.popleft()
                task_outs = [kernel(pd, ctx.shared_dev)   # async compute
                             for pd in part_devs]
                outs.extend(task_outs)
                inflight.append(task_outs)
                if next_stage < len(plans):
                    stage(next_stage)  # H2D of i+depth overlaps compute of i
                    next_stage += 1
                while len(inflight) >= self.depth:
                    # retire the oldest task: bounds live buffers to the
                    # window and (with donation) frees its inputs for reuse
                    jax.block_until_ready(inflight.popleft())
        return outs
