"""arctic-480b [moe] — 128 experts top-2 + dense residual MLP
[hf:Snowflake/snowflake-arctic-base; hf]."""
from repro.configs.base import ArchConfig, MoEConfig, register

ARCTIC_480B = register(
    ArchConfig(
        name="arctic-480b",
        family="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=4864,
        vocab_size=32000,
        moe=MoEConfig(
            num_experts=128,
            top_k=2,
            expert_d_ff=4864,
            dense_residual=True,
            dense_d_ff=4864,
            sharding="ep",  # 128 experts / 16-way model axis = 8 per group
        ),
        source="hf:Snowflake/snowflake-arctic-base",
    )
)
