"""Classification-based baseline (the paper's prior work [16]).

A classifier can only choose among configurations *seen in training* —
the limitation the paper's regression approach removes (§6.4).  We
implement the classifier family used in Table 5: k-NN and nearest-centroid
over merged config labels, plus a tree classifier.  Label merging (paper
§6.4): configurations whose training speedups are within 1% of the
program's best are merged toward the most frequent label to keep the
samples-per-label ratio workable.
"""
from __future__ import annotations

import dataclasses
from collections import Counter

import numpy as np

from repro.core.modeling.pipeline import FeaturePipeline
from repro.core.stream_config import StreamConfig


@dataclasses.dataclass
class KNNClassifier:
    pipeline: FeaturePipeline
    X_train: np.ndarray
    labels: list          # best StreamConfig per training program
    k: int = 3

    @staticmethod
    def train(prog_feats: np.ndarray, best_configs: list,
              *, k: int = 3, n_components: int = 9) -> "KNNClassifier":
        y_dummy = np.zeros(len(prog_feats))
        pipe = FeaturePipeline.fit(prog_feats, y_dummy,
                                   n_components=n_components)
        X = pipe.transform(prog_feats)
        labels = merge_labels(best_configs)
        return KNNClassifier(pipe, X, labels, k)

    def predict(self, prog_feat: np.ndarray) -> StreamConfig:
        x = self.pipeline.transform(np.atleast_2d(prog_feat))[0]
        d = np.linalg.norm(self.X_train - x, axis=1)
        idx = np.argsort(d)[: self.k]
        votes = Counter(self.labels[i] for i in idx)
        return votes.most_common(1)[0][0]


def merge_labels(configs: list, min_count: int = 2) -> list:
    """Map rare labels to their nearest frequent label (paper §6.4)."""
    counts = Counter(configs)
    frequent = [c for c, n in counts.items() if n >= min_count]
    if not frequent:
        return list(configs)

    def nearest(c: StreamConfig) -> StreamConfig:
        return min(frequent, key=lambda f: (
            abs(np.log2(f.partitions) - np.log2(c.partitions))
            + abs(np.log2(f.tasks) - np.log2(c.tasks))))

    return [c if c in frequent else nearest(c) for c in configs]
