"""Attention: RoPE, blocked (flash-style) causal attention in pure jnp,
GQA without KV-head materialization, and distributed flash-decode over a
sequence-sharded KV cache (shard_map over the 'model' mesh axis).

The blocked jnp path is simultaneously the production XLA path for pod-scale
shapes (bounded memory at 32k/500k sequence) and the oracle the Pallas
kernel (repro.kernels.flash_attention) is validated against.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, nheads, head_dim); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    # broadcast over head axis
    angles = angles[..., :, None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocked causal flash attention (pure jnp, GQA grouped)
# ---------------------------------------------------------------------------

_NEG_INF = -1e30


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def flash_attention(
    q: jax.Array,      # (B, Sq, H, hd)
    k: jax.Array,      # (B, Sk, KV, hd)
    v: jax.Array,      # (B, Sk, KV, hd)
    *,
    causal: bool = True,
    q_offset: int = 0,          # global position of q[0] (for cached prefill)
    q_block: int = 512,
    kv_block: int = 512,
    logit_scale: Optional[float] = None,
    gqa_grouped: bool = True,
) -> jax.Array:
    """Online-softmax blocked attention. Returns (B, Sq, H, hd).

    gqa_grouped=True computes GQA grouped — q reshaped to (B,Sq,KV,G,hd) so
    K/V are never expanded (best single-device).  gqa_grouped=False expands
    K/V to H heads first: under tensor parallelism the expansion of
    replicated KV to the model-sharded H dim is a local slice (zero
    communication), whereas the grouped reshape of a sharded H into (KV,G)
    makes GSPMD reshard — so the pod path uses the expanded form.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    assert H % KV == 0, (H, KV)
    if not gqa_grouped and KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        KV = H
    G = H // KV
    scale = logit_scale if logit_scale is not None else 1.0 / (hd ** 0.5)

    qb = min(q_block, Sq)
    kb = min(kv_block, Sk)
    Sq_p, Sk_p = _ceil_to(Sq, qb), _ceil_to(Sk, kb)
    q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    nq, nk = Sq_p // qb, Sk_p // kb

    q = q.reshape(B, nq, qb, KV, G, hd)
    k = k.reshape(B, nk, kb, KV, hd)
    v = v.reshape(B, nk, kb, KV, hd)

    q_pos = q_offset + jnp.arange(Sq_p).reshape(nq, qb)
    k_pos = jnp.arange(Sk_p).reshape(nk, kb)
    k_valid = (jnp.arange(Sk_p) < Sk).reshape(nk, kb)

    def q_step(_, qi):
        q_i, qp_i = qi  # (B, qb, KV, G, hd), (qb,)

        def kv_step(carry, ki):
            m, l, acc = carry
            k_j, v_j, kp_j, kv_j = ki
            s = jnp.einsum(
                "bqkgh,bskh->bkgqs", q_i, k_j,
                preferred_element_type=jnp.float32,
            ) * scale
            mask = kv_j[None, :]
            if causal:
                mask = mask & (qp_i[:, None] >= kp_j[None, :])
            s = jnp.where(mask[None, None, None], s, _NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bkgqs,bskh->bkgqh", p, v_j.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, qb), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qb), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qb, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0), k_pos, k_valid),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B, KV, G, qb, hd) -> (B, qb, KV, G, hd)
        return None, jnp.transpose(out, (0, 3, 1, 2, 4))

    _, o = jax.lax.scan(q_step, None, (jnp.moveaxis(q, 1, 0), q_pos))
    o = jnp.moveaxis(o, 0, 1).reshape(B, Sq_p, H, hd)[:, :Sq]
    return o.astype(v.dtype)


def reference_attention(q, k, v, *, causal=True, q_offset=0,
                        logit_scale=None) -> jax.Array:
    """Naive O(S^2)-memory oracle for tests."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = logit_scale if logit_scale is not None else 1.0 / (hd ** 0.5)
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        qp = q_offset + jnp.arange(Sq)
        mask = qp[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", p, v.astype(jnp.float32))
    return jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(B, Sq, H, hd).astype(v.dtype)


# ---------------------------------------------------------------------------
# Distributed flash-decode over a sequence-sharded KV cache
# ---------------------------------------------------------------------------
#
# The KV cache (B, S, KV, hd) is sharded S over the 'model' axis (16-way):
# starcoder2's kv=4 heads cannot shard a 16-way axis, but 32k/512k sequences
# can.  Each model-shard holds a contiguous S/16 slab; a decode step
#   1. writes the new k/v into whichever shard owns position `t`,
#   2. computes partial attention (per-shard max / exp-sum / weighted V),
#   3. combines partials with pmax/psum over 'model'  — flash-decode.


def _local_decode_attn(q, k_loc, v_loc, t, shard_base, s_loc, scale):
    """Partial attention of q (B,1,H,hd) against a local cache slab."""
    B, _, H, hd = q.shape
    KV = k_loc.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_loc,
                   preferred_element_type=jnp.float32) * scale
    pos = shard_base + jnp.arange(s_loc)
    mask = pos[None, None, None, :] <= t
    s = jnp.where(mask, s, _NEG_INF)
    m = jnp.max(s, axis=-1)                                   # (B,KV,G)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v_loc.astype(jnp.float32))
    return m, l, o


def decode_attention_sharded(
    q: jax.Array,        # (B, 1, H, hd)
    k_new: jax.Array,    # (B, 1, KV, hd)
    v_new: jax.Array,    # (B, 1, KV, hd)
    k_cache: jax.Array,  # (B, S, KV, hd)  S sharded over 'model'
    v_cache: jax.Array,
    t: jax.Array,        # scalar int32: position being decoded
    *,
    mesh,
    dp_axes: tuple,      # e.g. ('data',) or ('pod','data')
    logit_scale: Optional[float] = None,
):
    """Returns (attn_out (B,1,H,hd), new_k_cache, new_v_cache)."""
    B, _, H, hd = q.shape
    S = k_cache.shape[1]
    scale = logit_scale if logit_scale is not None else 1.0 / (hd ** 0.5)
    n_shards = 1
    for ax in ("model",):
        n_shards *= mesh.shape[ax]
    s_loc = S // n_shards

    dp = tuple(dp_axes) if dp_axes else None
    cache_spec = P(dp, "model", None, None)
    rep_spec = P(dp, None, None, None)

    def body(q, k_new, v_new, k_loc, v_loc, t):
        b_loc = q.shape[0]
        shard = jax.lax.axis_index("model")
        base = shard * s_loc
        # 1. masked cache write: only the owner shard takes the update.
        lp = jnp.clip(t - base, 0, s_loc - 1)
        owns = (t >= base) & (t < base + s_loc)
        k_upd = jax.lax.dynamic_update_slice(k_loc, k_new, (0, lp, 0, 0))
        v_upd = jax.lax.dynamic_update_slice(v_loc, v_new, (0, lp, 0, 0))
        k_loc = jnp.where(owns, k_upd, k_loc)
        v_loc = jnp.where(owns, v_upd, v_loc)
        # 2. partial flash-decode on the local slab.
        m, l, o = _local_decode_attn(q, k_loc, v_loc, t, base, s_loc, scale)
        # 3. combine partials across 'model'.
        m_g = jax.lax.pmax(m, "model")
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, "model")
        o_g = jax.lax.psum(o * corr[..., None], "model")
        out = (o_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(q.dtype)
        out = out.reshape(b_loc, 1, H, hd)
        return out, k_loc, v_loc

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(rep_spec, rep_spec, rep_spec, cache_spec, cache_spec, P()),
        out_specs=(rep_spec, cache_spec, cache_spec),
        check_rep=False,
    )
    return fn(q, k_new, v_new, k_cache, v_cache, t)


def _prod(mesh, axes) -> int:
    n = 1
    for a in axes or ():
        n *= mesh.shape[a]
    return n


def decode_attention_local(q, k_new, v_new, k_cache, v_cache, t, *,
                           logit_scale=None):
    """Single-host decode attention (no mesh) — smoke tests / reference."""
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new, (0, t, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new, (0, t, 0, 0))
    B, S, KV, hd = k_cache.shape
    H = q.shape[2]
    scale = logit_scale if logit_scale is not None else 1.0 / (hd ** 0.5)
    m, l, o = _local_decode_attn(q, k_cache, v_cache, t, 0, S, scale)
    out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    return out.reshape(B, 1, H, hd), k_cache, v_cache
