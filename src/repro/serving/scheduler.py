"""The adaptive serving scheduler: the paper's feature → model → config
loop, run online over a multi-tenant request stream.

Per request, the decision point is exactly paper §3.3 ("used as a utility
to quickly search for a good configuration at runtime"), made cheap
enough to sit on the serving path:

  warm path   TuningCache hit (microseconds) → dispatch immediately;
  cold path   extract features (one profiled iteration), rank the config
              space with the performance model via ``search_best``,
              cache the winner, dispatch.

Every dispatch appends a :class:`~repro.serving.telemetry.TelemetrySample`
(chosen config, predicted vs. measured runtime) to the telemetry log, and
feeds the relative prediction error to the
:class:`~repro.serving.refinement.DriftDetector`.  A triggered bucket is
handed to the :class:`~repro.serving.refinement.Refiner`, which
re-profiles a small candidate set, refreshes the cache entry, and refits
the model incrementally — closing the offline-learn / online-correct
loop.
"""
from __future__ import annotations

import collections
import dataclasses
import random
from typing import Optional, Sequence

import jax
import numpy as np

from repro.core import features as feat_lib
from repro.core.autotuner import TuneResult, TuningCache
from repro.core.backends import get_backend
from repro.core.features import RAW_FEATURE_NAMES
# re-exported for back-compat: the heuristic used to be defined here
from repro.core.modeling.heuristic import OverlapHeuristicModel  # noqa: F401
from repro.core.modeling.search import search_best, search_best_batch
from repro.core.stream_config import SINGLE_STREAM, StreamConfig, \
    default_space
from repro.core.streams import StreamedRunner, readback_outputs
from repro.core.workloads import get_workload
from repro.serving.clock import SystemClock
from repro.serving.observability import NULL_METRICS, NULL_TRACER, STAGES
from repro.serving.queue import RequestQueue, WorkloadRequest
from repro.serving.refinement import DriftDetector, Refiner
from repro.serving.resilience import NULL_FAULTS, CircuitBreaker, \
    FaultPlan, ResiliencePolicy, call_with_retry, nearest_bucket_entry
from repro.serving.telemetry import TelemetryLog, TelemetrySample, \
    relative_error
from repro.serving.tenancy import TenantContext, TenantRegistry

_I_T_SINGLE = RAW_FEATURE_NAMES.index("t_single_us")


@dataclasses.dataclass
class RequestResult:
    request: WorkloadRequest
    config: Optional[StreamConfig]
    outputs: list                  # per-slice outputs, task-major order
    measured_s: Optional[float]
    predicted_s: Optional[float]
    cache_hit: bool
    refined: bool
    sample: TelemetrySample
    #: terminal disposition: "served" | "degraded" (served via a
    #: fallback rung) | "failed" | "timeout" — a request is NEVER lost;
    #: under a ResiliencePolicy every submitted request retires with one
    #: of these instead of crashing the scheduler
    status: str = "served"
    error: Optional[str] = None


@dataclasses.dataclass
class PendingRequest:
    """One request mid-flight through the decide → dispatch → retire
    pipeline.  The serial scheduler runs all three stages back to back;
    the concurrent engine (:mod:`repro.serving.engine`) holds many of
    these in its in-flight window at once."""

    req: WorkloadRequest
    runner: StreamedRunner
    key: str
    n_rows: int
    entry: Optional[TuneResult] = None
    cache_hit: bool = False
    needs_anchor: bool = False     # warm persisted hit, anchor unprofiled
    order: int = -1                # global decision order
    bucket_idx: int = -1           # per-bucket dispatch index
    tenant_ctx: Optional[TenantContext] = None
    inflight: int = 1              # window occupancy at dispatch (engine)
    load_factor: float = 1.0       # contention normalization, set at retire
    defer_release: bool = False    # engine: runner held for a deferred
                                   # refinement, released after it runs
    # latency accounting stamps (scheduler clock; arrival lives on req)
    t_decide_s: Optional[float] = None
    t_dispatch_s: Optional[float] = None
    queue_depth: int = 0           # queue length observed at decide time
    # resilience bookkeeping (all inert without a ResiliencePolicy)
    degraded_via: Optional[str] = None   # first fallback rung taken
    requeues: int = 0              # watchdog re-dispatch count (engine)
    watchdog_deadline_s: Optional[float] = None


class AdaptiveScheduler:
    """Drains a :class:`RequestQueue`, making one model-informed placement
    decision per request and learning from every measurement."""

    def __init__(self, model, *,
                 backend: str = "host-sync",
                 policy: str = "fifo",
                 cache: Optional[TuningCache] = None,
                 candidates: Optional[Sequence[StreamConfig]] = None,
                 telemetry: Optional[TelemetryLog] = None,
                 drift: Optional[DriftDetector] = None,
                 refiner: Optional[Refiner] = None,
                 model_tag: str = "",
                 isolate_tenants: bool = False,
                 tenants: Optional[TenantRegistry] = None,
                 warm_before_measure: bool = True,
                 keep_outputs: bool = True,
                 clock=None,
                 tracer=None,
                 metrics=None,
                 faults: Optional[FaultPlan] = None,
                 resilience: Optional[ResiliencePolicy] = None):
        self.model = model
        self.backend_name = backend
        # ONE time source for every latency stamp, deadline judgment,
        # span timestamp, and tuning-overhead measurement: real
        # perf_counter in production, a VirtualClock under the trace
        # harness / timing tests (repro.serving.clock).  The queue, the
        # refiner, and the tracer are all bound to this same instance
        # below, so their clocks can never disagree.
        self.clock = clock if clock is not None else SystemClock()
        # observability: both default to shared no-op singletons whose
        # hot-path calls allocate nothing (asserted by a micro-test)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if self.tracer.enabled and self.tracer.clock is None:
            self.tracer.clock = self.clock
        self.metrics = metrics if metrics is not None else NULL_METRICS
        self.queue = RequestQueue(policy, clock=self.clock,
                                  metrics=self.metrics)
        self.cache = cache if cache is not None else TuningCache()
        self.candidates = list(candidates or default_space())
        self.telemetry = telemetry if telemetry is not None else TelemetryLog()
        self.drift = drift if drift is not None else DriftDetector()
        self.refiner = refiner if refiner is not None else Refiner(
            model, self.cache, candidates=self.candidates)
        if self.refiner.clock is None:
            self.refiner.clock = self.clock
        # pre-bound instruments: hot-path metric updates are one method
        # call on a resolved object (a no-op singleton when disabled)
        m = self.metrics
        self._m_stage = {s: m.histogram(f"serving.stage.{s}.seconds")
                         for s in STAGES}
        self._m_requests = m.counter("serving.requests")
        self._m_searches = m.counter("serving.model.searches")
        self._m_batch_size = m.histogram("serving.cold_batch.size",
                                         buckets=(1, 2, 4, 8, 16, 32, 64))
        self._m_drift_fired = m.counter("serving.drift.fired")
        self._m_refinements = m.counter("serving.refinements")
        self._m_slo_violations = m.counter("serving.slo.violations")
        self._m_queue_depth = m.gauge("serving.queue.depth")
        self._m_inflight = m.gauge("serving.inflight")
        self._m_fault_recovered = m.counter("serving.faults.recovered")
        self._m_fault_degraded = m.counter("serving.faults.degraded")
        self._m_failed = m.counter("serving.requests.failed")
        # fault tolerance: OFF unless a policy is given — every resilient
        # wrapper below passes straight through when self.resilience is
        # None, so the legacy (raise-on-error) behavior is bit-identical
        self.faults = faults if faults is not None else NULL_FAULTS
        if self.faults.enabled:
            self.faults.bind(metrics=self.metrics)
        self.resilience = resilience
        self.breaker: Optional[CircuitBreaker] = None
        if resilience is not None:
            self.breaker = CircuitBreaker(resilience.breaker,
                                          clock=self.clock,
                                          metrics=self.metrics)
            self._fallback_model = OverlapHeuristicModel()
        # tenant isolation: with ``isolate_tenants`` every tenant gets a
        # private cache namespace, drift windows, and (on first refit) a
        # fork of the shared base model.  Off by default — the registry
        # then resolves every tenant to ONE shared context whose drift
        # detector is ``self.drift``, i.e. the pre-tenancy behavior.
        self.tenancy = tenants if tenants is not None else TenantRegistry(
            model, self.drift, isolate=isolate_tenants)
        self.model_tag = model_tag
        self.warm_before_measure = warm_before_measure
        self.keep_outputs = keep_outputs
        self.stats: collections.Counter = collections.Counter()
        # per-bucket serving state: raw program features and the profiled
        # single-stream runtime (the model predicts *speedup*; runtime
        # prediction needs the single-stream anchor)
        self._feats: dict[str, np.ndarray] = {}
        self._t_single: dict[str, float] = {}
        self._warmed: set = set()
        self._seq = 0
        self._order = 0
        # candidate (partitions, tasks) columns, computed once: feasibility
        # filtering and the vectorized heuristic never loop over configs
        self._cand_parts, self._cand_tasks = feat_lib.config_pt_arrays(
            self.candidates)
        self._cand_cost = self._cand_parts * self._cand_tasks

    # -- request intake -------------------------------------------------------

    def submit(self, request: WorkloadRequest) -> WorkloadRequest:
        if request.arrival_s is None:
            request.arrival_s = self.clock.now()
        self.stats[f"tenant.{request.tenant}.submitted"] += 1
        return self.queue.push(request)

    def submit_all(self, requests) -> None:
        for r in requests:
            self.submit(r)

    # -- serving loop ---------------------------------------------------------

    def run(self, max_requests: Optional[int] = None) -> list[RequestResult]:
        """Drain the queue (up to ``max_requests``), one decision per
        request, in queue-policy order."""
        results = []
        while self.queue and (max_requests is None
                              or len(results) < max_requests):
            try:
                req = self.queue.pop()
            except IndexError:
                break      # deadline policy shed everything that was left
            results.append(self._process(req))
        return results

    def step(self) -> RequestResult:
        return self._process(self.queue.pop())

    def _process(self, req: WorkloadRequest) -> RequestResult:
        """Serial pipeline: decide → (cold tune) → execute → retire, all
        on the calling thread.  The concurrent engine reuses exactly
        these stages, overlapped."""
        if self.resilience is None:
            pending = self._decide(req)
            if pending.needs_anchor:
                self._measure_anchor(pending)
            if pending.entry is None:
                self._tune_cold(pending)
            outs, measured_s = self._execute(pending)
            result = self._retire(pending, outs, measured_s)
            self._release_runner(pending.runner)
            return result
        # resilient pipeline: any stage error fails THIS request
        # individually (error telemetry sample + status), never the loop
        pending = None
        try:
            pending = self._decide(req)
            if pending.needs_anchor:
                self._try_anchor(pending)
            if pending.entry is None:
                self._tune_cold_safe(pending)
            outs, measured_s = self._execute_safe(pending)
            result = self._retire(pending, outs, measured_s)
        # the per-request fault barrier: ANY stage failure
        # becomes an individual terminal result, never a
        # scheduler crash
        except Exception as e:  # noqa: BLE001
            result = self._fail_request(req, pending, e)
        finally:
            if pending is not None:
                self._release_runner(pending.runner)
        return result

    def _try_anchor(self, pending: PendingRequest) -> None:
        """The anchor is advisory (it only re-enables runtime prediction
        and drift for a persisted warm hit): under a resilience policy a
        failing anchor measurement is skipped, not fatal."""
        try:
            self._measure_anchor(pending)
        except Exception:  # noqa: BLE001 — advisory stage
            pending.needs_anchor = False

    # -- stage 1: decide ------------------------------------------------------

    def _make_runner(self, req: WorkloadRequest) -> StreamedRunner:
        """One runner per request: each request carries its OWN shared
        buffers, so a cached ExecutionContext would serve stale
        shared_dev data.  The expensive part — kernel compilation — is
        already shared across contexts by backends.base.memoized_jit.
        The concurrent engine overrides this with a context pool that
        swaps the per-request buffers instead of rebuilding."""
        return StreamedRunner(get_workload(req.workload), req.chunked,
                              req.shared, backend=self.backend_name)

    def _release_runner(self, runner: StreamedRunner) -> None:
        """Hook for the engine's context pool; serial runners are
        garbage."""

    def _decide(self, req: WorkloadRequest) -> PendingRequest:
        """Cache lookup + anchor bookkeeping.  A returned ``entry=None``
        means the request is cold and needs a tune before dispatch."""
        t0 = self.clock.now()
        with self.tracer.span("decide", trace_id=req.trace_id,
                              tenant=req.tenant, workload=req.workload):
            # fired before the runner lease so an injected decide error
            # never leaks a pooled ExecutionContext
            self.faults.fire("decide")
            runner = self._make_runner(req)
            n_rows = next(iter(req.chunked.values())).shape[0]
            ctx = self.tenancy.get(req.tenant)
            key = self.cache.key(runner.wl.name, req.chunked, req.shared,
                                 self.backend_name, self.model_tag,
                                 namespace=ctx.namespace)
            pending = PendingRequest(req=req, runner=runner, key=key,
                                     n_rows=n_rows, order=self._order,
                                     tenant_ctx=ctx,
                                     t_decide_s=self.clock.now(),
                                     queue_depth=len(self.queue))
            self._order += 1
            hit = self.cache.get(key, valid=lambda r: (
                r.config.partitions * r.config.tasks <= n_rows))
            if hit is not None:
                pending.entry, pending.cache_hit = hit, True
                # warm hit from a cache persisted by a previous process:
                # the single-stream anchor was never profiled here, and
                # without it predicted runtime — and therefore drift
                # detection — would stay disabled for this bucket.
                # Deferred to _measure_anchor so the engine can quiesce
                # its pool first (an anchor measured under contention
                # would bias rel_error for the bucket's lifetime).
                pending.needs_anchor = key not in self._t_single
        self._m_queue_depth.set(len(self.queue))
        self._m_stage["decide"].observe(self.clock.now() - t0)
        return pending

    def _measure_anchor(self, pending: PendingRequest) -> None:
        """One measured single-stream run restores the runtime anchor
        (and with it drift detection) for a persisted warm hit."""
        if pending.key not in self._t_single:
            with self.tracer.span("tune.anchor",
                                  trace_id=pending.req.trace_id,
                                  key=pending.key):
                self._t_single[pending.key] = pending.runner.run(
                    SINGLE_STREAM, reps=1)
        pending.needs_anchor = False

    # -- stage 1b: cold tune --------------------------------------------------

    def _feasible_configs(self, n_rows: int) -> list[StreamConfig]:
        # guard: an empty filtered list would make search_best fall back
        # to the FULL default grid, returning an unsplittable config
        mask = self._cand_cost <= n_rows
        return [c for c, ok in zip(self.candidates, mask)
                if ok] or [SINGLE_STREAM]

    def _extract(self, pending: PendingRequest) -> np.ndarray:
        feats = feat_lib.extract_features(pending.runner, profile_reps=1)
        self._feats[pending.key] = feats.values
        self._t_single[pending.key] = \
            float(feats.values[_I_T_SINGLE]) * 1e-6
        return feats.values

    def _model_for(self, pending: PendingRequest):
        """The model that ranks configs for this request: the tenant's
        fork once it has refitted, the shared base before that."""
        if pending.tenant_ctx is not None:
            return pending.tenant_ctx.active_model
        return self.model

    def _tune_cold(self, pending: PendingRequest, *,
                   model=None, source: str = "model") -> TuneResult:
        t0 = self.clock.now()
        with self.tracer.span("tune.cold", trace_id=pending.req.trace_id,
                              workload=pending.req.workload):
            self.faults.fire("tune.cold")
            feats = self._extract(pending)
            t_feat = self.clock.now() - t0
            cands = self._feasible_configs(pending.n_rows)
            best, preds, t_search = search_best(
                model if model is not None else self._model_for(pending),
                feats, cands)
            self.stats["model_searches"] += 1
            self._m_searches.inc()
            result = TuneResult(best, float(np.max(preds)), t_feat, t_search,
                                backend=self.backend_name, source=source)
            self.cache.put(pending.key, result)
            pending.entry = result
        self._m_stage["tune"].observe(self.clock.now() - t0)
        return result

    def _tune_cold_batch(self, pendings: Sequence[PendingRequest]) -> None:
        """The batched cold path: extract features once per unique
        bucket (profiling is measurement — it stays serial), then rank
        the config space for ALL cold buckets with ONE batched
        ``predict_configs`` call over the ``(B, F)`` feature matrix.

        Per-request feasibility (row counts differ across buckets) is a
        ``-inf`` mask into the shared prediction matrix, which keeps each
        pick identical to what a serial ``search_best`` over that
        request's filtered candidates would have returned.

        Tenant isolation: buckets are grouped by the model that must
        rank them — tenants that have forked search with their own
        model, so one batched search per DISTINCT model (one total until
        any tenant forks)."""
        # one representative pending per unique bucket, first-seen order
        by_key: dict[str, PendingRequest] = {}
        for p in pendings:
            by_key.setdefault(p.key, p)
        uniques = list(by_key.values())

        t_batch0 = self.clock.now()
        self._m_batch_size.observe(len(uniques))
        with self.tracer.span("tune.cold.batch",
                              trace_id=uniques[0].req.trace_id,
                              buckets=len(uniques),
                              requests=len(pendings)):
            self.faults.fire("tune.cold")
            t0 = self.clock.now()
            F = np.stack([self._extract(p) for p in uniques])
            t_feat = self.clock.now() - t0
            feasible = np.stack(
                [self._cand_cost <= p.n_rows for p in uniques])

            groups: dict[int, list[int]] = {}
            for i, p in enumerate(uniques):
                groups.setdefault(id(self._model_for(p)), []).append(i)

            # feature time was paid once across ALL uniques; search time
            # is per model-group — each term amortized over what it
            # covered
            per_feat = t_feat / len(uniques)
            for idxs in groups.values():
                model = self._model_for(uniques[idxs[0]])
                picks, best_preds, _, t_search = search_best_batch(
                    model, F[idxs], self.candidates,
                    feasible=feasible[idxs])
                self.stats["model_searches"] += 1
                self.stats["batched_searches"] += 1
                self.stats["batched_search_programs"] += len(idxs)
                self._m_searches.inc()
                per_search = t_search / len(idxs)

                for i, pick, pred in zip(idxs, picks, best_preds):
                    p = uniques[i]
                    if not np.isfinite(pred):  # every candidate infeasible
                        pick, pred = SINGLE_STREAM, float(
                            model.predict_configs(self._feats[p.key],
                                                  [SINGLE_STREAM])[0])
                    result = TuneResult(pick, float(pred), per_feat,
                                        per_search,
                                        backend=self.backend_name,
                                        source="model")
                    self.cache.put(p.key, result)
                    p.entry = result
        self._m_stage["tune"].observe(self.clock.now() - t_batch0)
        # same-bucket duplicates inside one batch are warm hits on the
        # representative's fresh entry — unless their own row count makes
        # that config unsplittable (possible within one shape-bucket
        # octave), in which case they re-tune individually, exactly as a
        # serial pass would have
        for p in pendings:
            if p.entry is not None:
                continue
            hit = self.cache.get(p.key, valid=lambda r: (
                r.config.partitions * r.config.tasks <= p.n_rows))
            if hit is not None:
                p.entry, p.cache_hit = hit, True
            else:
                self._tune_cold(p)

    # -- resilient stage wrappers ---------------------------------------------
    # (pass-throughs when self.resilience is None; see resilience/ and
    # the README "Resilience" ladder table)

    def _request_rng(self, req: WorkloadRequest) -> random.Random:
        """Per-request seeded RNG for retry jitter: deterministic given
        (policy seed, request seq), de-correlated across requests."""
        return random.Random((self.resilience.seed << 20) ^ (req.seq & 0xFFFFF))

    def _degrade(self, pending: PendingRequest, via: str) -> None:
        if pending.degraded_via is None:
            pending.degraded_via = via
            self._m_fault_degraded.inc()
            self.stats["degraded"] += 1

    def _tune_cold_safe(self, pending: PendingRequest) -> TuneResult:
        """Cold search down the ladder: primary model (retried within the
        SLO budget, breaker-guarded) → OverlapHeuristicModel → nearest
        cached shape-bucket → single stream.  Always yields an entry —
        a request is never failed for want of a *tuning* decision."""
        if self.resilience is None:
            return self._tune_cold(pending)
        req = pending.req
        bkey = (req.tenant, "tune")
        if self.breaker.allow(bkey):
            try:
                result = call_with_retry(
                    lambda: self._tune_cold(pending),
                    policy=self.resilience.retry,
                    rng=self._request_rng(req), clock=self.clock,
                    deadline_s=req.deadline_s,
                    on_recover=lambda n: self._m_fault_recovered.inc(n))
                self.breaker.record_success(bkey)
                return result
            except Exception:  # noqa: BLE001 — ladder rung
                self.breaker.record_failure(bkey)
        # rung 1: the shape-only heuristic needs no trained weights, but
        # still profiles features — it can fail too (backend death)
        try:
            result = self._tune_cold(pending, model=self._fallback_model,
                                     source="fallback")
            self._degrade(pending, "heuristic-model")
            return result
        except Exception:  # noqa: BLE001 — ladder rung
            pass
        # rung 2: no profiling at all — borrow the nearest cached shape
        # bucket, else run single-stream; NOT cached (it is a guess, and
        # caching it would freeze the guess into the warm path)
        entry = nearest_bucket_entry(self.cache, pending.key,
                                     pending.n_rows)
        if entry is not None:
            entry = dataclasses.replace(entry, source="nearest-bucket",
                                        cached=False)
            via = "nearest-bucket"
        else:
            entry = TuneResult(SINGLE_STREAM, 0.0, 0.0, 0.0,
                               backend=self.backend_name,
                               source="degraded")
            via = "single-stream"
        pending.entry = entry
        self._degrade(pending, via)
        return entry

    def _dispatch_fallback(self, pending: PendingRequest) -> tuple[list, float]:
        """One dispatch on the reference backend: the runner's
        ExecutionContext is backend-independent, so stepping down is a
        temporary swap of the dispatch strategy, not a new context."""
        runner = pending.runner
        prev = runner.backend
        runner.backend = get_backend(self.resilience.fallback_backend)
        try:
            return self._execute(pending)
        finally:
            runner.backend = prev

    def _execute_safe(self, pending: PendingRequest) -> tuple[list, float]:
        """Dispatch down the ladder: primary backend (retried within the
        SLO budget, breaker-guarded) → ``host-sync`` reference backend →
        individual request failure (raises; caller converts)."""
        if self.resilience is None:
            return self._execute(pending)
        req = pending.req
        bkey = (req.tenant, "dispatch")
        have_fallback = \
            self.backend_name != self.resilience.fallback_backend
        if not self.breaker.allow(bkey) and have_fallback:
            self._degrade(pending, "backend")
            return self._dispatch_fallback(pending)
        try:
            result = call_with_retry(
                lambda: self._execute(pending),
                policy=self.resilience.retry,
                rng=self._request_rng(req), clock=self.clock,
                deadline_s=req.deadline_s,
                on_recover=lambda n: self._m_fault_recovered.inc(n))
            self.breaker.record_success(bkey)
            return result
        except Exception:  # noqa: BLE001 — ladder rung
            self.breaker.record_failure(bkey)
            if not have_fallback:
                raise
        self._degrade(pending, "backend")
        return self._dispatch_fallback(pending)

    def _fail_request(self, req: WorkloadRequest,
                      pending: Optional[PendingRequest],
                      error: BaseException,
                      status: str = "failed") -> RequestResult:
        """Terminal *individual* failure: an error telemetry sample with
        ``status``/``error`` set, counters bumped, and a RequestResult
        the caller can return — the scheduler itself never crashes."""
        now = self.clock.now()
        config = pending.entry.config \
            if pending is not None and pending.entry is not None else None
        err = f"{type(error).__name__}: {error}"
        slo_violation = req.deadline_s is not None and now > req.deadline_s
        self._seq += 1
        sample = TelemetrySample(
            seq=self._seq, tenant=req.tenant, workload=req.workload,
            key=pending.key if pending is not None else "",
            backend=self.backend_name,
            partitions=config.partitions if config is not None else 0,
            tasks=config.tasks if config is not None else 0,
            cache_hit=bool(pending.cache_hit) if pending is not None
            else False,
            predicted_s=None, measured_s=None, rel_error=None,
            status=status, error=err,
            t_enqueue_s=req.arrival_s,
            t_decide_s=pending.t_decide_s if pending is not None else None,
            t_dispatch_s=pending.t_dispatch_s
            if pending is not None else None,
            t_retire_s=now,
            latency_s=(now - req.arrival_s
                       if req.arrival_s is not None else None),
            deadline_s=req.deadline_s, slo_violation=slo_violation,
            queue_depth=pending.queue_depth if pending is not None else 0,
            trace_id=req.trace_id)
        self.telemetry.append(sample)
        self.stats["requests"] += 1
        self.stats["failed"] += 1
        self.stats[f"tenant.{req.tenant}.failed"] += 1
        self._m_requests.inc()
        self._m_failed.inc()
        if slo_violation:
            self.stats["slo_violations"] += 1
            self._m_slo_violations.inc()
        return RequestResult(
            request=req, config=config, outputs=[], measured_s=None,
            predicted_s=None,
            cache_hit=bool(pending.cache_hit) if pending is not None
            else False,
            refined=False, sample=sample, status=status, error=err)

    # -- stage 2: execute -----------------------------------------------------

    def _execute(self, pending: PendingRequest) -> tuple[list, float]:
        """Dispatch + measure.  Thread-safe given distinct runners: the
        only shared state is the ``_warmed`` set (GIL-atomic adds; a rare
        duplicate warmup is harmless).  First occurrence of a
        (bucket, config) pair warms up so measured runtime is execution,
        not compilation."""
        runner, key = pending.runner, pending.key
        pending.t_dispatch_s = self.clock.now()
        config = pending.entry.config
        with self.tracer.span("dispatch", trace_id=pending.req.trace_id,
                              partitions=config.partitions,
                              tasks=config.tasks):
            self.faults.fire("dispatch")
            if self.warm_before_measure and \
                    (key, config) not in self._warmed:
                runner.warmup(config)
                self._warmed.add((key, config))
            t0 = self.clock.now()
            outs = runner.dispatch(config)
            jax.block_until_ready(outs)
            # read back like StreamedRunner.run does — every output leaf
            # — so measured_s and the single-stream prediction anchor are
            # timed on the same basis (dispatch + compute + D2H);
            # otherwise rel_error carries a constant bias on
            # transfer-heavy workloads
            readback_outputs(outs)
            measured_s = self.clock.now() - t0
        self._m_stage["dispatch"].observe(measured_s)
        return outs, measured_s

    # -- stage 3: retire ------------------------------------------------------

    def _load_factor(self, pending: PendingRequest) -> float:
        """Contention normalization for the drift signal; 1.0 on the
        serial scheduler (nothing overlaps).  The concurrent engine
        overrides this with in-flight occupancy over the host's measured
        parallel capacity."""
        return 1.0

    def _retire(self, pending: PendingRequest, outs: list,
                measured_s: float) -> RequestResult:
        """Telemetry + drift + refinement.  Runs on the coordinating
        thread only — per-bucket ordering of drift observations is the
        engine's contract, and the refiner re-profiles on the pending
        request's own runner.

        The drift signal is load-aware: ``measured_s`` is divided by the
        contention factor (window occupancy / host parallel capacity)
        before the prediction error is computed, so concurrent-mode
        overlap inflation does not masquerade as model drift.  Drift is
        observed on the request tenant's own windows, and a triggered
        refinement refits the tenant's fork of the model — never the
        shared base another tenant serves from."""
        t_stage0 = self.clock.now()
        req, key, entry = pending.req, pending.key, pending.entry
        ctx = pending.tenant_ctx if pending.tenant_ctx is not None \
            else self.tenancy.get(req.tenant)
        with self.tracer.span("retire", trace_id=req.trace_id,
                              tenant=req.tenant,
                              cache_hit=pending.cache_hit):
            self.faults.fire("retire")
            config = entry.config
            predicted_s = self._predicted_runtime(key, entry)
            load = self._load_factor(pending)
            pending.load_factor = load
            measured_norm_s = measured_s / load
            rel = relative_error(measured_norm_s, predicted_s)

            refined = False
            if ctx.drift.observe(key, rel, load_factor=load):
                ctx.drift.reset(key)
                self._m_drift_fired.inc()
                try:
                    self._refine(pending, ctx, key, entry)
                    refined = True
                except Exception:  # noqa: BLE001
                    # refinement is an optimization: under a resilience
                    # policy a failing refine loses one model update,
                    # never the request (or the scheduler)
                    if self.resilience is None:
                        raise
                    self.stats["refine_failures"] += 1
                    self.metrics.counter("serving.refine.failed").inc()

            t_retire = self.clock.now()
            latency = (t_retire - req.arrival_s
                       if req.arrival_s is not None else None)
            slo_violation = (req.deadline_s is not None
                             and t_retire > req.deadline_s)
            self._seq += 1
            sample = TelemetrySample(
                seq=self._seq, tenant=req.tenant,
                workload=pending.runner.wl.name,
                key=key, backend=self.backend_name,
                partitions=config.partitions,
                tasks=config.tasks, cache_hit=pending.cache_hit,
                predicted_s=predicted_s, measured_s=measured_s,
                rel_error=rel,
                status=("degraded" if pending.degraded_via is not None
                        else "ok"),
                degraded_via=pending.degraded_via,
                refined=refined, source=entry.source,
                inflight=pending.inflight, load_factor=load,
                measured_norm_s=measured_norm_s,
                t_enqueue_s=req.arrival_s, t_decide_s=pending.t_decide_s,
                t_dispatch_s=pending.t_dispatch_s, t_retire_s=t_retire,
                latency_s=latency, deadline_s=req.deadline_s,
                slo_violation=slo_violation,
                queue_depth=pending.queue_depth,
                trace_id=req.trace_id)
            self.telemetry.append(sample)

        self.stats["requests"] += 1
        self.stats["cache_hits" if pending.cache_hit else "cold_misses"] += 1
        self._m_requests.inc()
        ns = (ctx.namespace or "shared") if ctx is not None else "shared"
        self.metrics.counter(
            "serving.cache.hit" if pending.cache_hit
            else "serving.cache.miss", namespace=ns).inc()
        if slo_violation:
            self.stats["slo_violations"] += 1
            self._m_slo_violations.inc()
        self.stats[f"tenant.{req.tenant}.served"] += 1
        ctx.served += 1
        self._m_stage["retire"].observe(self.clock.now() - t_stage0)

        return RequestResult(
            request=req, config=config,
            outputs=outs if self.keep_outputs else [],
            measured_s=measured_s, predicted_s=predicted_s,
            cache_hit=pending.cache_hit, refined=refined, sample=sample,
            status=("degraded" if pending.degraded_via is not None
                    else "served"))

    def _refine(self, pending: PendingRequest, ctx: TenantContext,
                key: str, entry: TuneResult) -> None:
        """Run one drift-triggered refinement with the tenant's own
        (forked) model and recalibrate the runtime anchor from the
        refinement's measured single-stream run.  The serial scheduler
        refines inline; the engine overrides this to DEFER the
        re-profiling to its next pool-quiesce point, so refinement
        measurements — like all profiling — happen on an idle pool."""
        with self.tracer.span("refine", trace_id=pending.req.trace_id,
                              key=key):
            self.faults.fire("refine")
            refinement = self.refiner.refine(
                pending.runner, key, self._feats.get(key), entry,
                model=ctx.fork_for_refit())
        self._t_single[key] = refinement.t_single_s
        self.stats["refinements"] += 1
        self.stats[f"tenant.{pending.req.tenant}.refinements"] += 1
        ctx.refinements += 1
        self._m_refinements.inc()
        self._m_stage["refine"].observe(refinement.seconds)
        self.metrics.histogram(
            "serving.refit.seconds").observe(refinement.seconds)

    def _predicted_runtime(self, key: str,
                           entry: TuneResult) -> Optional[float]:
        t_single = self._t_single.get(key)
        if t_single is None or entry.predicted_speedup <= 0:
            return None
        return t_single / entry.predicted_speedup

    # -- model lifecycle ------------------------------------------------------

    def swap_model(self, model, model_tag: Optional[str] = None) -> None:
        """Hot-swap the serving base model (a registry ``refresh`` handed
        us a newly published artifact).  Future cold searches, batched
        searches, and refinements rank with the new model; tenants that
        already forked keep their fork (their measured corrections are
        newer than any offline retrain) until their next explicit reset.

        ``model_tag`` should name the new artifact id: tuning-cache keys
        embed it, so every bucket decided under the old model becomes a
        cold miss and is re-ranked by the new one instead of serving
        stale picks."""
        self.model = model
        self.refiner.model = model
        self.tenancy.hot_swap(model)
        if model_tag is not None:
            self.model_tag = model_tag

    # -- teardown -------------------------------------------------------------

    def close(self) -> None:
        """Deterministic teardown: flush + fsync + close the telemetry
        JSONL so a mid-trace shutdown never leaves a truncated last line
        for CI artifact uploads.  Idempotent; the engine extends this
        with its worker-pool shutdown."""
        if self.metrics.enabled:
            # fires-vs-suppressions: the suppression half only settles at
            # teardown (per-tenant detectors accumulate independently)
            suppressed = self.drift.suppressed + sum(
                ctx.drift.suppressed for ctx in self.tenancy
                if ctx.drift is not self.drift)
            self.metrics.gauge("serving.drift.suppressed").set(suppressed)
        self.telemetry.close()

    def __enter__(self) -> "AdaptiveScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def make_trace(workloads: Sequence[str], *, occurrences: int = 2,
               tenants=("tenant-a", "tenant-b"),
               scale_index: int = 0, seed: int = 0,
               priorities: Optional[Sequence[int]] = None
               ) -> list[WorkloadRequest]:
    """A deterministic mixed-workload request trace: ``occurrences``
    rounds over ``workloads``, data re-drawn per request (same shapes, so
    later rounds land in the same tuning bucket), tenants round-robin.
    ``tenants`` is a sequence of names, or an int N for
    ``tenant-0 .. tenant-{N-1}``."""
    if isinstance(tenants, int):
        tenants = tuple(f"tenant-{i}" for i in range(tenants))
    rng = np.random.default_rng(seed)
    reqs = []
    for round_idx in range(occurrences):
        for i, name in enumerate(workloads):
            wl = get_workload(name)
            scale = wl.datasets[min(scale_index, len(wl.datasets) - 1)]
            chunked, shared = wl.make_data(scale, rng)
            reqs.append(WorkloadRequest(
                workload=name, chunked=chunked, shared=shared,
                tenant=tenants[(round_idx * len(workloads) + i)
                               % len(tenants)],
                priority=(priorities[i % len(priorities)]
                          if priorities else 0)))
    return reqs
