"""Pallas TPU flash-attention kernel (causal, GQA) with explicit BlockSpec
VMEM tiling.

TPU adaptation of the paper's streaming idea at the kernel level: the grid
pipeline double-buffers HBM->VMEM DMA of K/V blocks against MXU compute on
the current block — the intra-chip analogue of the paper's host-device
transfer/compute overlap (DESIGN.md §2).

Grid: (batch*kv_head, q_blocks, kv_blocks); kv is the innermost
(fastest-moving) axis so the online-softmax accumulators live in VMEM
scratch across kv steps of one (bh, q_block) tile.  Causal skipping is
predicated with pl.when so fully-masked kv blocks do no MXU work.

Validated on CPU via interpret=True against repro.kernels.ref (pure jnp).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, q_block: int, kv_block: int, causal: bool,
                  group: int, seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Row r of the tile is query position qi*q_block + r//group.
    q_pos = qi * q_block + jax.lax.broadcasted_iota(
        jnp.int32, (q_block * group, 1), 0) // group
    k_pos = ki * kv_block + jax.lax.broadcasted_iota(
        jnp.int32, (1, kv_block), 1)

    if causal:
        run = (ki * kv_block) <= (qi * q_block + q_block - 1)
    else:
        run = True

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)       # (q_block*group, head_dim)
        k = k_ref[0, 0].astype(jnp.float32)       # (kv_block, head_dim)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        mask = k_pos < seq_len
        if causal:
            mask = mask & (q_pos >= k_pos)
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, KV, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """GQA flash attention. The G query heads of one KV group are folded
    into the q-block rows so each MXU tile is (q_block*G, head_dim) and K/V
    blocks are fetched once per group rather than once per query head."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    assert H % KV == 0
    group = H // KV
    scale = 1.0 / (hd ** 0.5)

    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    assert Sq % q_block == 0 and Sk % kv_block == 0, (
        "pad sequences to block multiples before calling")
    nq, nk = Sq // q_block, Sk // kv_block

    # (B, Sq, H, hd) -> (B*KV, nq, q_block*G, hd): one grid row per
    # (batch, kv head); the group's query heads ride along in the row dim.
    qg = (q.reshape(B, nq, q_block, KV, group, hd)
          .transpose(0, 3, 1, 2, 4, 5)
          .reshape(B * KV, nq, q_block * group, hd))
    kg = (k.reshape(B, nk, kv_block, KV, hd)
          .transpose(0, 3, 1, 2, 4)
          .reshape(B * KV, nk, kv_block, hd))
    vg = (v.reshape(B, nk, kv_block, KV, hd)
          .transpose(0, 3, 1, 2, 4)
          .reshape(B * KV, nk, kv_block, hd))

    grid = (B * KV, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, q_block=q_block, kv_block=kv_block,
        causal=causal, group=group, seq_len=Sk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_block * group, hd),
                         lambda b, qi, ki: (b, qi, 0, 0)),
            pl.BlockSpec((1, 1, kv_block, hd),
                         lambda b, qi, ki: (b, ki, 0, 0)),
            pl.BlockSpec((1, 1, kv_block, hd),
                         lambda b, qi, ki: (b, ki, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block * group, hd),
                               lambda b, qi, ki: (b, qi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (B * KV, nq, q_block * group, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block * group, hd), jnp.float32),   # acc
            pltpu.VMEM((q_block * group, 1), jnp.float32),    # m
            pltpu.VMEM((q_block * group, 1), jnp.float32),    # l
        ],
        interpret=interpret,
    )(qg, kg, vg)

    # (B*KV, nq, q_block*G, hd) -> (B, Sq, H, hd)
    o = (out.reshape(B, KV, nq, q_block, group, hd)
         .transpose(0, 2, 3, 1, 4, 5)
         .reshape(B, Sq, H, hd))
    return o
