"""Versioned model artifacts: the train-at-the-factory / predict-in-
production split's on-disk contract.

An artifact is a directory holding

  ``manifest.json``   kind, format version, feature-schema hash,
                      training-corpus fingerprint, leave-one-program-out
                      CV score, optional tag/tenant provenance, plus the
                      estimator's JSON-safe extras;
  ``weights.npz``     every numpy/JAX array of the estimator (feature
                      pipeline + learner parameters), bit-exact.

Loading refuses a manifest whose feature-schema hash does not match the
running code's (:class:`SchemaMismatchError`): a model trained against a
different feature vector would silently mis-rank every config — the one
failure mode a serving fleet cannot detect from telemetry alone, because
the predictions stay plausible.
"""
from __future__ import annotations

import hashlib
import json
import time
from pathlib import Path
from typing import Optional, Sequence

import numpy as np

from repro.core.features import N_CONFIG_FEATURES, RAW_FEATURE_NAMES
from repro.core.modeling.base import get_estimator_kind

FORMAT_VERSION = 1
MANIFEST_NAME = "manifest.json"
WEIGHTS_NAME = "weights.npz"


class SchemaMismatchError(RuntimeError):
    """The artifact was trained against a different feature schema than
    the running code extracts — refusing to serve from it."""


def feature_schema_hash() -> str:
    """Hash of the feature vector the running code produces: the raw
    feature names (order included) ++ the config-encoding width.  Any
    change to either invalidates every existing artifact."""
    payload = json.dumps({"raw_features": RAW_FEATURE_NAMES,
                          "n_config_features": N_CONFIG_FEATURES},
                         separators=(",", ":"))
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def corpus_fingerprint(samples: Sequence) -> str:
    """Order-independent digest of a profiled training corpus: which
    (program, dataset) cells it covers and how densely each was swept.
    Stamped into the manifest (and used as the CI profile-cache key
    material) so 'same corpus' is checkable without re-profiling."""
    h = hashlib.sha256()
    for s in sorted(samples, key=lambda s: (s.program, s.scale)):
        cfgs = ",".join(f"{p}x{t}" for p, t in sorted(s.times))
        h.update(f"{s.program}@{s.scale}:[{cfgs}];".encode())
    return h.hexdigest()[:16]


def save_artifact(model, path: "str | Path", *,
                  corpus: str = "",
                  cv: Optional[dict] = None,
                  tag: str = "",
                  tenant: str = "",
                  extra_meta: Optional[dict] = None) -> Path:
    """Write ``model`` as a versioned artifact directory at ``path``."""
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    arrays, extras = model.to_state()
    manifest = {
        "format_version": FORMAT_VERSION,
        "kind": model.kind,
        "feature_schema_hash": feature_schema_hash(),
        "corpus_fingerprint": corpus,
        "cv": cv,
        "tag": tag,
        "tenant": tenant,
        "created_unix": time.time(),
        "extras": extras,
    }
    if extra_meta:
        # namespaced: free-form metadata must not clobber the reserved
        # keys (kind, feature_schema_hash, ...) the loader dispatches on
        manifest["extra"] = dict(extra_meta)
    np.savez(path / WEIGHTS_NAME, **arrays)
    tmp = path / (MANIFEST_NAME + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    tmp.replace(path / MANIFEST_NAME)
    return path


def read_manifest(path: "str | Path") -> dict:
    with open(Path(path) / MANIFEST_NAME) as f:
        return json.load(f)


def is_artifact_dir(path: "str | Path") -> bool:
    return (Path(path) / MANIFEST_NAME).exists()


def load_artifact(path: "str | Path", *,
                  allow_schema_mismatch: bool = False):
    """Load ``(model, manifest)`` from an artifact directory.

    Raises :class:`SchemaMismatchError` when the artifact's feature
    schema hash differs from the running code's (override only for
    forensics — a mismatched model mis-ranks every config)."""
    path = Path(path)
    manifest = read_manifest(path)
    version = int(manifest.get("format_version", -1))
    if version > FORMAT_VERSION:
        raise RuntimeError(
            f"artifact {path} has format_version {version}, newer than "
            f"this code's {FORMAT_VERSION} — upgrade before loading")
    want = feature_schema_hash()
    got = manifest.get("feature_schema_hash")
    if got != want and not allow_schema_mismatch:
        raise SchemaMismatchError(
            f"artifact {path} was trained against feature schema {got}, "
            f"but the running code extracts schema {want}; retrain (or "
            f"pass allow_schema_mismatch=True for forensics)")
    cls = get_estimator_kind(manifest["kind"])
    with np.load(path / WEIGHTS_NAME) as npz:
        arrays = {k: npz[k] for k in npz.files}
    model = cls.from_state(arrays, manifest.get("extras", {}))
    return model, manifest
