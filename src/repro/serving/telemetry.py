"""Serving telemetry: one append-only JSONL record per dispatched request.

Each :class:`TelemetrySample` captures the serving decision and its
outcome — which config was chosen, where it came from (model search,
cache hit, or drift refinement), what runtime the model predicted, and
what was actually measured.  The relative prediction error
``|measured - predicted| / predicted`` is the drift-detection signal
(:mod:`repro.serving.refinement`) and the refit target provider.

The log is line-buffered JSONL: every ``append`` writes and flushes one
line, so a crashed serving process loses at most the in-flight request —
the same durability contract as the tuning cache's atomic save, but for
a stream instead of a snapshot.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import IO, Iterator, Optional


@dataclasses.dataclass
class TelemetrySample:
    seq: int                      # scheduler-assigned dispatch sequence
    tenant: str
    workload: str
    key: str                      # tuning-cache key (workload bucket id)
    backend: str
    partitions: int
    tasks: int
    cache_hit: bool
    predicted_s: Optional[float]  # model-predicted runtime (None if unknown)
    #: measured execution seconds; None for a request that never
    #: executed (status "failed"/"timeout" under a resilience policy)
    measured_s: Optional[float]
    rel_error: Optional[float]    # |measured - predicted| / predicted
    refined: bool = False         # this request triggered a refinement
    source: str = "model"         # config provenance: model | refined
    # -- resilience disposition (PR 8) -------------------------------------
    #: "ok" | "degraded" (served via a fallback rung) | "failed" |
    #: "timeout" — failed/timeout samples are the *error telemetry*: the
    #: request is terminal and accounted for, the scheduler survived
    status: str = "ok"
    #: "TypeName: message" for failed/timeout samples
    error: Optional[str] = None
    #: first fallback rung taken when status == "degraded"
    #: (heuristic-model | nearest-bucket | single-stream | backend)
    degraded_via: Optional[str] = None
    # -- load-aware drift fields (concurrent engine) ----------------------
    #: window occupancy when this request was dispatched (itself included);
    #: 1 under the serial scheduler
    inflight: int = 1
    #: contention factor measured_s was divided by before computing the
    #: drift signal: max(1, min(inflight, workers) / host parallel
    #: capacity); 1.0 when serving serially or load-awareness is off
    load_factor: float = 1.0
    #: measured_s / load_factor — the contention-normalized runtime that
    #: rel_error (and therefore drift detection) is computed from
    measured_norm_s: Optional[float] = None
    # -- latency accounting (the virtual-clock layer) ----------------------
    # All four stamps share the scheduler's clock (``SystemClock`` in
    # production, ``VirtualClock`` under the trace harness / tests):
    #: queue arrival (``WorkloadRequest.arrival_s``, stamped at submit)
    t_enqueue_s: Optional[float] = None
    #: placement decision made (cache lookup / model search done)
    t_decide_s: Optional[float] = None
    #: execution handed to the backend (pool submit / serial dispatch)
    t_dispatch_s: Optional[float] = None
    #: result retired (telemetry + drift observed)
    t_retire_s: Optional[float] = None
    #: t_retire_s - t_enqueue_s: the end-to-end latency the SLO is on
    latency_s: Optional[float] = None
    #: absolute SLO deadline carried by the request (None = no SLO)
    deadline_s: Optional[float] = None
    #: retired after its deadline (shed requests never get a sample —
    #: they are counted on the queue, not here)
    slo_violation: bool = False
    #: queue length observed at decision time
    queue_depth: int = 0
    #: queue-assigned request trace id — the span-tracing correlation
    #: key (repro.serving.observability); stable across out-of-order
    #: retirement in the concurrent engine
    trace_id: Optional[str] = None
    # -- fleet serving (multi-process router/worker split) -----------------
    #: worker-process label ("w0", "w1", ...) under the fleet router;
    #: None for single-process serving.  ``from_json`` filters unknown
    #: keys, so pre-fleet JSONL streams load unchanged
    worker: Optional[str] = None

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "TelemetrySample":
        fields = {f.name for f in dataclasses.fields(TelemetrySample)}
        return TelemetrySample(**{k: v for k, v in d.items() if k in fields})

    # -- compact positional codec (the fleet wire's slim payload) ----------

    def to_row(self) -> tuple:
        """Positional encoding in :data:`WIRE_FIELDS` order — the fleet
        wire's slim payload: no key strings cross the process boundary,
        only values.  The schema is the explicit field tuple below plus
        ``repro.serving.fleet.wire.WIRE_VERSION``."""
        return tuple(getattr(self, f) for f in WIRE_FIELDS)

    @staticmethod
    def from_row(row) -> "TelemetrySample":
        """Inverse of :meth:`to_row`.  A shorter row (an older writer
        that predates trailing fields) rehydrates with dataclass
        defaults for the missing tail — WIRE_FIELDS is append-only."""
        return TelemetrySample(**dict(zip(WIRE_FIELDS, row)))


#: Explicit positional schema of :meth:`TelemetrySample.to_row`.
#: APPEND-ONLY: new dataclass fields go at the END of this tuple and
#: bump ``repro.serving.fleet.wire.WIRE_VERSION`` — reordering or
#: removing entries breaks row decoding silently, which is exactly what
#: the wire version guard exists to prevent.  A tier-1 test asserts this
#: tuple stays in sync with the dataclass fields.
WIRE_FIELDS = (
    "seq", "tenant", "workload", "key", "backend", "partitions", "tasks",
    "cache_hit", "predicted_s", "measured_s", "rel_error", "refined",
    "source", "status", "error", "degraded_via", "inflight", "load_factor",
    "measured_norm_s", "t_enqueue_s", "t_decide_s", "t_dispatch_s",
    "t_retire_s", "latency_s", "deadline_s", "slo_violation", "queue_depth",
    "trace_id", "worker",
)


class EmptyWindowError(ValueError):
    """A statistic was requested over zero samples.

    The one typed signal for "there is nothing to aggregate":
    :func:`percentile` raises it on an empty window; the higher-level
    aggregators (:func:`latency_stats`, :meth:`TelemetryLog.summary`)
    catch the condition and return ``None``-shaped results instead, so
    a trace where admission control shed *every* request still renders
    a summary rather than blowing up the report path."""


def percentile(sorted_values, q: float) -> float:
    """Linear-interpolated percentile of an ascending-sorted sequence
    (``q`` in [0, 1]).  The one primitive the latency reports need —
    avoids dragging numpy into the telemetry hot path.  Raises
    :class:`EmptyWindowError` on an empty window (callers that can see
    empty windows should use :func:`latency_stats`, which maps the
    condition to ``None``)."""
    if not sorted_values:
        raise EmptyWindowError(
            "percentile over an empty window: no samples to aggregate "
            "(did a queue policy shed every request?)")
    if len(sorted_values) == 1:
        return float(sorted_values[0])
    pos = q * (len(sorted_values) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(sorted_values) - 1)
    frac = pos - lo
    return float(sorted_values[lo] * (1 - frac) + sorted_values[hi] * frac)


def latency_stats(latencies) -> Optional[dict]:
    """p50/p95/p99 + mean/max over a sequence of latency seconds;
    ``None`` when the sequence is empty (e.g. a trace where nothing
    retired) — the consistent empty-window contract: aggregators return
    ``None``, only the raw :func:`percentile` primitive raises."""
    lats = sorted(latencies)
    if not lats:
        return None
    return {
        "p50_s": percentile(lats, 0.50),
        "p95_s": percentile(lats, 0.95),
        "p99_s": percentile(lats, 0.99),
        "mean_s": sum(lats) / len(lats),
        "max_s": lats[-1],
        "n": len(lats),
    }


def relative_error(measured_s: float,
                   predicted_s: Optional[float]) -> Optional[float]:
    if predicted_s is None or predicted_s <= 0:
        return None
    return abs(measured_s - predicted_s) / predicted_s


class TelemetryLog:
    """In-memory sample list, mirrored to an append-only JSONL file.

    Usable as a context manager; ``close()`` flushes AND fsyncs before
    closing, and is idempotent.  A serving process torn down mid-trace
    (CI job timeout, SIGTERM between requests) must never leave a
    truncated last line for the artifact upload to capture — ``append``
    already flushes per line, but only fsync pushes the page cache to
    disk before the process dies."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.samples: list[TelemetrySample] = []
        self._fh: Optional[IO[str]] = None

    def append(self, sample: TelemetrySample) -> None:
        self.samples.append(sample)
        if self.path is not None:
            if self._fh is None:
                os.makedirs(os.path.dirname(os.path.abspath(self.path)),
                            exist_ok=True)
                self._fh = open(self.path, "a")
            self._fh.write(json.dumps(sample.to_json(),
                                      separators=(",", ":")) + "\n")
            self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            try:
                self._fh.flush()
                os.fsync(self._fh.fileno())
            except (OSError, ValueError):
                pass                  # already closed / non-seekable sink
            self._fh.close()
            self._fh = None

    @property
    def closed(self) -> bool:
        return self._fh is None

    def __enter__(self) -> "TelemetryLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __len__(self) -> int:
        return len(self.samples)

    def __iter__(self) -> Iterator[TelemetrySample]:
        return iter(self.samples)

    @staticmethod
    def read(path: str) -> list[TelemetrySample]:
        out = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    out.append(TelemetrySample.from_json(json.loads(line)))
        return out

    def summary(self) -> dict:
        """Aggregate view for dashboards / the --serve benchmark JSON.

        Total on an empty log (e.g. a deadline policy shed the entire
        trace, so nothing ever retired): every ratio/stat field comes
        back ``None`` or zero rather than raising — asserted by the
        observability tests."""
        n = len(self.samples)
        hits = sum(s.cache_hit for s in self.samples)
        errs = [s.rel_error for s in self.samples if s.rel_error is not None]
        per_workload: dict[str, list[float]] = {}
        for s in self.samples:
            if s.rel_error is not None:
                per_workload.setdefault(s.workload, []).append(s.rel_error)
        per_tenant: dict[str, dict] = {}
        for s in self.samples:
            t = per_tenant.setdefault(
                s.tenant, {"requests": 0, "cache_hits": 0,
                           "refinements": 0, "errors": []})
            t["requests"] += 1
            t["cache_hits"] += bool(s.cache_hit)
            t["refinements"] += bool(s.refined)
            if s.rel_error is not None:
                t["errors"].append(s.rel_error)
        lats = [s.latency_s for s in self.samples if s.latency_s is not None]
        with_deadline = [s for s in self.samples if s.deadline_s is not None]
        violations = sum(s.slo_violation for s in with_deadline)
        by_status: dict[str, int] = {}
        for s in self.samples:
            by_status[s.status] = by_status.get(s.status, 0) + 1
        return {
            "requests": n,
            "cache_hits": hits,
            "hit_rate": hits / n if n else 0.0,
            "refinements": sum(s.refined for s in self.samples),
            # failed/timeout samples carry measured_s=None — a window
            # where EVERY request errored must still summarize, so the
            # aggregate skips them rather than TypeError-ing
            "total_measured_s": sum(s.measured_s for s in self.samples
                                    if s.measured_s is not None),
            "by_status": by_status,
            "latency": latency_stats(lats),
            "slo_violations": violations,
            "slo_violation_rate": (violations / len(with_deadline)
                                   if with_deadline else None),
            "mean_rel_error": (sum(errs) / len(errs)) if errs else None,
            "mean_rel_error_by_workload": {
                w: sum(v) / len(v) for w, v in sorted(per_workload.items())},
            "per_tenant": {
                name: {"requests": t["requests"],
                       "cache_hits": t["cache_hits"],
                       "refinements": t["refinements"],
                       "mean_rel_error": (sum(t["errors"]) / len(t["errors"])
                                          if t["errors"] else None)}
                for name, t in sorted(per_tenant.items())},
        }
