"""Batched serving driver: prefill + decode loop with a KV/state cache.

Requests are batched (continuous-batching-lite: fixed batch slots, each
slot holds one sequence; finished slots are refilled from the queue), the
cache is pre-allocated at max_seq, and the decode step is the same
``serve_step`` the dry-run lowers at pod scale.

CPU-sized by default (reduced configs).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch, list_archs
from repro.models.model_zoo import Model
from repro.models.transformer import RunConfig


@dataclasses.dataclass
class ServeResult:
    n_requests: int
    tokens_generated: int
    wall_s: float
    tokens_per_s: float
    outputs: list


def serve(
    arch: str,
    *,
    n_requests: int = 8,
    batch_slots: int = 4,
    prompt_len: int = 16,
    gen_len: int = 16,
    reduced: bool = True,
    seed: int = 0,
    greedy: bool = True,
    verbose: bool = True,
) -> ServeResult:
    model = Model(
        get_arch(arch).reduced() if reduced else get_arch(arch),
        RunConfig())
    cfg = model.cfg
    params, _ = model.init(jax.random.key(seed))
    max_seq = prompt_len + gen_len

    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (n_requests, prompt_len)).astype(np.int32)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    def make_batch(tokens):
        b = {"tokens": jnp.asarray(tokens)}
        if cfg.frontend:
            b["embeds"] = jnp.zeros(
                (tokens.shape[0], tokens.shape[1], cfg.frontend_dim),
                jnp.float32)
        return b

    outputs = []
    t0 = time.perf_counter()
    total_tokens = 0
    for start in range(0, n_requests, batch_slots):
        chunk = prompts[start:start + batch_slots]
        B = chunk.shape[0]
        logits, cache = prefill(params, make_batch(chunk))
        # grow cache to max_seq (attention k/v only)
        def grow(path_leaf):
            return path_leaf
        grown = {}
        for key, val in cache.items():
            if isinstance(val, dict) and "k" in val:
                grown[key] = {
                    kk: jnp.pad(vv, ((0, 0), (0, 0),
                                     (0, max_seq - prompt_len),
                                     (0, 0), (0, 0)))
                    for kk, vv in val.items()}
            else:
                grown[key] = val
        cache = grown
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        gen = [toks]
        for i in range(gen_len - 1):
            t = jnp.int32(prompt_len + i)
            logits, cache = decode(params, make_batch(toks[:, None]),
                                   cache, t)
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            gen.append(toks)
        seqs = np.stack([np.asarray(g) for g in gen], axis=1)
        outputs.extend(list(seqs))
        total_tokens += B * gen_len
        if verbose:
            print(f"batch {start//batch_slots}: {B} requests, "
                  f"{B * gen_len} tokens")
    wall = time.perf_counter() - t0
    return ServeResult(
        n_requests=n_requests, tokens_generated=total_tokens, wall_s=wall,
        tokens_per_s=total_tokens / wall, outputs=outputs)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    res = serve(args.arch, n_requests=args.requests, batch_slots=args.slots,
                prompt_len=args.prompt_len, gen_len=args.gen_len)
    print(f"{res.tokens_generated} tokens in {res.wall_s:.2f}s "
          f"({res.tokens_per_s:.1f} tok/s)")


if __name__ == "__main__":
    main()
