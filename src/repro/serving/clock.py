"""Time sources for the serving stack.

Every latency-bearing decision in serving — enqueue/decide/dispatch/
retire stamps on telemetry, deadline expiry in the request queue — reads
time through one of these two clocks instead of calling
``time.perf_counter`` directly:

  :class:`SystemClock`   the production source, a thin wrapper over
      ``time.perf_counter`` (monotonic, sub-microsecond on Linux);
  :class:`VirtualClock`  a manually advanced clock for the trace
      harness (:mod:`repro.serving.traces`) and for tests.  A
      million-request trace replays in seconds of real time while the
      latency accounting sees realistic virtual seconds, and timing
      assertions in tests become exact instead of wall-clock-flaky.

Both expose a single method, ``now() -> float`` (seconds, arbitrary
epoch); anything accepting a clock should type against that duck.
"""
from __future__ import annotations

import time


class SystemClock:
    """Real time: ``now()`` is ``time.perf_counter()``."""

    def now(self) -> float:
        return time.perf_counter()


class VirtualClock:
    """Deterministic simulated time, advanced explicitly by its owner.

    ``advance`` moves forward by a delta; ``advance_to`` jumps to an
    absolute timestamp and is monotone (a target in the past is a no-op,
    so interleaved event sources can never run time backwards).
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance a clock by {dt!r}")
        self._now += dt
        return self._now

    def advance_to(self, t: float) -> float:
        self._now = max(self._now, float(t))
        return self._now
