"""Multi-tenant workload request queue.

A :class:`WorkloadRequest` is one unit of serving work: a named streamed
workload plus its host data, tagged with the submitting tenant, a
priority, and optionally an SLO deadline.  :class:`RequestQueue` orders
them under one of four policies:

  ``fifo``     — global arrival order;
  ``priority`` — higher ``priority`` first, arrival order within a level
                 (stable: equal-priority requests never reorder);
  ``fair``     — round-robin across tenants, arrival order within a
                 tenant, so one chatty tenant cannot starve the rest;
  ``deadline`` — earliest-deadline-first admission control: requests
                 nearest their deadline are boosted to the front (ties
                 broken by priority, then arrival), deadline-less
                 requests run last, and work whose deadline has already
                 expired by the time it is popped is *shed* — dropped
                 and counted on :attr:`RequestQueue.shed` — instead of
                 burning capacity on a guaranteed SLO miss.

All four are deterministic given the submission sequence and (for
``deadline``) the clock — the property the scheduler tests rely on.
Deadline expiry is judged against an injectable clock
(:mod:`repro.serving.clock`), so the trace harness sheds in virtual time
and tests never race the wall clock.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import itertools
import math
from typing import Optional

from repro.serving.clock import SystemClock

POLICIES = ("fifo", "priority", "fair", "deadline")


@dataclasses.dataclass
class WorkloadRequest:
    """One serving request: run ``workload`` over this request's data."""

    workload: str
    chunked: dict
    shared: dict
    tenant: str = "default"
    priority: int = 0
    #: arrival sequence number, assigned at enqueue time
    seq: int = -1
    #: arrival timestamp (scheduler clock), stamped at submit when unset
    arrival_s: Optional[float] = None
    #: absolute SLO deadline (same clock); None = no deadline
    deadline_s: Optional[float] = None
    #: request trace id, assigned at enqueue (derived from ``seq``, so
    #: it is deterministic per submission order); every span and
    #: telemetry sample for this request carries it
    trace_id: Optional[str] = None


class RequestQueue:
    def __init__(self, policy: str = "fifo", clock=None, metrics=None):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        self.policy = policy
        self.clock = clock if clock is not None else SystemClock()
        self._seq = itertools.count()
        self._fifo: collections.deque = collections.deque()
        self._heap: list = []
        self._per_tenant: dict[str, collections.deque] = {}
        self._rr: collections.deque = collections.deque()  # tenant rotation
        #: requests dropped by deadline admission control, in shed order
        self.shed: list[WorkloadRequest] = []
        # observability: shed counter + depth gauge, no-ops by default
        if metrics is None:
            from repro.serving.observability import NULL_METRICS
            metrics = NULL_METRICS
        self._m_shed = metrics.counter("serving.queue.shed")
        self._m_depth = metrics.gauge("serving.queue.depth")

    def push(self, req: WorkloadRequest) -> WorkloadRequest:
        req.seq = next(self._seq)
        if req.trace_id is None:
            # deterministic per submission order; survives any policy's
            # reordering and the engine's out-of-order retirement
            req.trace_id = f"r{req.seq:06d}"
        self._m_depth.set(len(self) + 1)
        if self.policy == "fifo":
            self._fifo.append(req)
        elif self.policy == "priority":
            heapq.heappush(self._heap, (-req.priority, req.seq, req))
        elif self.policy == "deadline":
            # EDF: the nearest deadline is served first (the "boost" —
            # near-deadline work overtakes everything slack), priority
            # breaks deadline ties, deadline-less requests sort last
            dl = req.deadline_s if req.deadline_s is not None else math.inf
            heapq.heappush(self._heap, (dl, -req.priority, req.seq, req))
        else:  # fair
            if req.tenant not in self._per_tenant:
                self._per_tenant[req.tenant] = collections.deque()
                self._rr.append(req.tenant)
            self._per_tenant[req.tenant].append(req)
        return req

    def pop(self) -> WorkloadRequest:
        """Next request in policy order.

        Under ``deadline`` this sheds every already-expired request it
        uncovers (recorded on :attr:`shed`) before returning a live one —
        so a non-empty queue can still raise ``IndexError`` when
        everything left in it is expired.  Callers draining a deadline
        queue must treat ``IndexError`` as "drained", not as a bug (the
        schedulers do).
        """
        if not len(self):
            raise IndexError("pop from an empty RequestQueue")
        if self.policy == "fifo":
            return self._fifo.popleft()
        if self.policy == "priority":
            return heapq.heappop(self._heap)[2]
        if self.policy == "deadline":
            now = self.clock.now()
            while self._heap:
                req = heapq.heappop(self._heap)[3]
                if req.deadline_s is not None and req.deadline_s < now:
                    self.shed.append(req)     # expired: shed, don't serve
                    self._m_shed.inc()
                    continue
                return req
            raise IndexError("every queued request was past its deadline")
        tenant = self._rr.popleft()
        req = self._per_tenant[tenant].popleft()
        if self._per_tenant[tenant]:
            self._rr.append(tenant)       # rotate: next tenant goes first
        else:
            del self._per_tenant[tenant]
        return req

    def peek_tenants(self) -> list[str]:
        """Tenants with queued work, in service order (fair policy)."""
        return list(self._rr)

    def pending_by_tenant(self) -> dict[str, int]:
        """Queued-request count per tenant, any policy — the serving
        dashboards' fairness view.  Under ``fair`` this is exactly the
        per-tenant backlog the round-robin rotation drains one-at-a-time:
        in any stretch where every tenant stays non-empty, each tenant is
        served exactly once per rotation (asserted in the tenancy
        tests).  Under ``deadline``, expired-but-not-yet-shed requests
        still count — they are only classified at pop time."""
        if self.policy == "fair":
            return {t: len(d) for t, d in self._per_tenant.items()}
        counts: dict[str, int] = {}
        items = (self._fifo if self.policy == "fifo"
                 else (entry[-1] for entry in self._heap))
        for req in items:
            counts[req.tenant] = counts.get(req.tenant, 0) + 1
        return counts

    def __len__(self) -> int:
        if self.policy == "fifo":
            return len(self._fifo)
        if self.policy in ("priority", "deadline"):
            return len(self._heap)
        return sum(len(d) for d in self._per_tenant.values())

    def __bool__(self) -> bool:
        return len(self) > 0
