"""Workload suite: all 39 programs run, and chunk-split invariance holds
for the row-independent ones (the property streaming relies on)."""
import jax
import numpy as np
import pytest

from repro.core.workloads import _REGISTRY, get_workload, list_workloads


def test_suite_has_39_programs():
    assert len(list_workloads()) == 39
    suites = {w.suite for w in _REGISTRY.values()}
    assert suites == {"nvidia", "amd", "parboil", "polybench"}


def test_each_program_has_enough_datasets():
    for name in list_workloads():
        assert len(get_workload(name).datasets) >= 8, name


@pytest.mark.parametrize("name", list_workloads())
def test_kernel_runs_and_finite(name):
    wl = get_workload(name)
    rng = np.random.default_rng(0)
    chunked, shared = wl.make_data(wl.datasets[0], rng)
    out = jax.jit(wl.kernel)(chunked, shared)
    for leaf in jax.tree.leaves(out):
        assert np.isfinite(np.asarray(leaf)).all(), name


@pytest.mark.parametrize("name", [n for n in list_workloads()
                                  if get_workload(n).combine == "concat"])
def test_chunk_invariance(name):
    """kernel(rows) == concat(kernel(row chunks)) for row-independent
    programs — the correctness contract of the streamed executor."""
    wl = get_workload(name)
    rng = np.random.default_rng(1)
    chunked, shared = wl.make_data(wl.datasets[0], rng)
    full = np.asarray(jax.jit(wl.kernel)(chunked, shared))
    n = next(iter(chunked.values())).shape[0]
    half = n // 2
    a = {k: v[:half] for k, v in chunked.items()}
    b = {k: v[half:] for k, v in chunked.items()}
    parts = np.concatenate([
        np.asarray(jax.jit(wl.kernel)(a, shared)),
        np.asarray(jax.jit(wl.kernel)(b, shared))], axis=0)
    # gemm reduction order differs across chunk shapes in XLA; 3mm chains
    # two 256-dim contractions so values reach ~1e3-1e4
    np.testing.assert_allclose(parts, full, rtol=1e-3, atol=0.1)


@pytest.mark.parametrize("name", [n for n in list_workloads()
                                  if get_workload(n).combine == "sum"])
def test_sum_partials(name):
    wl = get_workload(name)
    rng = np.random.default_rng(2)
    chunked, shared = wl.make_data(wl.datasets[0], rng)
    full = np.asarray(jax.jit(wl.kernel)(chunked, shared))
    n = next(iter(chunked.values())).shape[0]
    half = n // 2
    a = {k: v[:half] for k, v in chunked.items()}
    b = {k: v[half:] for k, v in chunked.items()}
    parts = (np.asarray(jax.jit(wl.kernel)(a, shared))
             + np.asarray(jax.jit(wl.kernel)(b, shared)))
    np.testing.assert_allclose(parts, full, rtol=1e-3)
