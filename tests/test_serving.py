"""Adaptive serving subsystem: queue ordering, cache-hit vs cold-miss
dispatch, telemetry JSONL round-trip, drift-triggered refinement, and the
end-to-end acceptance trace (outputs allclose to the host-sync reference,
warm second occurrences, one refinement on injected misprediction)."""
import dataclasses

import numpy as np
import pytest

from repro.core.autotuner import TuningCache
from repro.core.perf_model import PerformanceModel
from repro.core.stream_config import SINGLE_STREAM, StreamConfig
from repro.core.streams import StreamedRunner
from repro.core.workloads import get_workload
from repro.serving import (AdaptiveScheduler, DriftDetector,
                           OverlapHeuristicModel, Refiner, RequestQueue,
                           TelemetryLog, TelemetrySample, WorkloadRequest,
                           make_trace, relative_error)


class _CalibratedStub:
    """Predicts speedup 1.0 for every config (so the stable-sorted search
    picks single-stream and predicted runtime == profiled single-stream
    time — tightly calibrated, which keeps natural drift near zero)."""

    def predict_configs(self, feats, candidates):
        return np.ones(len(candidates))


class _RecordingRefitStub(_CalibratedStub):
    def __init__(self):
        self.refit_calls = []

    def refit(self, X, y, **kw):
        self.refit_calls.append((np.atleast_2d(X).shape[0], kw))
        return 0.0


class _PinnedTimeScheduler(AdaptiveScheduler):
    """Structural deflake for the e2e drift tests: kernels really run
    (numerical-equivalence assertions stay honest), but the ``measured_s``
    fed to telemetry and drift detection is pinned to the bucket's
    profiled single-stream anchor.  Prediction error — and therefore the
    poison → refine → recover sequence — becomes a pure function of
    cache state instead of wall-clock noise on a loaded CI box, which is
    exactly what flaked the old threshold-bumping approach (3.0 → 6.0 in
    PR 3, regressed anyway)."""

    def _execute(self, pending):
        outs, measured = super()._execute(pending)
        return outs, self._t_single.get(pending.key, measured)


def _req(workload="vecadd", rows=256, seed=0, **kw):
    wl = get_workload(workload)
    chunked, shared = wl.make_data(rows, np.random.default_rng(seed))
    return WorkloadRequest(workload=workload, chunked=chunked,
                          shared=shared, **kw)


# -- queue -------------------------------------------------------------------


def test_fifo_queue_preserves_arrival_order():
    q = RequestQueue("fifo")
    for i in range(5):
        q.push(_req(tenant=f"t{i}"))
    assert [q.pop().tenant for _ in range(5)] == [f"t{i}" for i in range(5)]
    assert not q
    with pytest.raises(IndexError):
        q.pop()


def test_priority_queue_orders_by_priority_then_arrival():
    q = RequestQueue("priority")
    q.push(_req(tenant="low-1", priority=0))
    q.push(_req(tenant="high", priority=5))
    q.push(_req(tenant="low-2", priority=0))
    q.push(_req(tenant="mid", priority=2))
    order = [q.pop().tenant for _ in range(4)]
    assert order == ["high", "mid", "low-1", "low-2"]


def test_fair_queue_round_robins_tenants():
    q = RequestQueue("fair")
    for i in range(3):
        q.push(_req(tenant="chatty", seed=i))
    q.push(_req(tenant="quiet"))
    order = [q.pop().tenant for _ in range(4)]
    # quiet is served second despite arriving fourth
    assert order == ["chatty", "quiet", "chatty", "chatty"]


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown policy"):
        RequestQueue("lifo")


# -- telemetry ---------------------------------------------------------------


def test_telemetry_jsonl_roundtrip(tmp_path):
    path = str(tmp_path / "telemetry.jsonl")
    log = TelemetryLog(path)
    samples = [
        TelemetrySample(seq=1, tenant="a", workload="vecadd", key="k1",
                        backend="host-sync", partitions=1, tasks=4,
                        cache_hit=False, predicted_s=1e-3, measured_s=2e-3,
                        rel_error=1.0),
        TelemetrySample(seq=2, tenant="b", workload="sgemm", key="k2",
                        backend="host-sync", partitions=2, tasks=2,
                        cache_hit=True, predicted_s=None, measured_s=5e-4,
                        rel_error=None, refined=True, source="refined"),
    ]
    for s in samples:
        log.append(s)
    log.close()
    assert TelemetryLog.read(path) == samples
    # append-only: a second log object extends, not truncates
    log2 = TelemetryLog(path)
    log2.append(dataclasses.replace(samples[0], seq=3))
    log2.close()
    assert [s.seq for s in TelemetryLog.read(path)] == [1, 2, 3]


def test_telemetry_summary():
    log = TelemetryLog()
    log.append(TelemetrySample(seq=1, tenant="a", workload="w", key="k",
                               backend="b", partitions=1, tasks=1,
                               cache_hit=False, predicted_s=1.0,
                               measured_s=2.0, rel_error=1.0))
    log.append(TelemetrySample(seq=2, tenant="a", workload="w", key="k",
                               backend="b", partitions=1, tasks=1,
                               cache_hit=True, predicted_s=2.0,
                               measured_s=2.0, rel_error=0.0))
    s = log.summary()
    assert s["requests"] == 2 and s["cache_hits"] == 1
    assert s["hit_rate"] == 0.5
    assert s["mean_rel_error"] == pytest.approx(0.5)
    assert s["mean_rel_error_by_workload"] == {"w": pytest.approx(0.5)}


def test_relative_error():
    assert relative_error(2.0, 1.0) == pytest.approx(1.0)
    assert relative_error(1.0, 2.0) == pytest.approx(0.5)
    assert relative_error(1.0, None) is None
    assert relative_error(1.0, 0.0) is None


# -- drift detector ----------------------------------------------------------


def test_drift_fires_after_min_samples_over_threshold():
    d = DriftDetector(window=4, threshold=1.0, min_samples=2, cooldown=2)
    assert not d.observe("k", 5.0)          # only one sample
    assert d.observe("k", 5.0)              # mean 5.0 > 1.0, n=2
    d.reset("k")
    # cooldown: the next two high-error observations never trigger AND
    # are not accumulated — a re-trigger needs min_samples FRESH
    # post-cooldown observations (one drift event, one refinement)
    assert not d.observe("k", 5.0)
    assert not d.observe("k", 5.0)
    assert not d.observe("k", 5.0)          # fresh window: n=1 < min_samples
    assert d.observe("k", 5.0)              # n=2, mean over threshold
    assert d.triggers == 2


def test_drift_cooldown_samples_are_not_accumulated():
    """The double-fire bug: samples observed during cooldown used to pile
    into the window, so the first post-cooldown observation was judged
    against a mean of exactly the settling-period noise the cooldown
    existed to ignore."""
    d = DriftDetector(window=8, threshold=1.0, min_samples=2, cooldown=2)
    assert d.observe("k", 9.0) or d.observe("k", 9.0)
    d.reset("k")
    d.observe("k", 9.0)                     # settling spike, ignored
    d.observe("k", 9.0)                     # settling spike, ignored
    assert d.rolling_error("k") is None     # window really is empty
    # healthy steady state after the settling period: never re-fires
    for _ in range(8):
        assert not d.observe("k", 0.1)
    assert d.triggers == 1


def test_drift_load_discount_damps_contended_samples():
    d = DriftDetector(window=4, threshold=1.0, min_samples=2,
                      load_discount=0.5)
    # the same borderline error stream fires when idle...
    assert not d.observe("idle", 1.5, load_factor=1.0)
    assert d.observe("idle", 1.5, load_factor=1.0)
    # ...but not when every sample was retired at occupancy 5 (the
    # residual contention noise the normalization can't cancel)
    for _ in range(6):
        assert not d.observe("busy", 1.5, load_factor=5.0)
    # genuine drift still dwarfs the discount and fires under load
    assert not d.observe("drifted", 12.0, load_factor=5.0)
    assert d.observe("drifted", 12.0, load_factor=5.0)
    # the clone template carries the discount to per-tenant detectors
    assert d.clone().load_discount == 0.5


def test_drift_ignores_small_errors_and_none():
    d = DriftDetector(window=4, threshold=1.0, min_samples=2)
    for _ in range(6):
        assert not d.observe("k", 0.2)
    assert not d.observe("k", None)
    assert d.rolling_error("k") == pytest.approx(0.2)
    assert d.rolling_error("other") is None


def test_drift_windows_are_per_key():
    d = DriftDetector(window=4, threshold=1.0, min_samples=2)
    d.observe("a", 9.0)
    assert not d.observe("b", 9.0)          # b has only one sample
    assert d.observe("a", 9.0)


# -- scheduler dispatch paths ------------------------------------------------


def test_cold_miss_then_cache_hit_dispatch():
    sched = AdaptiveScheduler(_CalibratedStub(), backend="host-sync")
    sched.submit(_req(seed=0))
    sched.submit(_req(seed=1))              # same bucket, fresh data
    r_cold, r_warm = sched.run()
    assert not r_cold.cache_hit and r_warm.cache_hit
    assert sched.stats["model_searches"] == 1
    assert sched.stats["cache_hits"] == 1
    assert sched.stats["cold_misses"] == 1
    assert r_warm.config == r_cold.config
    assert r_cold.sample.source == "model"
    # predicted runtime is anchored to the profiled single-stream time
    assert r_warm.predicted_s is not None and r_warm.predicted_s > 0


def test_scheduler_respects_priority_policy():
    sched = AdaptiveScheduler(_CalibratedStub(), policy="priority")
    sched.submit(_req(tenant="background", priority=0))
    sched.submit(_req(tenant="interactive", priority=9))
    results = sched.run()
    assert [r.request.tenant for r in results] == ["interactive",
                                                   "background"]


def test_scheduler_writes_telemetry_jsonl(tmp_path):
    path = str(tmp_path / "t.jsonl")
    sched = AdaptiveScheduler(_CalibratedStub(),
                              telemetry=TelemetryLog(path))
    sched.submit_all([_req(seed=0), _req(seed=1)])
    sched.run()
    sched.telemetry.close()
    back = TelemetryLog.read(path)
    assert len(back) == 2
    assert back == sched.telemetry.samples
    assert [s.cache_hit for s in back] == [False, True]


# -- refinement --------------------------------------------------------------


def test_refiner_refreshes_cache_and_calls_refit():
    model = _RecordingRefitStub()
    cache = TuningCache()
    wl = get_workload("vecadd")
    chunked, shared = wl.make_data(256, np.random.default_rng(0))
    runner = StreamedRunner(wl, chunked, shared)
    key = cache.key(wl.name, chunked, shared, "host-sync")
    from repro.core.autotuner import TuneResult
    stale = TuneResult(StreamConfig(1, 2), 100.0, 0.0, 0.0)
    cache.put(key, stale)

    refiner = Refiner(model, cache, top_k=2, reps=1)
    feats = np.zeros(22)
    res = refiner.refine(runner, key, feats, stale)

    entry = cache.get(key)
    assert entry is not None and entry.source == "refined"
    assert entry.config == res.new_config
    # refined prediction is measured: single-stream speedup of the pick
    assert entry.predicted_speedup == pytest.approx(res.speedup)
    assert res.t_single_s > 0 and SINGLE_STREAM in res.measured
    assert len(model.refit_calls) == 1
    assert model.refit_calls[0][0] == len(res.measured)
    assert refiner.history == [res]


def test_perf_model_refit_moves_predictions_toward_new_targets():
    rng = np.random.default_rng(0)
    X = rng.standard_normal((60, 25))
    y = X[:, 0] * 2.0 + 1.0
    model = PerformanceModel.train(X, y, epochs=120, seed=0)
    # the serving-time ground truth disagrees: targets shifted up by 3
    X_new, y_new = X[:16], y[:16] + 3.0
    before = float(np.mean((model.predict(X_new) - y_new) ** 2))
    model.refit(X_new, y_new, epochs=200, lr=3e-3)
    after = float(np.mean((model.predict(X_new) - y_new) ** 2))
    assert after < before


# -- end-to-end acceptance ---------------------------------------------------


def test_end_to_end_adaptive_serving():
    """Mixed trace of 3 workloads: outputs allclose to host-sync
    reference, second occurrences all cache hits with no extra model
    search, and an injected misprediction triggers exactly one refinement
    that lowers that workload's rolling prediction error.

    Measured times are pinned (``_PinnedTimeScheduler``): the calibrated
    stub then sees rel_error exactly 0 pre-poison, exactly 39 on the
    poisoned bucket, and the refined entry's measured-speedup error
    after — the refinement count is deterministic by construction, on
    any host, under any neighbor load."""
    workloads = ["vecadd", "dotprod", "mvmult"]
    sched = _PinnedTimeScheduler(
        _CalibratedStub(), backend="host-sync",
        drift=DriftDetector(window=8, threshold=6.0, min_samples=2,
                            cooldown=2))
    trace = make_trace(workloads, occurrences=2, seed=0)
    sched.submit_all(trace)
    results = sched.run()

    # 1) numerical equivalence with the single-stream host-sync reference
    for r in results:
        wl = get_workload(r.request.workload)
        ref_runner = StreamedRunner(wl, r.request.chunked, r.request.shared,
                                    backend="host-sync")
        ref = np.concatenate(
            [np.asarray(o) for o in ref_runner.dispatch(SINGLE_STREAM)],
            axis=0)
        got = np.concatenate([np.asarray(o) for o in r.outputs], axis=0)
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=1e-3,
                                   err_msg=r.request.workload)

    # 2) second occurrence of each workload is a warm cache hit
    assert [r.cache_hit for r in results] == [False] * 3 + [True] * 3
    assert sched.stats["model_searches"] == 3
    assert sched.stats["refinements"] == 0

    # 3) inject a misprediction: poison the vecadd entry so its predicted
    #    runtime is ~40x too small, then keep serving vecadd traffic
    poison_req = trace[0]
    key = sched.cache.key("vecadd", poison_req.chunked, poison_req.shared,
                          "host-sync", "")
    entry = sched.cache.get(key)
    assert entry is not None
    sched.cache.put(key, dataclasses.replace(
        entry, predicted_speedup=entry.predicted_speedup * 40.0))

    for seed in range(10, 16):
        sched.submit(_req("vecadd", rows=256, seed=seed))
    post = sched.run()

    assert sched.stats["refinements"] == 1          # exactly one
    refined_at = next(i for i, r in enumerate(post) if r.refined)
    poisoned = [r.sample.rel_error for r in post[:refined_at + 1]]
    recovered = [r.sample.rel_error for r in post[refined_at + 1:]]
    assert recovered, "refinement should leave room for recovery samples"
    assert np.mean(recovered) < np.mean(poisoned)
    # the refreshed entry serves warm hits with measured-speedup provenance
    assert all(r.cache_hit for r in post[refined_at + 1:])
    assert all(r.sample.source == "refined" for r in post[refined_at + 1:])
    # still numerically correct after refinement
    for r in post:
        wl = get_workload("vecadd")
        ref_runner = StreamedRunner(wl, r.request.chunked, r.request.shared)
        ref = np.concatenate(
            [np.asarray(o) for o in ref_runner.dispatch(SINGLE_STREAM)],
            axis=0)
        got = np.concatenate([np.asarray(o) for o in r.outputs], axis=0)
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=1e-3)


def test_warm_hit_from_persisted_cache_keeps_drift_alive(tmp_path):
    """A restarted serving process hits the persisted cache without ever
    profiling features — the scheduler must re-anchor the single-stream
    runtime so prediction error (and hence drift refinement) still
    works."""
    path = str(tmp_path / "cache.json")
    first = AdaptiveScheduler(_CalibratedStub(), cache=TuningCache(path))
    first.submit(_req(seed=0))
    first.run()
    first.cache.save()

    # pinned measured times: the poison → refine assertions below depend
    # only on cache state, not on wall-clock noise (same structural
    # deflake as the e2e trace test)
    restarted = _PinnedTimeScheduler(
        _CalibratedStub(), cache=TuningCache(path),
        drift=DriftDetector(window=4, threshold=6.0, min_samples=2))
    restarted.submit_all([_req(seed=s) for s in (1, 2)])
    results = restarted.run()
    assert all(r.cache_hit for r in results)
    assert restarted.stats["model_searches"] == 0
    # the anchor was measured lazily, so predictions and errors exist
    assert all(r.predicted_s is not None for r in results)
    assert all(r.sample.rel_error is not None for r in results)

    # a poisoned persisted entry is therefore still refinable
    key = results[0].sample.key
    entry = restarted.cache.get(key)
    restarted.cache.put(key, dataclasses.replace(
        entry, predicted_speedup=entry.predicted_speedup * 40.0))
    restarted.submit_all([_req(seed=s) for s in (3, 4, 5)])
    post = restarted.run()
    assert restarted.stats["refinements"] == 1
    assert any(r.refined for r in post)


def test_cold_tune_with_infeasible_candidates_falls_back_to_single_stream():
    sched = AdaptiveScheduler(_CalibratedStub(),
                              candidates=[StreamConfig(32, 64)])
    sched.submit(_req(rows=16))
    (res,) = sched.run()
    assert res.config == SINGLE_STREAM
    got = np.concatenate([np.asarray(o) for o in res.outputs], axis=0)
    assert got.shape[0] == 16


def test_make_trace_is_deterministic_and_bucketed():
    t1 = make_trace(["vecadd", "dotprod"], occurrences=2, seed=3)
    t2 = make_trace(["vecadd", "dotprod"], occurrences=2, seed=3)
    assert [r.workload for r in t1] == ["vecadd", "dotprod"] * 2
    for a, b in zip(t1, t2):
        np.testing.assert_array_equal(
            next(iter(a.chunked.values())), next(iter(b.chunked.values())))
    # same shapes across occurrences => same tuning bucket
    assert (next(iter(t1[0].chunked.values())).shape
            == next(iter(t1[2].chunked.values())).shape)


def test_heuristic_model_prefers_overlap_without_overhead_blowup():
    feats = np.zeros(22)
    feats[19] = 1000.0   # t_transfer_us
    feats[20] = 1000.0   # t_compute_us
    m = OverlapHeuristicModel(overhead_s=30e-6)
    cands = [StreamConfig(1, 1), StreamConfig(1, 4), StreamConfig(8, 64)]
    preds = m.predict_configs(feats, cands)
    assert preds[1] > preds[0]       # overlapping 4 tasks beats serial
    assert preds[1] > preds[2]       # 512 dispatches of overhead lose
