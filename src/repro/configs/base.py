"""Architecture configuration system.

Every assigned architecture is expressed as an ``ArchConfig``: a composable
decoder specification built from a repeating ``layer_pattern`` of block types
(``attn`` / ``mamba`` / ``slstm`` / ``mlstm``) with an optional MoE FFN.  The
model zoo (``repro.models.model_zoo``) consumes this config to build params +
apply functions; ``repro.launch.dryrun`` consumes it to build pod-scale
``ShapeDtypeStruct`` inputs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


# ---------------------------------------------------------------------------
# Input shapes (assigned): every LM-family arch is paired with all four.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

ALL_SHAPES: tuple[InputShape, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    # Which layers (index within the layer_pattern repeat group) carry MoE.
    # None => every FFN is MoE.
    dense_residual: bool = False  # arctic: dense MLP residual alongside MoE
    dense_d_ff: int = 0
    # "ep": shard expert dim over the model axis (experts % model_axis == 0)
    # "tp": shard each expert's d_ff over the model axis (few experts)
    sharding: str = "ep"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM block parameters."""

    state_dim: int = 16
    conv_width: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 => ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    """sLSTM/mLSTM block parameters (xLSTM, arXiv:2405.04517)."""

    proj_factor_slstm: float = 4.0 / 3.0
    proj_factor_mlstm: float = 2.0
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | vlm | ssm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    # Repeating pattern of block types; tiled to num_layers.
    # e.g. dense transformer: ("attn",); jamba: 1 attn : 7 mamba.
    layer_pattern: Sequence[str] = ("attn",)
    # Which pattern positions have an MoE FFN (indices into layer_pattern).
    moe_layer_indices: Sequence[int] = ()
    # FFN placement: "attn" = after attention blocks only (dense decoders);
    # "all" = after every block (jamba-style); "none" = blocks self-contained.
    ffn_on: str = "attn"
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    head_dim: int = 0  # 0 => d_model // num_heads
    gated_mlp: bool = True  # SwiGLU (3 mats) vs classic up/down GELU (2 mats)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # vlm/audio: the modality frontend is a stub — inputs are precomputed
    # patch/frame embeddings of shape (B, S, frontend_dim).
    frontend: Optional[str] = None  # None | "vision_patches" | "audio_frames"
    frontend_dim: int = 0
    # True if attention is full/quadratic everywhere (=> skip long_500k).
    subquadratic: bool = False
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        assert self.num_layers % len(self.layer_pattern) == 0, (
            f"{self.name}: num_layers {self.num_layers} not divisible by "
            f"pattern length {len(self.layer_pattern)}"
        )

    # -- derived quantities -------------------------------------------------

    @property
    def num_pattern_repeats(self) -> int:
        return self.num_layers // len(self.layer_pattern)

    @property
    def attn_layers(self) -> int:
        per = sum(1 for b in self.layer_pattern if b == "attn")
        return per * self.num_pattern_repeats

    def block_counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for b in self.layer_pattern:
            out[b] = out.get(b, 0) + self.num_pattern_repeats
        return out

    def shapes(self) -> tuple[InputShape, ...]:
        """Input shapes applicable to this architecture."""
        out = []
        for s in ALL_SHAPES:
            if s is LONG_500K and not self.subquadratic:
                continue  # full-attention arch: 500k dense KV cache non-goal
            out.append(s)
        return tuple(out)

    def skipped_shapes(self) -> tuple[InputShape, ...]:
        return tuple(s for s in ALL_SHAPES if s not in self.shapes())

    # -- parameter counting (used for MODEL_FLOPS and roofline) -------------

    def param_counts(self) -> dict[str, float]:
        """Total and active (per-token) parameter counts."""
        d, hd = self.d_model, self.head_dim
        q_heads, kv_heads = self.num_heads, self.num_kv_heads
        per_block_total = {}
        per_block_active = {}
        for b in set(self.layer_pattern):
            if b == "attn":
                n = d * (q_heads * hd) + 2 * d * (kv_heads * hd) + (q_heads * hd) * d
                per_block_total[b] = per_block_active[b] = n + 2 * d  # + norms
            elif b == "mamba":
                assert self.ssm is not None
                e = self.ssm.expand * d
                dtr = self.ssm.dt_rank or -(-d // 16)
                n = (
                    d * 2 * e  # in_proj (x and z branches)
                    + e * self.ssm.conv_width  # depthwise conv
                    + e * (dtr + 2 * self.ssm.state_dim)  # x -> dt, B, C
                    + dtr * e  # dt_proj
                    + e * self.ssm.state_dim  # A
                    + e  # D
                    + e * d  # out_proj
                    + d  # norm
                )
                per_block_total[b] = per_block_active[b] = n
            elif b in ("slstm", "mlstm"):
                assert self.xlstm is not None
                if b == "mlstm":
                    e = int(self.xlstm.proj_factor_mlstm * d)
                    n = d * 2 * e + 3 * e * e // max(self.num_heads, 1) + e * d + 2 * d
                else:
                    e = int(self.xlstm.proj_factor_slstm * d)
                    n = 4 * d * d + 4 * d * d // max(self.num_heads, 1) + d * e + e * d + 2 * d
                per_block_total[b] = per_block_active[b] = n
            else:
                raise ValueError(b)
        # FFN (attached to attn blocks only, per decoder convention)
        moe_set = set(self.moe_layer_indices)
        ffn_total = ffn_active = 0.0
        for i, b in enumerate(self.layer_pattern):
            if self.ffn_on == "none":
                continue
            if self.ffn_on == "attn" and b != "attn":
                continue  # block embeds its own FFN-equivalent
            nmat = 3 if self.gated_mlp else 2
            if self.moe is not None and (not moe_set or i in moe_set):
                m = self.moe
                e_params = nmat * d * m.expert_d_ff
                ffn_total += m.num_experts * e_params + d * m.num_experts
                ffn_active += m.top_k * e_params + d * m.num_experts
                if m.dense_residual:
                    dn = nmat * d * (m.dense_d_ff or self.d_ff)
                    ffn_total += dn
                    ffn_active += dn
            elif self.d_ff > 0:
                n = nmat * d * self.d_ff
                ffn_total += n
                ffn_active += n
        reps = self.num_pattern_repeats
        total = reps * (
            sum(per_block_total[b] for b in self.layer_pattern) + ffn_total
        )
        active = reps * (
            sum(per_block_active[b] for b in self.layer_pattern) + ffn_active
        )
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total += embed + d
        active += embed + d
        return {"total": float(total), "active": float(active)}

    def model_flops(self, shape: InputShape) -> float:
        """Useful model FLOPs for a step of the given shape.

        train: 6 * N_active * tokens ; prefill: 2 * N_active * tokens ;
        decode: 2 * N_active * batch (one token per sequence).
        """
        n_active = self.param_counts()["active"]
        if shape.kind == "train":
            return 6.0 * n_active * shape.seq_len * shape.global_batch
        if shape.kind == "prefill":
            return 2.0 * n_active * shape.seq_len * shape.global_batch
        return 2.0 * n_active * shape.global_batch

    # -- reduced config for CPU smoke tests ---------------------------------

    def reduced(self) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        pat = tuple(self.layer_pattern)
        n_layers = len(pat) if len(pat) > 1 else 2
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(2, self.moe.top_k),
                expert_d_ff=64, dense_d_ff=64 if self.moe.dense_residual else 0,
            )
        heads = min(self.num_heads, 4)
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=n_layers,
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=64 // heads if 64 % heads == 0 else 16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            moe=moe,
            frontend_dim=64 if self.frontend else 0,
        )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    assert cfg.name not in _REGISTRY, cfg.name
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    # Import the per-arch modules for their registration side effects.
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        arctic_480b,
        codeqwen15_7b,
        grok1_314b,
        jamba15_large_398b,
        musicgen_medium,
        pixtral_12b,
        stablelm_3b,
        starcoder2_15b,
        xlstm_350m,
        yi_9b,
    )
