"""Executor-backend protocol: how a (partitions, tasks) stream config is
realized on a concrete substrate.

A backend receives an :class:`ExecutionContext` — the immutable per-run
state (kernel, host data, device, jitted callables, resident shared
buffers) — and a :class:`~repro.core.stream_config.StreamConfig`, and
returns the list of per-slice outputs in deterministic (task-major,
partition-minor) order.  That ordering contract is what makes every
backend comparable against the single-stream reference: concatenating the
outputs along axis 0 must reproduce the unsplit result for ``concat``
workloads.

Two backend kinds exist:
  * ``runner``     — drives a chunkable data-parallel kernel
                     (``dispatch`` is the entry point);
  * ``train-step`` — rewrites a training step into a streamed equivalent
                     (``wrap_train_step`` is the entry point).
"""
from __future__ import annotations

import abc
import dataclasses
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np

# Process-wide jit memo: serving creates one ExecutionContext per request,
# and a fresh ``jax.jit(kernel)`` wrapper per request would recompile every
# shape it has already seen.  Workload kernels are module-level callables
# with stable identity, so memoizing the wrapper by kernel shares the trace
# cache across contexts (and across requests for the whole process).
# Bounded with FIFO eviction: a jitted wrapper strongly references its
# kernel, so a weak-keyed map would never collect entries anyway, and
# callers jitting dynamically created closures must not grow the memo (and
# every compiled executable behind it) without bound.
_JIT_MEMO: dict = {}
_JIT_MEMO_MAX = 256
# miss-path lock: backends run on pool worker threads (host-threads, the
# concurrent serving engine), and an unguarded evict-while-full loop lets
# two threads pop the same key
_MEMO_LOCK = threading.Lock()


def memoized_jit(kernel: Callable, *, donate: bool = False) -> Callable:
    """``jax.jit(kernel)`` with the wrapper shared across ExecutionContexts."""
    try:
        entry = _JIT_MEMO.get(kernel)
    except TypeError:          # unhashable callable: no memoization
        return (jax.jit(kernel, donate_argnums=0) if donate
                else jax.jit(kernel))
    key = "donate" if donate else "plain"
    if entry is not None and key in entry:
        return entry[key]
    with _MEMO_LOCK:
        entry = _JIT_MEMO.get(kernel)
        if entry is None:
            while len(_JIT_MEMO) >= _JIT_MEMO_MAX:
                _JIT_MEMO.pop(next(iter(_JIT_MEMO)), None)
            entry = _JIT_MEMO[kernel] = {}
        if key not in entry:
            entry[key] = (jax.jit(kernel, donate_argnums=0) if donate
                          else jax.jit(kernel))
        return entry[key]


def split_arrays(arrs: dict, n: int) -> list[dict]:
    """Split every array in the dict into n chunks along axis 0."""
    if n == 1:
        return [arrs]
    keys = list(arrs)
    pieces = {k: np.array_split(arrs[k], n) for k in keys}
    return [{k: pieces[k][i] for k in keys} for i in range(n)]


# Dispatch-plan cache: the (start, stop) row ranges of every task and
# partition slice depend only on (row count, config), yet the backends used
# to re-derive them through nested ``np.array_split`` calls on every
# dispatch.  Serving traffic repeats the same few (shape-bucket, config)
# pairs thousands of times, so the boundaries are memoized here and the
# arrays sliced directly — the hot-path cost per dispatch drops to plain
# ``a[lo:hi]`` views.
_PLAN_CACHE: dict = {}
_PLAN_CACHE_MAX = 4096


def _split_bounds(lo: int, hi: int, n: int) -> list[tuple[int, int]]:
    """(start, stop) ranges identical to ``np.array_split`` of hi-lo rows
    into n pieces (first ``rem`` pieces get the extra row)."""
    total = hi - lo
    base, rem = divmod(total, n)
    bounds = []
    start = lo
    for i in range(n):
        size = base + (1 if i < rem else 0)
        bounds.append((start, start + size))
        start += size
    return bounds


def dispatch_plan(n_rows: int, config) -> tuple:
    """Memoized slicing plan for one dispatch: a tuple of tasks, each a
    tuple of global (start, stop) partition row ranges — task-major,
    partition-minor, byte-identical boundaries to the nested
    ``split_arrays`` the backends used to compute per call.

    Thread-safe: backends dispatch from pool workers, so the eviction
    loop runs under the shared memo lock (the hit path stays lock-free —
    a racy ``get`` of an immutable tuple is fine)."""
    key = (n_rows, config.partitions, config.tasks)
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        with _MEMO_LOCK:
            plan = _PLAN_CACHE.get(key)
            if plan is None:
                while len(_PLAN_CACHE) >= _PLAN_CACHE_MAX:
                    _PLAN_CACHE.pop(next(iter(_PLAN_CACHE)), None)
                plan = tuple(
                    tuple(_split_bounds(t_lo, t_hi, config.partitions))
                    for t_lo, t_hi in _split_bounds(0, n_rows, config.tasks))
                _PLAN_CACHE[key] = plan
    return plan


def slice_rows(arrs: dict, lo: int, hi: int) -> dict:
    """Row-range view of every array in the dict (no copies)."""
    return {k: a[lo:hi] for k, a in arrs.items()}


@dataclasses.dataclass
class ExecutionContext:
    """Per-(workload, dataset) state shared by every runner backend."""

    kernel: Callable
    chunked: dict
    shared: dict
    device: Any
    jit_kernel: Callable
    shared_dev: Any
    _donating_jit: Optional[Callable] = None

    @classmethod
    def create(cls, kernel: Callable, chunked: dict, shared: dict,
               device=None) -> "ExecutionContext":
        device = device or jax.devices()[0]
        # buffer-validity tracking (paper §4.4.5): shared buffers are
        # transferred once and stay resident across tasks and runs.
        shared_dev = jax.device_put(shared, device)
        jax.block_until_ready(shared_dev)
        return cls(kernel=kernel, chunked=chunked, shared=shared,
                   device=device, jit_kernel=memoized_jit(kernel),
                   shared_dev=shared_dev)

    def swap_buffers(self, chunked: dict, shared: dict) -> "ExecutionContext":
        """Re-point this context at a new request's data, keeping the
        jitted handles and device.

        The shared-buffer H2D transfer is semantically required when the
        new request carries shared data (its values differ), but a
        workload with an empty shared dict pays nothing — which is what
        makes pooling contexts cheaper than rebuilding them: creation
        always round-trips through ``device_put`` + ``block_until_ready``,
        a swap only does when there is something to ship."""
        self.chunked = chunked
        self.shared = shared
        if shared:
            self.shared_dev = jax.device_put(shared, self.device)
            jax.block_until_ready(self.shared_dev)
        else:
            self.shared_dev = {}
        return self

    @property
    def donating_jit(self) -> Callable:
        """Kernel jitted with the chunk argument donated, so a finished
        task's device buffers are recycled for its outputs (no-op on
        backends without donation support, e.g. CPU)."""
        if self._donating_jit is None:
            self._donating_jit = memoized_jit(self.kernel, donate=True)
        return self._donating_jit


class StreamBackend(abc.ABC):
    """One realization of the streamed-execution strategy."""

    #: unique registry key
    name: str = ""
    #: "runner" (chunkable kernels) or "train-step" (training loops)
    kind: str = "runner"

    def dispatch(self, ctx: ExecutionContext, config) -> list:
        """Issue the full iteration space under ``config``; returns the
        per-slice outputs (possibly still in flight — callers block)."""
        raise NotImplementedError(f"{self.name} is not a runner backend")

    def wrap_train_step(self, loss_fn: Callable, config, *,
                        unroll: bool = True) -> Callable:
        """Rewrite ``loss_fn(params, batch) -> (loss, aux)`` into a
        streamed step function."""
        raise NotImplementedError(f"{self.name} is not a train-step backend")

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<StreamBackend {self.name} ({self.kind})>"
