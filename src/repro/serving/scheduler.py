"""The adaptive serving scheduler: the paper's feature → model → config
loop, run online over a multi-tenant request stream.

Per request, the decision point is exactly paper §3.3 ("used as a utility
to quickly search for a good configuration at runtime"), made cheap
enough to sit on the serving path:

  warm path   TuningCache hit (microseconds) → dispatch immediately;
  cold path   extract features (one profiled iteration), rank the config
              space with the performance model via ``search_best``,
              cache the winner, dispatch.

Every dispatch appends a :class:`~repro.serving.telemetry.TelemetrySample`
(chosen config, predicted vs. measured runtime) to the telemetry log, and
feeds the relative prediction error to the
:class:`~repro.serving.refinement.DriftDetector`.  A triggered bucket is
handed to the :class:`~repro.serving.refinement.Refiner`, which
re-profiles a small candidate set, refreshes the cache entry, and refits
the model incrementally — closing the offline-learn / online-correct
loop.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional, Sequence, Union

import jax
import numpy as np

from repro.core import features as feat_lib
from repro.core.autotuner import TuneResult, TuningCache
from repro.core.features import RAW_FEATURE_NAMES
from repro.core.search import search_best
from repro.core.stream_config import SINGLE_STREAM, StreamConfig, \
    default_space
from repro.core.streams import StreamedRunner
from repro.core.workloads import get_workload
from repro.serving.queue import RequestQueue, WorkloadRequest
from repro.serving.refinement import DriftDetector, Refiner
from repro.serving.telemetry import TelemetryLog, TelemetrySample, \
    relative_error

_I_T_SINGLE = RAW_FEATURE_NAMES.index("t_single_us")
_I_T_XFER = RAW_FEATURE_NAMES.index("t_transfer_us")
_I_T_COMP = RAW_FEATURE_NAMES.index("t_compute_us")


class OverlapHeuristicModel:
    """Zero-training stand-in for a trained :class:`PerformanceModel`.

    Scores each candidate with the classic streams overlap bound: with
    ``n`` tasks the makespan is the dominant phase plus ``1/n`` of the
    overlapped phase plus a per-dispatch overhead that grows with
    partitions × tasks.  Deterministic given the extracted features, so
    the serving smoke paths (CLI, CI trace) need no training set.
    """

    def __init__(self, overhead_s: float = 30e-6):
        self.overhead_s = overhead_s

    def predict_configs(self, prog_feats: np.ndarray,
                        configs) -> np.ndarray:
        t_comp = float(prog_feats[_I_T_COMP]) * 1e-6
        t_xfer = float(prog_feats[_I_T_XFER]) * 1e-6
        base = max(t_comp + t_xfer, 1e-9)
        preds = []
        for c in configs:
            makespan = (max(t_comp, t_xfer)
                        + min(t_comp, t_xfer) / c.tasks
                        + self.overhead_s * c.partitions * c.tasks)
            preds.append(base / makespan)
        return np.asarray(preds)


@dataclasses.dataclass
class RequestResult:
    request: WorkloadRequest
    config: StreamConfig
    outputs: list                  # per-slice outputs, task-major order
    measured_s: float
    predicted_s: Optional[float]
    cache_hit: bool
    refined: bool
    sample: TelemetrySample


class AdaptiveScheduler:
    """Drains a :class:`RequestQueue`, making one model-informed placement
    decision per request and learning from every measurement."""

    def __init__(self, model, *,
                 backend: str = "host-sync",
                 policy: str = "fifo",
                 cache: Optional[TuningCache] = None,
                 candidates: Optional[Sequence[StreamConfig]] = None,
                 telemetry: Optional[TelemetryLog] = None,
                 drift: Optional[DriftDetector] = None,
                 refiner: Optional[Refiner] = None,
                 model_tag: str = "",
                 warm_before_measure: bool = True,
                 keep_outputs: bool = True):
        self.model = model
        self.backend_name = backend
        self.queue = RequestQueue(policy)
        self.cache = cache if cache is not None else TuningCache()
        self.candidates = list(candidates or default_space())
        self.telemetry = telemetry if telemetry is not None else TelemetryLog()
        self.drift = drift if drift is not None else DriftDetector()
        self.refiner = refiner if refiner is not None else Refiner(
            model, self.cache, candidates=self.candidates)
        self.model_tag = model_tag
        self.warm_before_measure = warm_before_measure
        self.keep_outputs = keep_outputs
        self.stats: collections.Counter = collections.Counter()
        # per-bucket serving state: raw program features and the profiled
        # single-stream runtime (the model predicts *speedup*; runtime
        # prediction needs the single-stream anchor)
        self._feats: dict[str, np.ndarray] = {}
        self._t_single: dict[str, float] = {}
        self._warmed: set = set()
        self._seq = 0

    # -- request intake -------------------------------------------------------

    def submit(self, request: WorkloadRequest) -> WorkloadRequest:
        self.stats[f"tenant.{request.tenant}.submitted"] += 1
        return self.queue.push(request)

    def submit_all(self, requests) -> None:
        for r in requests:
            self.submit(r)

    # -- serving loop ---------------------------------------------------------

    def run(self, max_requests: Optional[int] = None) -> list[RequestResult]:
        """Drain the queue (up to ``max_requests``), one decision per
        request, in queue-policy order."""
        results = []
        while self.queue and (max_requests is None
                              or len(results) < max_requests):
            results.append(self.step())
        return results

    def step(self) -> RequestResult:
        return self._process(self.queue.pop())

    def _process(self, req: WorkloadRequest) -> RequestResult:
        wl = get_workload(req.workload)
        # one runner per request: each request carries its OWN shared
        # buffers, so a cached ExecutionContext would serve stale
        # shared_dev data.  The expensive part — kernel compilation — is
        # already shared across contexts by backends.base.memoized_jit;
        # what remains per request is the shared-buffer H2D transfer,
        # which is semantically required.
        runner = StreamedRunner(wl, req.chunked, req.shared,
                                backend=self.backend_name)
        n_rows = next(iter(req.chunked.values())).shape[0]
        key = self.cache.key(wl.name, req.chunked, req.shared,
                             self.backend_name, self.model_tag)

        hit = self.cache.get(key, valid=lambda r: (
            r.config.partitions * r.config.tasks <= n_rows))
        if hit is not None:
            entry, cache_hit = hit, True
            if key not in self._t_single:
                # warm hit from a cache persisted by a previous process:
                # the single-stream anchor was never profiled here, and
                # without it predicted runtime — and therefore drift
                # detection — would stay disabled for this bucket.  One
                # measured single-stream run restores both.
                self._t_single[key] = runner.run(SINGLE_STREAM, reps=1)
        else:
            entry, cache_hit = self._cold_tune(runner, key, n_rows), False
        config = entry.config

        # dispatch + measure (first occurrence of a (bucket, config) pair
        # warms up so measured runtime is execution, not compilation)
        if self.warm_before_measure and (key, config) not in self._warmed:
            runner.warmup(config)
            self._warmed.add((key, config))
        t0 = time.perf_counter()
        outs = runner.dispatch(config)
        jax.block_until_ready(outs)
        # read back like StreamedRunner.run does, so measured_s and the
        # single-stream prediction anchor are timed on the same basis
        # (dispatch + compute + D2H); otherwise rel_error carries a
        # constant bias on transfer-heavy workloads
        for o in outs:
            np.asarray(jax.tree.leaves(o)[0], copy=False)
        measured_s = time.perf_counter() - t0

        predicted_s = self._predicted_runtime(key, entry)
        rel = relative_error(measured_s, predicted_s)

        refined = False
        if self.drift.observe(key, rel):
            refinement = self.refiner.refine(runner, key,
                                             self._feats.get(key), entry)
            # recalibrate the runtime anchor from the refinement's own
            # measured single-stream run
            self._t_single[key] = refinement.t_single_s
            self.drift.reset(key)
            self.stats["refinements"] += 1
            refined = True

        self._seq += 1
        sample = TelemetrySample(
            seq=self._seq, tenant=req.tenant, workload=wl.name, key=key,
            backend=self.backend_name, partitions=config.partitions,
            tasks=config.tasks, cache_hit=cache_hit,
            predicted_s=predicted_s, measured_s=measured_s, rel_error=rel,
            refined=refined, source=entry.source)
        self.telemetry.append(sample)

        self.stats["requests"] += 1
        self.stats["cache_hits" if cache_hit else "cold_misses"] += 1
        self.stats[f"tenant.{req.tenant}.served"] += 1

        return RequestResult(
            request=req, config=config,
            outputs=outs if self.keep_outputs else [],
            measured_s=measured_s, predicted_s=predicted_s,
            cache_hit=cache_hit, refined=refined, sample=sample)

    # -- cold path ------------------------------------------------------------

    def _cold_tune(self, runner: StreamedRunner, key: str,
                   n_rows: int) -> TuneResult:
        t0 = time.perf_counter()
        feats = feat_lib.extract_features(runner, profile_reps=1)
        t_feat = time.perf_counter() - t0
        self._feats[key] = feats.values
        self._t_single[key] = float(feats.values[_I_T_SINGLE]) * 1e-6
        # guard: an empty filtered list would make search_best fall back
        # to the FULL default grid, returning an unsplittable config
        cands = [c for c in self.candidates
                 if c.partitions * c.tasks <= n_rows] or [SINGLE_STREAM]
        best, preds, t_search = search_best(self.model, feats.values, cands)
        self.stats["model_searches"] += 1
        result = TuneResult(best, float(np.max(preds)), t_feat, t_search,
                            backend=self.backend_name, source="model")
        self.cache.put(key, result)
        return result

    def _predicted_runtime(self, key: str,
                           entry: TuneResult) -> Optional[float]:
        t_single = self._t_single.get(key)
        if t_single is None or entry.predicted_speedup <= 0:
            return None
        return t_single / entry.predicted_speedup


def make_trace(workloads: Sequence[str], *, occurrences: int = 2,
               tenants: Sequence[str] = ("tenant-a", "tenant-b"),
               scale_index: int = 0, seed: int = 0,
               priorities: Optional[Sequence[int]] = None
               ) -> list[WorkloadRequest]:
    """A deterministic mixed-workload request trace: ``occurrences``
    rounds over ``workloads``, data re-drawn per request (same shapes, so
    later rounds land in the same tuning bucket), tenants round-robin."""
    rng = np.random.default_rng(seed)
    reqs = []
    for round_idx in range(occurrences):
        for i, name in enumerate(workloads):
            wl = get_workload(name)
            scale = wl.datasets[min(scale_index, len(wl.datasets) - 1)]
            chunked, shared = wl.make_data(scale, rng)
            reqs.append(WorkloadRequest(
                workload=name, chunked=chunked, shared=shared,
                tenant=tenants[(round_idx * len(workloads) + i)
                               % len(tenants)],
                priority=(priorities[i % len(priorities)]
                          if priorities else 0)))
    return reqs
