"""Fleet serving: a tenant-sharding router over N worker processes.

The single-process schedulers (:mod:`repro.serving.scheduler`,
:mod:`repro.serving.engine`) are capped by the GIL and one XLA client;
the fleet splits the same serving pipeline across processes:

  submit → router admission queue (fifo/priority/fair/deadline)
         → tenant → worker shard (stable CRC32)
         → worker process: own ConcurrentScheduler + tuning cache +
           telemetry + metrics + drift/refinement
         → results stream back; worker-labeled samples merge into one
           fleet telemetry log / metrics snapshot

The data plane is event-driven and framed (see ``wire.py``): the
router parks in ``multiprocessing.connection.wait`` over result pipes
and process sentinels, and workers ship batched ``("results", ...)``
frames of slim positional rows (``REPRO_FLEET_WIRE=legacy`` restores
the per-request payload-dict wire).  Worker death is handled by
respawn-and-requeue (see ``router.py``); model versions distribute
through the shared ``ModelRegistry`` —
``FleetRouter.refresh_model("latest")`` makes every worker reload and
hot-swap the pinned artifact.  Entry points:
``launch/serve.py --worker-procs N`` and
``benchmarks/run.py --serve-fleet``.
"""
from repro.serving.fleet.aggregate import (fleet_summary, merge_metrics,
                                           merge_samples, payload_from_sample)
from repro.serving.fleet.router import FleetRouter, shard_for
from repro.serving.fleet.wire import (WIRE_MODES, WIRE_VERSION,
                                      WireProtocolError, resolve_wire_mode)
from repro.serving.fleet.worker import WorkerConfig, worker_main

__all__ = [
    "FleetRouter", "WorkerConfig", "worker_main", "shard_for",
    "merge_samples", "merge_metrics", "fleet_summary",
    "payload_from_sample", "WIRE_VERSION", "WIRE_MODES",
    "WireProtocolError", "resolve_wire_mode",
]
