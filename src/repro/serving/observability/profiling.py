"""Hot-path profiling over the serving engine's decide/dispatch/retire
loop.

Two opt-in instruments, composable with the span tracer:

  :func:`aggregate_stage_times`
      Rolls a tracer's spans up into the five attribution stages
      (``decide`` / ``tune`` / ``dispatch`` / ``retire`` / ``refine``),
      reporting wall seconds, span counts, means, and — when the tracer
      recorded thread CPU time — the CPU share of each stage.  This is
      the per-stage breakdown ``BENCH_overhead.json`` commits to.

  :class:`AllocationProfiler`
      A ``tracemalloc`` wrapper that answers "where do the hot-path
      allocations live?" — the question ROADMAP's real-engine-replay
      item exists to expose.  Strictly opt-in: tracemalloc roughly
      doubles allocation cost, so the overhead benchmark runs its timed
      pass untraced and takes a separate, shorter allocation pass.

:class:`HotPathProfiler` bundles both around a callable for one-line
use in benchmarks and the serve CLI.
"""
from __future__ import annotations

import time
import tracemalloc
from typing import Iterable, Optional

from repro.serving.observability.tracing import (STAGES, SpanRecord,
                                                 stage_of)


def aggregate_stage_times(spans: Iterable[SpanRecord],
                          stages: tuple = STAGES) -> dict:
    """Per-stage attribution: {stage: {"wall_s", "count", "mean_s"[,
    "cpu_s"]}}.  Only top-level spans (``depth == 0``) are summed so a
    nested ``tune.cold`` inside an outer span is never double-counted;
    every requested stage is present (zeroed) even if nothing hit it,
    so downstream JSON consumers see a stable schema."""
    out = {s: {"wall_s": 0.0, "count": 0, "mean_s": None}
           for s in stages}
    cpu_seen = False
    for span in spans:
        if span.depth:
            continue
        stage = stage_of(span.name)
        agg = out.get(stage)
        if agg is None:
            agg = out[stage] = {"wall_s": 0.0, "count": 0, "mean_s": None}
        agg["wall_s"] += span.duration_s
        agg["count"] += 1
        if span.cpu_s is not None:
            cpu_seen = True
            agg["cpu_s"] = agg.get("cpu_s", 0.0) + span.cpu_s
    for agg in out.values():
        if agg["count"]:
            agg["mean_s"] = agg["wall_s"] / agg["count"]
        if cpu_seen:
            agg.setdefault("cpu_s", 0.0)
    return out


class AllocationProfiler:
    """Top allocation sites over a profiled region, via ``tracemalloc``.

    ``start()``/``stop()`` bracket the region (also usable as a context
    manager); ``top(n)`` returns the heaviest allocation sites as plain
    dicts (``site``, ``size_kb``, ``count``) — grouped by (file, line)
    with ``frames`` stack depth available for deeper grouping.  The
    snapshot is taken at ``stop()`` so ``top()`` reflects live memory at
    region end — steady-state retention, not transient churn."""

    def __init__(self, *, frames: int = 8):
        self.frames = frames
        self._snapshot = None
        self._started_here = False

    def start(self) -> "AllocationProfiler":
        if not tracemalloc.is_tracing():
            tracemalloc.start(self.frames)
            self._started_here = True
        return self

    def stop(self) -> None:
        if tracemalloc.is_tracing():
            self._snapshot = tracemalloc.take_snapshot()
            if self._started_here:
                tracemalloc.stop()

    def __enter__(self) -> "AllocationProfiler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def top(self, n: int = 15) -> list[dict]:
        if self._snapshot is None:
            return []
        stats = self._snapshot.statistics("lineno")
        return [{
            "site": (f"{st.traceback[0].filename}:"
                     f"{st.traceback[0].lineno}"),
            "size_kb": st.size / 1024.0,
            "count": st.count,
        } for st in stats[:n]]


class HotPathProfiler:
    """One-line profiling of a serving run: per-stage wall/CPU from the
    tracer's spans, optional top allocation sites, and the overall
    wall/CPU envelope of the profiled region.

        prof = HotPathProfiler(tracer, alloc=True)
        with prof:
            scheduler.run()
        report = prof.report()
    """

    def __init__(self, tracer, *, alloc: bool = False):
        self.tracer = tracer
        self.alloc = AllocationProfiler() if alloc else None
        self._t0 = self._cpu0 = 0.0
        self.wall_s: Optional[float] = None
        self.cpu_s: Optional[float] = None

    def __enter__(self) -> "HotPathProfiler":
        if self.alloc is not None:
            self.alloc.start()
        self._cpu0 = time.process_time()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.wall_s = time.perf_counter() - self._t0
        self.cpu_s = time.process_time() - self._cpu0
        if self.alloc is not None:
            self.alloc.stop()

    def report(self, *, top_allocations: int = 15) -> dict:
        rep = {
            "wall_s": self.wall_s,
            "cpu_s": self.cpu_s,
            "stages": aggregate_stage_times(self.tracer.spans),
        }
        if self.alloc is not None:
            rep["allocations"] = self.alloc.top(top_allocations)
        return rep
