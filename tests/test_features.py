"""Feature extraction from live workloads (paper §3.2)."""
import numpy as np
import pytest

from repro.core.features import RAW_FEATURE_NAMES, extract_features
from repro.core.streams import StreamedRunner
from repro.core.workloads import get_workload


@pytest.fixture(scope="module")
def feats():
    wl = get_workload("mvmult")
    rng = np.random.default_rng(0)
    chunked, shared = wl.make_data(wl.datasets[0], rng)
    runner = StreamedRunner(wl, chunked, shared)
    return extract_features(runner, profile_reps=1).as_dict()


def test_feature_vector_complete(feats):
    assert set(feats) == set(RAW_FEATURE_NAMES)
    assert all(np.isfinite(v) for v in feats.values())


def test_transfer_features(feats):
    wl = get_workload("mvmult")
    rng = np.random.default_rng(0)
    chunked, shared = wl.make_data(wl.datasets[0], rng)
    n = chunked["A"].shape[0]
    assert feats["loop_count"] == n
    assert feats["max_blocks"] == n
    assert feats["dts"] == chunked["A"].nbytes + shared["v"].nbytes
    assert feats["redundant_transfer"] == shared["v"].nbytes
    assert feats["n_xfer_mem"] == 2


def test_static_compiled_features(feats):
    assert feats["flops"] > 0
    assert feats["hlo_ops"] >= 1
    assert 0 <= feats["frac_dot"] <= 1


def test_dynamic_profile_features(feats):
    assert feats["t_single_us"] > 0
    assert feats["t_compute_us"] > 0
    assert feats["t_transfer_us"] > 0


def test_sequential_flag():
    wl = get_workload("binomial")
    rng = np.random.default_rng(0)
    chunked, shared = wl.make_data(wl.datasets[0], rng)
    f = extract_features(StreamedRunner(wl, chunked, shared),
                         profile=False).as_dict()
    assert f["sequential_inner"] == 1.0
