"""Multi-tenant workload request queue.

A :class:`WorkloadRequest` is one unit of serving work: a named streamed
workload plus its host data, tagged with the submitting tenant and a
priority.  :class:`RequestQueue` orders them under one of three policies:

  ``fifo``     — global arrival order;
  ``priority`` — higher ``priority`` first, arrival order within a level
                 (stable: equal-priority requests never reorder);
  ``fair``     — round-robin across tenants, arrival order within a
                 tenant, so one chatty tenant cannot starve the rest.

All three are deterministic given the submission sequence — the property
the scheduler tests rely on.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import itertools

POLICIES = ("fifo", "priority", "fair")


@dataclasses.dataclass
class WorkloadRequest:
    """One serving request: run ``workload`` over this request's data."""

    workload: str
    chunked: dict
    shared: dict
    tenant: str = "default"
    priority: int = 0
    #: arrival sequence number, assigned at enqueue time
    seq: int = -1


class RequestQueue:
    def __init__(self, policy: str = "fifo"):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        self.policy = policy
        self._seq = itertools.count()
        self._fifo: collections.deque = collections.deque()
        self._heap: list = []
        self._per_tenant: dict[str, collections.deque] = {}
        self._rr: collections.deque = collections.deque()  # tenant rotation

    def push(self, req: WorkloadRequest) -> WorkloadRequest:
        req.seq = next(self._seq)
        if self.policy == "fifo":
            self._fifo.append(req)
        elif self.policy == "priority":
            heapq.heappush(self._heap, (-req.priority, req.seq, req))
        else:  # fair
            if req.tenant not in self._per_tenant:
                self._per_tenant[req.tenant] = collections.deque()
                self._rr.append(req.tenant)
            self._per_tenant[req.tenant].append(req)
        return req

    def pop(self) -> WorkloadRequest:
        if not len(self):
            raise IndexError("pop from an empty RequestQueue")
        if self.policy == "fifo":
            return self._fifo.popleft()
        if self.policy == "priority":
            return heapq.heappop(self._heap)[2]
        tenant = self._rr.popleft()
        req = self._per_tenant[tenant].popleft()
        if self._per_tenant[tenant]:
            self._rr.append(tenant)       # rotate: next tenant goes first
        else:
            del self._per_tenant[tenant]
        return req

    def peek_tenants(self) -> list[str]:
        """Tenants with queued work, in service order (fair policy)."""
        return list(self._rr)

    def pending_by_tenant(self) -> dict[str, int]:
        """Queued-request count per tenant, any policy — the serving
        dashboards' fairness view.  Under ``fair`` this is exactly the
        per-tenant backlog the round-robin rotation drains one-at-a-time:
        in any stretch where every tenant stays non-empty, each tenant is
        served exactly once per rotation (asserted in the tenancy
        tests)."""
        if self.policy == "fair":
            return {t: len(d) for t, d in self._per_tenant.items()}
        counts: dict[str, int] = {}
        items = (self._fifo if self.policy == "fifo"
                 else (entry[2] for entry in self._heap))
        for req in items:
            counts[req.tenant] = counts.get(req.tenant, 0) + 1
        return counts

    def __len__(self) -> int:
        if self.policy == "fifo":
            return len(self._fifo)
        if self.policy == "priority":
            return len(self._heap)
        return sum(len(d) for d in self._per_tenant.values())

    def __bool__(self) -> bool:
        return len(self) > 0
