"""Pallas kernels (interpret mode) vs pure-jnp oracles — shape/dtype sweep."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.ops import flash_attention, rmsnorm
from repro.kernels.ref import flash_attention_ref, rmsnorm_ref

SHAPES = [
    # B, S, H, KV, hd
    (1, 128, 4, 4, 32),
    (2, 256, 8, 2, 64),
    (1, 256, 6, 3, 128),
    (2, 128, 4, 1, 64),   # MQA
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_attention_pallas(shape, dtype):
    B, S, H, KV, hd = shape
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd)).astype(dtype)
    out = flash_attention(q, k, v, q_block=128, kv_block=128)
    ref = flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert out.dtype == dtype
    assert jnp.allclose(out.astype(jnp.float32), ref.astype(jnp.float32),
                        atol=tol), float(jnp.abs(
                            out.astype(jnp.float32)
                            - ref.astype(jnp.float32)).max())


@pytest.mark.parametrize("blocks", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_block_sweep(blocks):
    qb, kb = blocks
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 32))
    k = jax.random.normal(ks[1], (1, 256, 2, 32))
    v = jax.random.normal(ks[2], (1, 256, 2, 32))
    out = flash_attention(q, k, v, q_block=qb, kv_block=kb)
    ref = flash_attention_ref(q, k, v)
    assert jnp.allclose(out, ref, atol=2e-5)


@pytest.mark.parametrize("rows,d", [(4, 64), (37, 96), (256, 128), (1, 32)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_rmsnorm_pallas(rows, d, dtype):
    ks = jax.random.split(jax.random.key(2), 2)
    x = jax.random.normal(ks[0], (rows, d)).astype(dtype)
    s = jax.random.normal(ks[1], (d,)).astype(dtype)
    out = rmsnorm(x, s, row_block=64)
    ref = rmsnorm_ref(x, s)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert jnp.allclose(out.astype(jnp.float32), ref.astype(jnp.float32),
                        atol=tol)


def test_rmsnorm_3d():
    x = jax.random.normal(jax.random.key(3), (2, 17, 64))
    s = jnp.ones((64,))
    assert jnp.allclose(rmsnorm(x, s), rmsnorm_ref(x, s), atol=1e-5)
