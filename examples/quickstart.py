"""Quickstart: the two faces of the framework in ~60 seconds on CPU.

1. Train a reduced-config assigned architecture end-to-end (synthetic data,
   AdamW, checkpointing).
2. Autotune the stream configuration of a data-parallel workload with the
   learned performance model (the paper's technique).

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro.core import dataset as ds
from repro.core.autotuner import AutoTuner, TuningCache
from repro.core.perf_model import PerformanceModel
from repro.core.workloads import get_workload
from repro.launch.train import train_loop

print("=== 1. train a reduced yi-9b for 30 steps ===")
res = train_loop("yi-9b", steps=30, batch=4, seq=32, verbose=True)
print(f"loss {res.losses[0]:.3f} -> {res.final_loss:.3f}\n")

print("=== 2. learn a performance model on 3 programs, tune a 4th ===")
samples = ds.generate(["vecadd", "binomial", "sgemm"],
                      datasets_per_program=2, reps=1,
                      cache_path="/tmp/quickstart_cache.json")
X, y = ds.training_matrix(samples)
model = PerformanceModel.train(X, y, epochs=300)

wl = get_workload("dotprod")  # never seen in training
chunked, shared = wl.make_data(2048, np.random.default_rng(0))
cache = TuningCache("/tmp/quickstart_tuning_cache.json")
tuner = AutoTuner(model, cache=cache)
t0 = time.perf_counter()
result = tuner.tune(wl, chunked, shared)
t_cold = time.perf_counter() - t0
print(f"chosen stream config for dotprod: "
      f"(partitions={result.config.partitions}, tasks={result.config.tasks})")
print(f"predicted speedup {result.predicted_speedup:.2f}x; "
      f"search took {result.search_seconds*1e3:.2f} ms "
      f"(feature extraction {result.feature_seconds*1e3:.0f} ms)")

print("=== 3. warm-start from the persistent tuning cache ===")
# a second request in the same shape bucket skips profiling entirely —
# the serving-time deployment flow (save the cache, reload at startup)
t1 = time.perf_counter()
warm = tuner.tune(wl, chunked, shared)
t_warm = time.perf_counter() - t1
cache.save()
if result.cached:
    # the whole script warm-started from a previous run's persisted file
    print(f"cache file from a previous run served both tunes in ~"
          f"{t_warm*1e6:.0f} us (delete {cache.path} for a cold demo)")
else:
    print(f"warm hit: cached={warm.cached}, "
          f"same config={warm.config == result.config}, "
          f"{t_cold*1e3:.0f} ms cold -> {t_warm*1e6:.0f} us warm "
          f"({t_cold/max(t_warm, 1e-9):.0f}x); "
          f"cache persisted to {cache.path}")
