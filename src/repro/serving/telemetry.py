"""Serving telemetry: one append-only JSONL record per dispatched request.

Each :class:`TelemetrySample` captures the serving decision and its
outcome — which config was chosen, where it came from (model search,
cache hit, or drift refinement), what runtime the model predicted, and
what was actually measured.  The relative prediction error
``|measured - predicted| / predicted`` is the drift-detection signal
(:mod:`repro.serving.refinement`) and the refit target provider.

The log is line-buffered JSONL: every ``append`` writes and flushes one
line, so a crashed serving process loses at most the in-flight request —
the same durability contract as the tuning cache's atomic save, but for
a stream instead of a snapshot.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import IO, Iterator, Optional


@dataclasses.dataclass
class TelemetrySample:
    seq: int                      # scheduler-assigned dispatch sequence
    tenant: str
    workload: str
    key: str                      # tuning-cache key (workload bucket id)
    backend: str
    partitions: int
    tasks: int
    cache_hit: bool
    predicted_s: Optional[float]  # model-predicted runtime (None if unknown)
    measured_s: float
    rel_error: Optional[float]    # |measured - predicted| / predicted
    refined: bool = False         # this request triggered a refinement
    source: str = "model"         # config provenance: model | refined
    # -- load-aware drift fields (concurrent engine) ----------------------
    #: window occupancy when this request was dispatched (itself included);
    #: 1 under the serial scheduler
    inflight: int = 1
    #: contention factor measured_s was divided by before computing the
    #: drift signal: max(1, min(inflight, workers) / host parallel
    #: capacity); 1.0 when serving serially or load-awareness is off
    load_factor: float = 1.0
    #: measured_s / load_factor — the contention-normalized runtime that
    #: rel_error (and therefore drift detection) is computed from
    measured_norm_s: Optional[float] = None

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "TelemetrySample":
        fields = {f.name for f in dataclasses.fields(TelemetrySample)}
        return TelemetrySample(**{k: v for k, v in d.items() if k in fields})


def relative_error(measured_s: float,
                   predicted_s: Optional[float]) -> Optional[float]:
    if predicted_s is None or predicted_s <= 0:
        return None
    return abs(measured_s - predicted_s) / predicted_s


class TelemetryLog:
    """In-memory sample list, mirrored to an append-only JSONL file.

    Usable as a context manager; ``close()`` flushes AND fsyncs before
    closing, and is idempotent.  A serving process torn down mid-trace
    (CI job timeout, SIGTERM between requests) must never leave a
    truncated last line for the artifact upload to capture — ``append``
    already flushes per line, but only fsync pushes the page cache to
    disk before the process dies."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.samples: list[TelemetrySample] = []
        self._fh: Optional[IO[str]] = None

    def append(self, sample: TelemetrySample) -> None:
        self.samples.append(sample)
        if self.path is not None:
            if self._fh is None:
                os.makedirs(os.path.dirname(os.path.abspath(self.path)),
                            exist_ok=True)
                self._fh = open(self.path, "a")
            self._fh.write(json.dumps(sample.to_json(),
                                      separators=(",", ":")) + "\n")
            self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            try:
                self._fh.flush()
                os.fsync(self._fh.fileno())
            except (OSError, ValueError):
                pass                  # already closed / non-seekable sink
            self._fh.close()
            self._fh = None

    @property
    def closed(self) -> bool:
        return self._fh is None

    def __enter__(self) -> "TelemetryLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __len__(self) -> int:
        return len(self.samples)

    def __iter__(self) -> Iterator[TelemetrySample]:
        return iter(self.samples)

    @staticmethod
    def read(path: str) -> list[TelemetrySample]:
        out = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    out.append(TelemetrySample.from_json(json.loads(line)))
        return out

    def summary(self) -> dict:
        """Aggregate view for dashboards / the --serve benchmark JSON."""
        n = len(self.samples)
        hits = sum(s.cache_hit for s in self.samples)
        errs = [s.rel_error for s in self.samples if s.rel_error is not None]
        per_workload: dict[str, list[float]] = {}
        for s in self.samples:
            if s.rel_error is not None:
                per_workload.setdefault(s.workload, []).append(s.rel_error)
        per_tenant: dict[str, dict] = {}
        for s in self.samples:
            t = per_tenant.setdefault(
                s.tenant, {"requests": 0, "cache_hits": 0,
                           "refinements": 0, "errors": []})
            t["requests"] += 1
            t["cache_hits"] += bool(s.cache_hit)
            t["refinements"] += bool(s.refined)
            if s.rel_error is not None:
                t["errors"].append(s.rel_error)
        return {
            "requests": n,
            "cache_hits": hits,
            "hit_rate": hits / n if n else 0.0,
            "refinements": sum(s.refined for s in self.samples),
            "total_measured_s": sum(s.measured_s for s in self.samples),
            "mean_rel_error": (sum(errs) / len(errs)) if errs else None,
            "mean_rel_error_by_workload": {
                w: sum(v) / len(v) for w, v in sorted(per_workload.items())},
            "per_tenant": {
                name: {"requests": t["requests"],
                       "cache_hits": t["cache_hits"],
                       "refinements": t["refinements"],
                       "mean_rel_error": (sum(t["errors"]) / len(t["errors"])
                                          if t["errors"] else None)}
                for name, t in sorted(per_tenant.items())},
        }
