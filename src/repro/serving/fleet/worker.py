"""Fleet worker process: one :class:`ConcurrentScheduler` per process.

``worker_main`` is the spawn target the router launches N of.  Each
worker builds its own serving stack — model, tuning cache, telemetry
log, metrics registry, drift detector — so nothing is shared across
processes except two channels: ``task_q`` (router → worker, an mp queue)
carries serve batches and control messages, and a per-worker result
:class:`~multiprocessing.connection.Connection` (worker → router, the
send end of a one-way pipe) carries result frames and the lifecycle
handshakes.  A dedicated result pipe per worker matters for crash
handling: a SIGKILL mid-``send`` can truncate a frame mid-byte-stream,
and with per-worker pipes the corruption dies with the worker — the
router holds only the read end (it closes its copy of the write end at
spawn), so a dead worker's truncated frame surfaces as a clean
``EOFError`` instead of wedging the whole fleet's result channel.

The message vocabulary lives in :mod:`repro.serving.fleet.wire`.  The
return path is *batched*: every engine run's results fold into framed
``("results", ...)`` messages — the worker-side mirror of
``_drain_serve``'s request folding — instead of one pickled payload per
request, with the engine-run boundary as the time window and
``frame_max`` as the size window.  Result receipt doubles as the
delivery ack, so acks ride the same frame.  ``wire="legacy"`` (or
``REPRO_FLEET_WIRE=legacy``) restores the per-request payload-dict
messages.

``token`` is the router-assigned ``trace_id`` — the worker's own queue
preserves it (``RequestQueue.push`` only assigns when unset), so results
map back to router bookkeeping without a shared sequence space.

Workers default to a :class:`ResiliencePolicy`: a bad request fails
*individually* (terminal ``failed`` result) instead of taking the
process down.  Anything that still escapes — a scheduler bug, an OOM —
exits the process nonzero after a best-effort ``fatal`` message, and
the router's death handler requeues the un-acked work on a respawn:
crash recovery composes out of per-request resilience inside the
process and whole-process replacement outside it.
"""
from __future__ import annotations

import dataclasses
import os
import queue as queue_mod
import time
from typing import Optional

from repro.serving.fleet.wire import (make_results_frame, resolve_wire_mode,
                                      split_frames)


@dataclasses.dataclass
class WorkerConfig:
    """Per-process serving configuration; must stay picklable (it is
    shipped to the spawn child as a process argument)."""

    worker_id: int = 0
    backend: str = "host-sync"
    #: in-flight window of the per-worker ConcurrentScheduler
    window: int = 2
    #: engine thread-pool size (default: window)
    workers: Optional[int] = None
    #: model spec — "heuristic", an artifact id, or a registry path.
    #: Pass a *pinned* artifact id rather than "latest": workers resolve
    #: with ``bootstrap=False`` so N processes never race to train
    model: str = "heuristic"
    model_dir: Optional[str] = None
    drift_threshold: float = 4.0
    #: per-worker tuning-cache JSON path (None = in-memory only); the
    #: router derives distinct paths per slot so namespaces never collide
    cache_path: Optional[str] = None
    #: per-worker telemetry JSONL path (None = in-memory; the router
    #: aggregates the merged fleet stream either way)
    telemetry_path: Optional[str] = None
    #: arm ResiliencePolicy: bad requests fail individually instead of
    #: killing the process
    resilience: bool = True
    #: load-aware drift capacity.  Fleet workers share one host, so a
    #: per-process thread-scaling probe would both slow startup and
    #: measure its neighbors; 1.0 disables within-worker load
    #: normalization (None = probe, as single-process serving does)
    capacity: Optional[float] = 1.0
    keep_outputs: bool = False
    #: result wire mode: "auto" (``$REPRO_FLEET_WIRE`` or v2), "v2"
    #: (framed positional rows), "legacy" (per-request payload dicts)
    wire: str = "auto"
    #: size window of result-frame coalescing: one engine run's results
    #: split into frames of at most this many items
    frame_max: int = 32

    @property
    def label(self) -> str:
        return f"w{self.worker_id}"


def _build_scheduler(cfg: WorkerConfig):
    """The worker's private serving stack.  Imports live here, not at
    module top: the spawn child pays them once, and the router process
    can import this module's dataclass without dragging in jax."""
    from repro.core.autotuner import TuningCache
    from repro.launch.serve import resolve_serving_model
    from repro.serving import (ConcurrentScheduler, DriftDetector,
                               MetricsRegistry, ResiliencePolicy,
                               TelemetryLog)

    model, info = resolve_serving_model(
        cfg.model, cfg.model_dir, bootstrap=False, verbose=False)
    sched = ConcurrentScheduler(
        model,
        window=cfg.window,
        workers=cfg.workers,
        capacity=cfg.capacity,
        backend=cfg.backend,
        policy="fifo",                 # admission ordering is the router's
        cache=TuningCache(cfg.cache_path),
        telemetry=TelemetryLog(cfg.telemetry_path),
        drift=DriftDetector(threshold=cfg.drift_threshold,
                            load_discount=0.5),
        model_tag=info["artifact_id"],
        keep_outputs=cfg.keep_outputs,
        metrics=MetricsRegistry(),
        resilience=ResiliencePolicy() if cfg.resilience else None)
    return sched, info["artifact_id"]


def _light_result(r, label: str) -> dict:
    """Strip a RequestResult for the LEGACY wire: the request's numpy
    payload stays in the worker (the router kept its own copy for
    requeue), only the decision/outcome/telemetry crosses back.  Wire v2
    sends just the sample row instead — see :func:`_send_results`."""
    sample = r.sample
    sample.worker = label
    return {
        "status": r.status,
        "error": r.error,
        "workload": r.request.workload,
        "tenant": r.request.tenant,
        "config": ([r.config.partitions, r.config.tasks]
                   if r.config is not None else None),
        "measured_s": r.measured_s,
        "predicted_s": r.predicted_s,
        "cache_hit": r.cache_hit,
        "refined": r.refined,
        "sample": sample.to_json(),
    }


def _drain_serve(task_q, batch: list):
    """Greedily fold queued-up serve messages into one batch so the
    engine sees a full window instead of chunk-sized trickles; the first
    non-serve message ends the drain and is returned for handling."""
    while True:
        try:
            msg = task_q.get_nowait()
        except queue_mod.Empty:
            return batch, None
        if msg[0] == "serve":
            batch.extend(msg[1])
        else:
            return batch, msg


def _send_results(conn, label: str, results, busy_s: float,
                  wire: str, frame_max: int) -> None:
    """Ship one engine run's results back to the router.

    Wire v2 folds them into framed ``("results", ...)`` messages of
    ``(token, sample_row)`` items — the batched, slim return path — with
    the run's engine wall time spread across frames pro rata (the router
    sums busy time per worker, so the attribution split is lossless).
    Legacy mode sends one ``("result", ...)`` payload dict per request.
    """
    if wire == "legacy":
        for r in results:
            # token == the router-assigned trace_id, preserved by push()
            conn.send(("result", label, r.request.trace_id,
                       _light_result(r, label)))
        return
    n = max(1, len(results))
    for chunk in split_frames(results, frame_max):
        items = []
        for r in chunk:
            sample = r.sample
            sample.worker = label
            items.append((r.request.trace_id, sample.to_row()))
        conn.send(make_results_frame(
            label, busy_s * (len(chunk) / n), items))


def _serve_batch(sched, cfg: WorkerConfig, batch, conn, wire: str) -> None:
    for _token, req in batch:
        sched.submit(req)
    t0 = time.perf_counter()
    results = sched.run()
    busy = time.perf_counter() - t0
    _send_results(conn, cfg.label, results, busy, wire, cfg.frame_max)


def _refresh(sched, cfg: WorkerConfig, spec: str):
    from repro.launch.serve import resolve_serving_model
    model, info = resolve_serving_model(
        spec, cfg.model_dir, bootstrap=False, verbose=False)
    sched.swap_model(model, model_tag=info["artifact_id"])
    return info["artifact_id"]


def worker_main(cfg: WorkerConfig, task_q, conn) -> None:
    """Spawn-target serving loop (must live in an importable module —
    spawn re-imports the target by qualified name, so a closure or
    ``__main__`` function would break under pytest and ``-m`` entry
    points).  ``conn`` is the send end of this worker's result pipe."""
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    label = cfg.label
    wire = resolve_wire_mode(cfg.wire)
    try:
        sched, model_tag = _build_scheduler(cfg)
    except BaseException as e:  # noqa: BLE001 — report, then die loudly
        conn.send(("fatal", label, f"{type(e).__name__}: {e}"))
        raise SystemExit(1)
    conn.send(("ready", label, os.getpid(), model_tag))

    try:
        pending_ctrl = None
        while True:
            msg = pending_ctrl if pending_ctrl is not None else task_q.get()
            pending_ctrl = None
            kind = msg[0]
            if kind == "stop":
                break
            if kind == "serve":
                batch, pending_ctrl = _drain_serve(task_q, list(msg[1]))
                _serve_batch(sched, cfg, batch, conn, wire)
            elif kind == "refresh":
                try:
                    tag = _refresh(sched, cfg, msg[1])
                    conn.send(("refreshed", label, tag, None))
                except Exception as e:  # noqa: BLE001 — keep serving on
                    # a bad publish; the old model stays live
                    conn.send(("refreshed", label, None,
                               f"{type(e).__name__}: {e}"))
            elif kind == "ping":
                conn.send(("pong", label))
    except BaseException as e:  # noqa: BLE001 — anything past the
        # per-request resilience barrier is process-fatal: report, exit
        # nonzero, let the router respawn and requeue un-acked work
        conn.send(("fatal", label, f"{type(e).__name__}: {e}"))
        raise SystemExit(1)

    # graceful goodbye: ship the per-worker aggregates for the fleet
    # merge, then tear down (telemetry close fsyncs the JSONL)
    conn.send(("bye", label, {
        "summary": sched.telemetry.summary(),
        "metrics": sched.metrics.snapshot(),
        "stats": dict(sched.stats),
    }))
    if cfg.cache_path:
        sched.cache.save()
    sched.close()
    conn.close()
