"""Pluggable executor backends for the streamed runtime.

A *backend* is one realization of the paper's (partitions, tasks)
execution strategy on a concrete substrate.  Backends register under a
string name; the runner (:class:`repro.core.streams.StreamedRunner`), the
autotuner, and the tuning cache all address them by that name, so a
serving process can switch substrates — or A/B two host pipelines — with
a config string.

Built-ins:
  ``host-sync``      — the synchronous reference executor (seed behavior)
  ``host-pipelined`` — depth-2 double-buffered pipeline with host-side
                       partition slicing and buffer donation
  ``host-threads``   — thread-pool task issue with a bounded in-flight
                       window (host-side analogue of multiple HW queues)
  ``mesh``           — pod-scale microbatched training step

Adding a backend::

    from repro.core.backends import StreamBackend, register_backend

    class MyBackend(StreamBackend):
        name = "my-backend"
        def dispatch(self, ctx, config): ...

    register_backend(MyBackend())
"""
from __future__ import annotations

from repro.core.backends.base import (ExecutionContext, StreamBackend,
                                      dispatch_plan, memoized_jit,
                                      slice_rows, split_arrays)
from repro.core.backends.host_pipelined import PipelinedHostBackend
from repro.core.backends.host_sync import SyncHostBackend
from repro.core.backends.host_threads import ThreadedHostBackend, \
    WindowedPool
from repro.core.backends.mesh import MeshBackend

_BACKENDS: dict[str, StreamBackend] = {}

#: the numerical reference every runner backend must reproduce
REFERENCE_BACKEND = "host-sync"


def register_backend(backend: StreamBackend, *,
                     overwrite: bool = False) -> StreamBackend:
    """Register a backend instance under ``backend.name``."""
    if not backend.name:
        raise ValueError(f"{backend!r} has no name")
    if backend.name in _BACKENDS and not overwrite:
        raise ValueError(f"backend {backend.name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _BACKENDS[backend.name] = backend
    return backend


def get_backend(name: str) -> StreamBackend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; registered: {list_backends()}"
        ) from None


def list_backends(kind: str | None = None) -> list[str]:
    """Sorted names of registered backends, optionally filtered by kind
    (``"runner"`` or ``"train-step"``)."""
    return sorted(n for n, b in _BACKENDS.items()
                  if kind is None or b.kind == kind)


register_backend(SyncHostBackend())
register_backend(PipelinedHostBackend())
register_backend(ThreadedHostBackend())
register_backend(MeshBackend())

__all__ = [
    "ExecutionContext", "StreamBackend", "memoized_jit", "split_arrays",
    "dispatch_plan", "slice_rows", "WindowedPool",
    "SyncHostBackend", "PipelinedHostBackend", "ThreadedHostBackend",
    "MeshBackend",
    "register_backend", "get_backend", "list_backends",
    "REFERENCE_BACKEND",
]
