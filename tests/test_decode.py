"""Prefill + decode consistency vs the full forward pass, per family."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.model_zoo import build_model
from repro.models.transformer import RunConfig


def _grow_attn_cache(cache, extra):
    out = {}
    for key, val in cache.items():
        if isinstance(val, dict) and "k" in val:
            out[key] = {kk: jnp.pad(
                vv, ((0, 0), (0, 0), (0, extra), (0, 0), (0, 0)))
                for kk, vv in val.items()}
        else:
            out[key] = val
    return out


@pytest.mark.parametrize("arch", [
    pytest.param("yi-9b", marks=pytest.mark.slow),
    pytest.param("codeqwen1.5-7b", marks=pytest.mark.slow),
    "starcoder2-15b",
    pytest.param("jamba-1.5-large-398b", marks=pytest.mark.slow),
    "xlstm-350m",
    pytest.param("grok-1-314b", marks=pytest.mark.slow),
    "pixtral-12b",
    pytest.param("musicgen-medium", marks=pytest.mark.slow),
])
def test_decode_matches_full_forward(arch):
    # capacity_factor high so MoE routing has no train/decode drop skew
    m = build_model(arch, RunConfig(capacity_factor=16.0), reduced=True)
    cfg = m.cfg
    params, _ = m.init(jax.random.key(0))
    B, S = 2, 8
    key = jax.random.key(1)
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)

    def mk(tokens):
        b = {"tokens": tokens}
        if cfg.frontend:
            b["embeds"] = jnp.zeros(
                (tokens.shape[0], tokens.shape[1], cfg.frontend_dim))
        return b

    last_logits, cache = m.prefill(params, mk(toks[:, :S]))
    full_logits = m.forward_logits(params, mk(toks[:, :S]))
    assert jnp.allclose(last_logits, full_logits[:, -1], atol=1e-4), arch

    cache = _grow_attn_cache(cache, 1)
    step_logits, new_cache = m.decode_step(
        params, mk(toks[:, S:S + 1]), cache, jnp.int32(S))
    ref = m.forward_logits(params, mk(toks))[:, -1]
    err = float(jnp.max(jnp.abs(step_logits - ref)))
    assert err < 1e-3, (arch, err)
    # cache structurally intact
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_multi_step_decode_matches_full():
    m = build_model("yi-9b", reduced=True)
    cfg = m.cfg
    params, _ = m.init(jax.random.key(0))
    B, S, extra = 2, 6, 3
    toks = jax.random.randint(jax.random.key(1), (B, S + extra), 0,
                              cfg.vocab_size)
    _, cache = m.prefill(params, {"tokens": toks[:, :S]})
    cache = _grow_attn_cache(cache, extra)
    for i in range(extra):
        logits, cache = m.decode_step(
            params, {"tokens": toks[:, S + i:S + i + 1]}, cache,
            jnp.int32(S + i))
    ref = m.forward_logits(params, {"tokens": toks})[:, -1]
    assert jnp.allclose(logits, ref, atol=1e-3)
