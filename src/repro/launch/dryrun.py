"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract memory / cost / collective-roofline evidence.

MUST set XLA_FLAGS before any other import (jax locks the device count on
first init).  Only this entry point forces 512 host devices — smoke tests
and benches see the real single CPU device.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import dataclasses
import json
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig, InputShape, get_arch, list_archs
from repro.core.stream_config import StreamConfig
from repro.core.streams import streamify_train_step
from repro.core.xla_cost import cost_analysis_dict
from repro.launch.mesh import dp_axes_of, make_production_mesh
from repro.models.model_zoo import Model
from repro.models.transformer import RunConfig
from repro.optim import optimizer as opt_lib
from repro.parallel.sharding_rules import AxisRules, tree_specs
from repro.roofline.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                     RooflineTerms, collective_bytes)
from repro.roofline.jaxpr_cost import step_cost


@dataclasses.dataclass
class DryRunOptions:
    multi_pod: bool = False
    remat: str = "dots"
    fsdp: bool = True
    fsdp_over_pod: bool = False
    microbatches: int = 1
    opt_dtype: str = "f32"           # f32 | bf16
    capacity_factor: float = 1.25
    q_block: int = 1024
    kv_block: int = 1024
    moe_group: int = 512
    scan_layers: bool = True
    donate: bool = True
    dp_over_model: bool = False  # no TP: 'model' axis as extra DP (§Perf)

    def tag(self) -> str:
        bits = []
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if v != f.default:
                bits.append(f"{f.name}={v}")
        return ",".join(bits) or "baseline"


def build_rules(cfg: ArchConfig, shape: InputShape, mesh,
                opts: DryRunOptions) -> AxisRules:
    model_size = mesh.shape["model"]
    dp_size = 1
    for a in dp_axes_of(mesh):
        dp_size *= mesh.shape[a]
    rules = AxisRules.pod(
        multi_pod=opts.multi_pod,
        fsdp=opts.fsdp,
        fsdp_over_pod=opts.fsdp_over_pod,
        shard_heads=(cfg.num_heads % model_size == 0),
        shard_kv_heads=(cfg.num_kv_heads % model_size == 0),
        tp=not opts.dp_over_model,
    )
    if opts.dp_over_model:
        dp_size *= model_size
    if shape.global_batch % dp_size:
        # long_500k (B=1): batch replicated, sequence still model-sharded.
        r = dict(rules.rules)
        r["batch"] = None
        r["cache_batch"] = None
        rules = AxisRules(rules=r)
    return rules


def build_cell(arch: str, shape_name: str, mesh, opts: DryRunOptions):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if shape not in cfg.shapes():
        raise SystemExit(
            f"SKIP {arch} x {shape_name}: full-attention arch, 500k dense "
            f"KV cache is a non-goal (DESIGN.md §Arch-applicability)")
    rules = build_rules(cfg, shape, mesh, opts)
    dp = dp_axes_of(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if shape.global_batch % dp_size:
        dp = ()  # batch replicated (long_500k B=1)
    rcfg = RunConfig(
        param_dtype=jnp.bfloat16,
        compute_dtype=jnp.bfloat16,
        cache_dtype=jnp.bfloat16,
        rules=rules,
        q_block=opts.q_block,
        kv_block=opts.kv_block,
        remat=opts.remat if shape.kind == "train" else "none",
        capacity_factor=opts.capacity_factor,
        decode_attn="sharded",
        mesh=mesh,
        dp_axes=dp,
        scan_layers=opts.scan_layers,
        moe_group_size=opts.moe_group,
        attn_expand_kv=True,
    )
    model = Model(cfg, rcfg)

    param_sds, param_axes = model.abstract_params()
    pspec = tree_specs(param_axes, rules)
    psharding = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec)

    batch_sds = model.input_specs(shape)
    bspec = {}
    for k, v in batch_sds.items():
        bspec[k] = NamedSharding(mesh, rules.spec(
            ("batch",) + ("seq",) * 0 + (None,) * (len(v.shape) - 1)))

    if shape.kind == "train":
        ocfg = opt_lib.AdamWConfig(
            state_dtype=jnp.bfloat16 if opts.opt_dtype == "bf16"
            else jnp.float32)
        opt_sds = jax.eval_shape(
            lambda p: opt_lib.init_state(p, ocfg), param_sds)
        opt_axes = opt_lib.state_logical_axes(param_axes, ocfg)
        ospec = {
            "step": NamedSharding(mesh, P()),
            "m": jax.tree.map(lambda s: NamedSharding(mesh, s),
                              tree_specs(opt_axes["m"], rules)),
            "v": jax.tree.map(lambda s: NamedSharding(mesh, s),
                              tree_specs(opt_axes["v"], rules)),
        }

        grad_fn = streamify_train_step(
            lambda p, b: model.loss(p, b),
            StreamConfig(1, opts.microbatches))

        def train_step(params, opt_state, batch):
            loss, metrics, grads = grad_fn(params, batch)
            params, opt_state, om = opt_lib.apply_updates(
                params, grads, opt_state, ocfg)
            return params, opt_state, loss

        fn = jax.jit(
            train_step,
            in_shardings=(psharding, ospec, bspec),
            out_shardings=(psharding, ospec, NamedSharding(mesh, P())),
            donate_argnums=(0, 1) if opts.donate else (),
        )
        args = (param_sds, opt_sds, batch_sds)

    elif shape.kind == "prefill":
        fn = jax.jit(
            model.forward_logits,
            in_shardings=(psharding, bspec),
        )
        args = (param_sds, batch_sds)

    else:  # decode
        cache_sds = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        cache_axes = model.cache_axes()
        cspec = jax.tree.map(lambda s: NamedSharding(mesh, s),
                             tree_specs(cache_axes, rules))
        t_sds = jax.ShapeDtypeStruct((), jnp.int32)

        def serve_step(params, batch, cache, t):
            return model.decode_step(params, batch, cache, t)

        fn = jax.jit(
            serve_step,
            in_shardings=(psharding, bspec, cspec, NamedSharding(mesh, P())),
            donate_argnums=(2,) if opts.donate else (),
        )
        args = (param_sds, batch_sds, cache_sds, t_sds)

    return fn, args, cfg, shape


def _mem_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    # memory_analysis is optional across backends/versions
    except Exception:  # noqa: BLE001
        return {}
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _sharded_bytes(sds_tree, sharding_tree, mesh) -> int:
    """Estimated per-device bytes of a sharded pytree (mesh-independent
    fallback when the backend's memory_analysis is unavailable)."""
    total = 0
    for sds, sh in zip(jax.tree.leaves(sds_tree), jax.tree.leaves(sharding_tree)):
        shard_shape = sh.shard_shape(sds.shape)
        total += int(np.prod(shard_shape)) * sds.dtype.itemsize
    return total


def run_cell(arch: str, shape_name: str, opts: DryRunOptions,
             *, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=opts.multi_pod)
    record: dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape)
                + f" ({','.join(mesh.axis_names)})",
        "n_chips": int(mesh.size),
        "options": opts.tag(),
    }
    with mesh:
        fn, args, cfg, shape = build_cell(arch, shape_name, mesh, opts)
        t0 = time.time()
        lowered = fn.lower(*args)
        record["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t0, 2)

        mem = _mem_dict(compiled)
        record["memory_analysis"] = mem
        hlo = compiled.as_text()
        record["hlo_bytes"] = len(hlo)
        # collective term: loop-aware parse of the post-SPMD per-chip module
        coll = collective_bytes(hlo)
        record["collective_bytes"] = {
            k: int(v) for k, v in coll.items() if k != "counts"}
        record["collective_counts"] = coll["counts"]
        # compute/memory terms: jaxpr walker (exact scan trip counts),
        # global logical cost / n_chips
        t0 = time.time()
        jc = step_cost(fn, *args)
        record["jaxpr_cost_s"] = round(time.time() - t0, 2)
        flops_chip = jc.flops / mesh.size
        bytes_chip = jc.bytes_fused / mesh.size
        terms = RooflineTerms(
            compute_s=flops_chip / PEAK_FLOPS,
            memory_s=bytes_chip / HBM_BW,
            collective_s=coll["total"] / ICI_BW,
            flops_per_chip=flops_chip,
            bytes_per_chip=bytes_chip,
            coll_bytes_per_chip=float(coll["total"]),
            model_flops=cfg.model_flops(shape),
            n_chips=mesh.size,
        )
        record["roofline"] = terms.as_dict()
        record["roofline"]["bytes_raw_per_chip"] = jc.bytes / mesh.size
        record["roofline"]["memory_raw_s"] = jc.bytes / mesh.size / HBM_BW
        # XLA's own (loop-body-once) numbers kept for reference
        cost = cost_analysis_dict(compiled)
        record["xla_cost_analysis"] = {
            k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float)) and k in
            ("flops", "bytes accessed", "transcendentals")}
    if verbose:
        print(json.dumps(record, indent=1))
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--remat", default="dots", choices=["none", "dots", "full"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--fsdp-over-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--opt-dtype", default="f32", choices=["f32", "bf16"])
    ap.add_argument("--capacity-factor", type=float, default=1.25)
    ap.add_argument("--q-block", type=int, default=1024)
    ap.add_argument("--kv-block", type=int, default=1024)
    ap.add_argument("--moe-group", type=int, default=512)
    ap.add_argument("--no-scan", action="store_true")
    ap.add_argument("--no-donate", action="store_true")
    ap.add_argument("--dp-over-model", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    opts = DryRunOptions(
        multi_pod=args.multi_pod, remat=args.remat, fsdp=not args.no_fsdp,
        fsdp_over_pod=args.fsdp_over_pod, microbatches=args.microbatches,
        opt_dtype=args.opt_dtype, capacity_factor=args.capacity_factor,
        q_block=args.q_block, kv_block=args.kv_block,
        moe_group=args.moe_group, scan_layers=not args.no_scan,
        donate=not args.no_donate, dp_over_model=args.dp_over_model)

    record = run_cell(args.arch, args.shape, opts)
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(record, f, indent=1)


if __name__ == "__main__":
    main()
