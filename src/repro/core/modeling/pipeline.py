"""The shared feature pipeline (paper §3.2.1-§3.2.2):

  raw program features ++ config encoding
    -> Z-score standardization
    -> correlation pruning (|Pearson rho| > 0.7 drops the later feature)
    -> PCA (9 components; paper: "PCA with 9 components gives the best
       overall result")
  target: speedup over single-stream, Z-score standardized.

Every estimator kind front-ends its learner with one of these; the
artifact layer serializes it to a flat array dict so a saved model
carries its input space with it.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class FeaturePipeline:
    mean: np.ndarray
    std: np.ndarray
    keep_idx: np.ndarray          # surviving columns after pruning
    pca_components: np.ndarray    # (kept, n_comp)
    pca_mean: np.ndarray
    y_mean: float
    y_std: float

    @staticmethod
    def fit(X: np.ndarray, y: np.ndarray, *, n_components: int = 9,
            corr_threshold: float = 0.7) -> "FeaturePipeline":
        X = np.asarray(X, dtype=np.float64)
        mean = X.mean(axis=0)
        std = X.std(axis=0)
        constant = std < 1e-12
        std = np.where(constant, 1.0, std)
        Z = (X - mean) / std

        # correlation pruning: keep the earlier feature of any |rho|>0.7
        # pair.  Constant columns are dropped outright — they carry no
        # signal, and their NaN correlations (masked to 0 below) would
        # otherwise always survive the pruning rule.
        n = Z.shape[1]
        with np.errstate(divide="ignore", invalid="ignore"):
            corr = np.corrcoef(Z, rowvar=False)
        corr = np.nan_to_num(np.atleast_2d(corr))
        keep: list[int] = []
        for j in range(n):
            if constant[j]:
                continue
            if all(abs(corr[j, i]) <= corr_threshold for i in keep):
                keep.append(j)
        if not keep:      # fully degenerate input: keep one column so the
            keep = [0]    # transform still produces a well-formed matrix
        keep_idx = np.array(keep, dtype=np.int64)
        Zk = Z[:, keep_idx]

        # PCA, clamped to the numerical rank: with constant columns or
        # n_samples < n_components the trailing singular vectors span the
        # null space — arbitrary axes (sign/permutation unstable across
        # BLAS builds) that would inject pure noise dimensions
        pca_mean = Zk.mean(axis=0)
        Zc = Zk - pca_mean
        _, s, vt = np.linalg.svd(Zc, full_matrices=False)
        tol = (float(s[0]) if s.size else 0.0) \
            * max(Zc.shape) * np.finfo(np.float64).eps
        rank = int(np.sum(s > max(tol, 1e-12)))
        n_comp = max(1, min(n_components, Zk.shape[1], max(rank, 1)))
        components = vt[:n_comp].T  # (kept, n_comp)

        y = np.asarray(y, dtype=np.float64)
        y_mean, y_std = float(y.mean()), float(max(y.std(), 1e-9))
        return FeaturePipeline(mean, std, keep_idx, components, pca_mean,
                               y_mean, y_std)

    def transform(self, X: np.ndarray) -> np.ndarray:
        Z = (X - self.mean) / self.std
        Zk = Z[:, self.keep_idx]
        return (Zk - self.pca_mean) @ self.pca_components

    def transform_y(self, y: np.ndarray) -> np.ndarray:
        return (y - self.y_mean) / self.y_std

    def inverse_y(self, yn: np.ndarray) -> np.ndarray:
        return yn * self.y_std + self.y_mean

    # -- artifact serialization ----------------------------------------------

    def to_arrays(self, prefix: str = "pipe.") -> dict:
        """Flat float64/int64 array dict (npz-ready); scalars become 0-d
        arrays so the round-trip is bit-exact, not JSON-float-exact."""
        return {
            f"{prefix}mean": np.asarray(self.mean, np.float64),
            f"{prefix}std": np.asarray(self.std, np.float64),
            f"{prefix}keep_idx": np.asarray(self.keep_idx, np.int64),
            f"{prefix}pca_components": np.asarray(self.pca_components,
                                                  np.float64),
            f"{prefix}pca_mean": np.asarray(self.pca_mean, np.float64),
            f"{prefix}y_mean": np.asarray(self.y_mean, np.float64),
            f"{prefix}y_std": np.asarray(self.y_std, np.float64),
        }

    @staticmethod
    def from_arrays(arrays: dict, prefix: str = "pipe.") -> "FeaturePipeline":
        return FeaturePipeline(
            mean=arrays[f"{prefix}mean"],
            std=arrays[f"{prefix}std"],
            keep_idx=arrays[f"{prefix}keep_idx"],
            pca_components=arrays[f"{prefix}pca_components"],
            pca_mean=arrays[f"{prefix}pca_mean"],
            y_mean=float(arrays[f"{prefix}y_mean"]),
            y_std=float(arrays[f"{prefix}y_std"]),
        )

    @property
    def n_features_in(self) -> int:
        return int(self.mean.shape[0])
