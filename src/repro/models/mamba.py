"""Mamba selective-SSM block (arXiv:2312.00752) in pure JAX.

Train/prefill run the selective scan with ``jax.lax.scan`` over time;
decode is a single recurrence step carrying (conv_state, ssm_state).
The inner expanded dim E = expand*d_model is tensor-parallel ('inner').
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models import layers
from repro.parallel.sharding_rules import AxisRules


def _dt_rank(d_model: int, cfg: SSMConfig) -> int:
    return cfg.dt_rank or -(-d_model // 16)


def mamba_init(key, d_model: int, cfg: SSMConfig, dtype=jnp.float32) -> dict:
    E = cfg.expand * d_model
    N = cfg.state_dim
    R = _dt_rank(d_model, cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A.
    a = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (E, 1))
    return {
        "in_proj": layers.dense_init(ks[0], (d_model, 2 * E), ("embed", "inner"), dtype),
        "conv_w": layers.dense_init(ks[1], (cfg.conv_width, E), ("conv", "inner"), dtype,
                                    fan_in=cfg.conv_width),
        "conv_b": layers.zeros_init((E,), ("inner",), dtype),
        "x_proj": layers.dense_init(ks[2], (E, R + 2 * N), ("inner", None), dtype),
        "dt_proj": layers.dense_init(ks[3], (R, E), (None, "inner"), dtype),
        "dt_bias": layers.zeros_init((E,), ("inner",), dtype),
        "A_log": layers.Leaf(jnp.log(a).astype(jnp.float32), ("inner", "ssm_state")),
        "D": layers.ones_init((E,), ("inner",), jnp.float32),
        "out_proj": layers.dense_init(ks[4], (E, d_model), ("inner", "embed"), dtype,
                                      fan_in=E),
    }


def _conv_causal(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x (B,S,E), w (W,E) -> (B,S,E)."""
    W = w.shape[0]
    lhs = jnp.moveaxis(x, 1, 2)  # (B,E,S)
    rhs = jnp.moveaxis(w, 1, 0)[:, None, :]  # (E,1,W)
    out = jax.lax.conv_general_dilated(
        lhs.astype(jnp.float32), rhs.astype(jnp.float32),
        window_strides=(1,), padding=[(W - 1, 0)],
        feature_group_count=x.shape[-1],
        dimension_numbers=("NCH", "OIH", "NCH"),
    )
    return (jnp.moveaxis(out, 2, 1) + b).astype(x.dtype)


def _ssm_params(params, xc, d_model, cfg):
    """xc (..., E) -> dt (..., E), Bp (..., N), Cp (..., N)."""
    N = cfg.state_dim
    R = _dt_rank(d_model, cfg)
    dbc = jnp.einsum("...e,er->...r", xc, params["x_proj"])
    dt_x, Bp, Cp = jnp.split(dbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("...r,re->...e", dt_x, params["dt_proj"]) + params["dt_bias"])
    return dt.astype(jnp.float32), Bp.astype(jnp.float32), Cp.astype(jnp.float32)


def mamba_apply(params: dict, x: jax.Array, cfg: SSMConfig, rules: AxisRules,
                *, ssm_state=None, conv_state=None, return_state: bool = False):
    """x (B,S,D). With states given (decode), S must be 1.

    Returns y (B,S,D) and, if return_state, (ssm_state, conv_state).
    """
    B, S, D = x.shape
    E = cfg.expand * D
    N = cfg.state_dim
    W = cfg.conv_width

    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    xz = rules.constrain(xz, "batch", "seq", "inner")
    x1, z = jnp.split(xz, 2, axis=-1)

    A = -jnp.exp(params["A_log"])  # (E,N)

    if ssm_state is None:
        # --- full-sequence path -------------------------------------------
        xc = jax.nn.silu(_conv_causal(x1, params["conv_w"], params["conv_b"]))
        dt, Bp, Cp = _ssm_params(params, xc, D, cfg)  # (B,S,E),(B,S,N),(B,S,N)
        xcf = xc.astype(jnp.float32)

        def step(h, inp):
            dt_t, B_t, C_t, x_t = inp  # (B,E),(B,N),(B,N),(B,E)
            dA = jnp.exp(dt_t[..., None] * A)                     # (B,E,N)
            dBx = dt_t[..., None] * B_t[:, None, :] * x_t[..., None]
            h = h * dA + dBx
            y = jnp.einsum("ben,bn->be", h, C_t)
            return h, y

        h0 = jnp.zeros((B, E, N), jnp.float32)
        xs = (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(Bp, 1, 0),
              jnp.moveaxis(Cp, 1, 0), jnp.moveaxis(xcf, 1, 0))
        h_last, ys = jax.lax.scan(step, h0, xs)
        y = jnp.moveaxis(ys, 0, 1) + params["D"] * xcf            # (B,S,E)
        new_conv = None
        if return_state:
            # last W-1 pre-conv inputs
            pad = jnp.zeros((B, max(W - 1 - S, 0), E), x1.dtype)
            new_conv = jnp.concatenate([pad, x1[:, -(W - 1):]], axis=1)
        new_ssm = h_last
    else:
        # --- single-step decode -------------------------------------------
        assert S == 1
        window = jnp.concatenate([conv_state, x1], axis=1)        # (B,W,E)
        xc = jnp.einsum("bwe,we->be", window.astype(jnp.float32),
                        params["conv_w"].astype(jnp.float32)) + params["conv_b"]
        xc = jax.nn.silu(xc)                                      # (B,E)
        dt, Bp, Cp = _ssm_params(params, xc, D, cfg)
        dA = jnp.exp(dt[..., None] * A)
        dBx = dt[..., None] * Bp[:, None, :] * xc.astype(jnp.float32)[..., None]
        new_ssm = ssm_state * dA + dBx
        y = jnp.einsum("ben,bn->be", new_ssm, Cp) + params["D"] * xc
        y = y[:, None, :]                                         # (B,1,E)
        new_conv = window[:, 1:]

    y = (y.astype(x.dtype) * jax.nn.silu(z))
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    out = rules.constrain(out, "batch", "seq", "embed_act")
    if return_state:
        return out, new_ssm, new_conv
    return out


def mamba_state_shapes(batch: int, d_model: int, cfg: SSMConfig):
    E = cfg.expand * d_model
    return {
        "ssm": (batch, E, cfg.state_dim),
        "conv": (batch, cfg.conv_width - 1, E),
    }
