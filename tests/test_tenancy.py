"""Per-tenant serving isolation and the load-aware drift signal:
namespaced cache keys, per-tenant drift windows and model
fork-on-refit (tenant A's refinement never touches tenant B's cache
entry or model), contention-factor arithmetic, zero spurious
refinements under pure contention, and fair-across-tenants queue
determinism."""
import dataclasses

import numpy as np
import pytest

from repro.core.autotuner import TuningCache
from repro.core.perf_model import PerformanceModel
from repro.core.workloads import get_workload
from repro.serving import (AdaptiveScheduler, ConcurrentScheduler,
                           DriftDetector, RequestQueue, TelemetryLog,
                           TenantRegistry, WorkloadRequest,
                           contention_factor)


class _CalibratedStub:
    """Speedup 1.0 for every config: the stable-sorted search picks
    single-stream and predicted runtime == the profiled single-stream
    anchor, so natural drift stays near zero."""

    def predict_configs(self, feats, candidates):
        F = np.atleast_2d(np.asarray(feats))
        preds = np.ones((F.shape[0], len(candidates)))
        return preds[0] if np.ndim(feats) == 1 else preds


class _RefittableStub(_CalibratedStub):
    """Refit-capable (so tenancy forks it via deepcopy) and recording —
    the cross-tenant refit-isolation witness."""

    def __init__(self):
        self.refit_calls = []

    def refit(self, X, y, **kw):
        self.refit_calls.append(np.atleast_2d(X).shape[0])
        return 0.0


def _req(workload="vecadd", rows=256, seed=0, **kw):
    wl = get_workload(workload)
    chunked, shared = wl.make_data(rows, np.random.default_rng(seed))
    return WorkloadRequest(workload=workload, chunked=chunked,
                          shared=shared, **kw)


def _poison(sched, tenant, workload="vecadd", rows=256, factor=40.0):
    """Inflate a tenant's cached predicted speedup so its predicted
    runtime is ~``factor``x too small — deterministic injected drift."""
    wl = get_workload(workload)
    chunked, shared = wl.make_data(rows, np.random.default_rng(0))
    ns = sched.tenancy.namespace(tenant)
    key = sched.cache.key(workload, chunked, shared, sched.backend_name,
                          sched.model_tag, namespace=ns)
    entry = sched.cache.get(key)
    assert entry is not None
    sched.cache.put(key, dataclasses.replace(
        entry, predicted_speedup=entry.predicted_speedup * factor))
    return key, entry


# -- namespaced cache keys ----------------------------------------------------


def test_cache_key_namespace_prefix_and_legacy_format():
    wl = get_workload("vecadd")
    chunked, shared = wl.make_data(64, np.random.default_rng(0))
    plain = TuningCache.key("vecadd", chunked, shared, "host-sync")
    spaced = TuningCache.key("vecadd", chunked, shared, "host-sync",
                             namespace="tenant-a")
    # empty namespace == the exact pre-tenancy key, so persisted caches
    # from before isolation keep hitting
    assert not plain.startswith("tenant:")
    assert spaced == f"tenant:tenant-a|{plain}"
    assert TuningCache.key("vecadd", chunked, shared, "host-sync",
                           namespace="tenant-b") != spaced


def test_registry_shared_until_isolation_requested():
    drift = DriftDetector(threshold=2.0)
    shared = TenantRegistry(object(), drift, isolate=False)
    assert shared.get("a") is shared.get("b")
    assert shared.get("a").drift is drift          # scheduler's detector
    assert shared.namespace("a") == ""
    assert len(shared) == 0

    iso = TenantRegistry(object(), drift, isolate=True)
    a, b = iso.get("a"), iso.get("b")
    assert a is not b and iso.get("a") is a
    assert a.drift is not drift and a.drift is not b.drift
    assert a.drift.threshold == drift.threshold    # cloned template rules
    assert iso.namespace("a") == "a"
    assert len(iso) == 2


# -- load-normalized drift arithmetic -----------------------------------------


def test_contention_factor_arithmetic():
    # serial / no-capacity cases never scale
    assert contention_factor(1, 2.0) == 1.0
    assert contention_factor(4, None) == 1.0
    # k requests on a host scaling by C: each runs k/C slower
    assert contention_factor(4, 2.0) == pytest.approx(2.0)
    assert contention_factor(8, 2.0, workers=4) == pytest.approx(2.0)
    # overlap never *deflates* a measurement
    assert contention_factor(2, 4.0) == 1.0


def test_serial_scheduler_records_unit_load():
    sched = AdaptiveScheduler(_CalibratedStub())
    sched.submit_all([_req(seed=0), _req(seed=1)])
    for r in sched.run():
        assert r.sample.inflight == 1
        assert r.sample.load_factor == 1.0
        assert r.sample.measured_norm_s == pytest.approx(r.measured_s)


def test_engine_normalizes_measured_by_occupancy():
    eng = ConcurrentScheduler(_CalibratedStub(), window=4, capacity=1.0,
                              drift=DriftDetector(threshold=1e9))
    eng.submit_all([_req(seed=s) for s in range(6)])
    results = eng.run()
    eng.close()
    for r in results:
        s = r.sample
        assert s.load_factor == pytest.approx(
            contention_factor(s.inflight, 1.0, eng.workers))
        assert s.measured_norm_s == pytest.approx(
            s.measured_s / s.load_factor)
    # the window did actually overlap requests
    assert max(r.sample.inflight for r in results) > 1


def test_no_spurious_refinements_under_pure_contention():
    """Acceptance: window=8, no real drift — wall time inflated purely
    by contention must trigger ZERO refinements with the load-aware
    detector, while the raw-wall-time detector (load_aware=False) fires
    spuriously on the same trace."""

    class _InflatedEngine(ConcurrentScheduler):
        # simulate pure contention deterministically: a request that
        # shared the window with k-1 others takes exactly k times its
        # (calibrated) predicted runtime
        def _execute(self, pending):
            outs, _ = super()._execute(pending)
            pred = self._predicted_runtime(pending.key, pending.entry)
            assert pred is not None
            return outs, pred * pending.inflight

    def run_trace(load_aware):
        eng = _InflatedEngine(
            _CalibratedStub(), window=8, capacity=1.0,
            load_aware=load_aware,
            drift=DriftDetector(window=8, threshold=0.75, min_samples=2),
            keep_outputs=False)
        eng.submit_all([_req(seed=s) for s in range(12)])
        eng.run()
        eng.close()
        return eng

    aware = run_trace(load_aware=True)
    assert aware.stats["refinements"] == 0
    errs = [s.rel_error for s in aware.telemetry]
    assert max(errs) == pytest.approx(0.0, abs=1e-9)

    raw = run_trace(load_aware=False)
    assert raw.stats["refinements"] >= 1       # contention read as drift


# -- tenant isolation ---------------------------------------------------------


class _SyntheticSerial(AdaptiveScheduler):
    """Real pipeline, synthetic wall time: every request 'measures'
    exactly its single-stream anchor, so a calibrated bucket has zero
    drift BY CONSTRUCTION and a poisoned one a huge, deterministic
    error — no box-noise flakes."""

    def _execute(self, pending):
        outs, _ = super()._execute(pending)
        return outs, self._t_single[pending.key]


def test_refinement_stays_inside_the_drifting_tenant_serial():
    """Tenant A's poisoned bucket refines; tenant B's cache entry and
    drift windows are untouched, and the shared base model is never
    refitted — A refits its own fork."""
    base = _RefittableStub()
    sched = _SyntheticSerial(
        base, isolate_tenants=True,
        drift=DriftDetector(window=8, threshold=6.0, min_samples=2,
                            cooldown=2))
    # one cold round per tenant, same workload bucket
    sched.submit_all([_req(seed=0, tenant="a"), _req(seed=1, tenant="b")])
    sched.run()

    key_a, _ = _poison(sched, "a")
    key_b = sched.cache.key("vecadd",
                            *(lambda r: (r.chunked, r.shared))(_req(seed=9)),
                            sched.backend_name, namespace="b")
    entry_b_before = sched.cache.get(key_b)
    assert entry_b_before is not None

    for s in range(10, 16):
        sched.submit(_req(seed=s, tenant="a"))
        sched.submit(_req(seed=s + 10, tenant="b"))
    post = sched.run()

    assert sched.stats["refinements"] == 1
    assert sched.stats["tenant.a.refinements"] == 1
    assert sched.stats["tenant.b.refinements"] == 0
    assert [r.refined for r in post if r.request.tenant == "b"] \
        == [False] * 6
    # B's entry object is untouched; A's was refreshed with measured
    # provenance
    assert sched.cache.get(key_b) is entry_b_before
    assert sched.cache.get(key_a).source == "refined"
    # model isolation: the shared base was NEVER refitted; tenant A
    # refitted its own deepcopy fork, B still serves from the base
    ctx_a, ctx_b = sched.tenancy.get("a"), sched.tenancy.get("b")
    assert base.refit_calls == []
    assert ctx_a.forked and ctx_a.active_model.refit_calls
    assert not ctx_b.forked and ctx_b.active_model is base
    # per-tenant telemetry aggregates see the same split
    per_tenant = sched.telemetry.summary()["per_tenant"]
    assert per_tenant["a"]["refinements"] == 1
    assert per_tenant["b"]["refinements"] == 0


def test_drift_divergent_tenants_concurrent_engine():
    """Acceptance: two tenants running drift-divergent workloads
    concurrently — refinement fires only for the drifting tenant.

    Execution is synthetic-contended (wall time = anchor x occupancy,
    which the load-aware normalization divides back out exactly), so
    tenant B's healthy bucket shows zero drift by construction and
    tenant A's poisoned prediction a deterministic ~79x error — the
    test isolates tenancy routing, with no real-box timing noise."""

    class _SyntheticContended(ConcurrentScheduler):
        def _execute(self, pending):
            outs, _ = super()._execute(pending)
            return outs, self._t_single[pending.key] * pending.inflight

    eng = _SyntheticContended(
        _CalibratedStub(), window=4, capacity=1.0, isolate_tenants=True,
        drift=DriftDetector(window=8, threshold=6.0, min_samples=2,
                            cooldown=2),
        keep_outputs=False)
    eng.submit_all([_req(seed=0, tenant="a"), _req(seed=1, tenant="b")])
    eng.run()
    key_a, _ = _poison(eng, "a", factor=80.0)

    reqs = []
    for s in range(20, 26):
        reqs.append(_req(seed=s, tenant="a"))
        reqs.append(_req(seed=s + 10, tenant="b"))
    eng.submit_all(reqs)
    eng.run()
    eng.close()

    assert eng.stats["tenant.a.refinements"] >= 1
    assert eng.stats["tenant.b.refinements"] == 0
    assert eng.tenancy.get("b").refinements == 0
    assert eng.cache.get(key_a).source == "refined"
    # engine invariants survived the deferred refinement path
    assert eng.retirer.held == 0
    b_samples = [s for s in eng.telemetry if s.tenant == "b"]
    assert b_samples and all(s.source == "model" for s in b_samples)


def test_non_isolated_refit_lands_on_the_callers_model():
    """Pre-tenancy contract: without isolation, online refits move the
    model object the caller handed in — no hidden fork."""
    base = _RefittableStub()
    sched = _SyntheticSerial(
        base, drift=DriftDetector(window=8, threshold=6.0, min_samples=2,
                                  cooldown=2))
    sched.submit_all([_req(seed=0, tenant="a"), _req(seed=1, tenant="b")])
    sched.run()
    _poison(sched, "a")            # empty namespace: the shared bucket
    sched.submit_all([_req(seed=s, tenant="b") for s in range(5, 9)])
    sched.run()
    assert sched.stats["refinements"] == 1
    assert base.refit_calls           # refit hit the caller's object...
    ctx = sched.tenancy.get("anyone")
    assert not ctx.forked             # ...not a hidden fork
    assert ctx.active_model is base


def test_isolated_tenants_do_not_share_warm_entries():
    sched = AdaptiveScheduler(_CalibratedStub(), isolate_tenants=True)
    sched.submit_all([_req(seed=0, tenant="a"), _req(seed=1, tenant="b"),
                      _req(seed=2, tenant="a"), _req(seed=3, tenant="b")])
    results = sched.run()
    # each tenant's first sight of the bucket is its own cold miss
    assert [r.cache_hit for r in results] == [False, False, True, True]
    assert sched.stats["model_searches"] == 2


def test_perf_model_fork_refit_isolated():
    rng = np.random.default_rng(0)
    X = rng.standard_normal((60, 25))
    y = X[:, 0] * 2.0 + 1.0
    base = PerformanceModel.train(X, y, epochs=60, seed=0)
    before = base.predict(X[:8]).copy()

    fork = base.fork()
    np.testing.assert_allclose(fork.predict(X[:8]), before)
    fork.refit(X[:16], y[:16] + 3.0, epochs=80, lr=3e-3)

    # the fork moved, the base did not
    assert not np.allclose(fork.predict(X[:8]), before)
    np.testing.assert_allclose(base.predict(X[:8]), before)


# -- fair-across-tenants queue ------------------------------------------------


def test_fair_queue_rotation_is_deterministic_across_tenants():
    q = RequestQueue("fair")
    order_in = [("a", 0), ("b", 1), ("a", 2), ("c", 3), ("b", 4), ("a", 5)]
    for tenant, seed in order_in:
        q.push(_req(tenant=tenant, seed=seed))
    assert q.pending_by_tenant() == {"a": 3, "b": 2, "c": 1}
    # round-robin across tenants, arrival order within each
    served = [(r.tenant, r.seq) for r in (q.pop() for _ in range(6))]
    assert served == [("a", 0), ("b", 1), ("c", 3),
                      ("a", 2), ("b", 4), ("a", 5)]
    assert q.pending_by_tenant() == {}


def test_fair_queue_serves_each_tenant_once_per_rotation():
    q = RequestQueue("fair")
    tenants = ["t0", "t1", "t2", "t3"]
    for i in range(16):                       # 4 requests per tenant
        q.push(_req(tenant=tenants[i % 4], seed=i))
    for _ in range(4):                        # while all stay non-empty
        window = [q.pop().tenant for _ in range(4)]
        assert sorted(window) == sorted(tenants)


def test_pending_by_tenant_other_policies():
    for policy in ("fifo", "priority"):
        q = RequestQueue(policy)
        q.push(_req(tenant="x", seed=0))
        q.push(_req(tenant="y", seed=1, priority=3))
        q.push(_req(tenant="x", seed=2))
        assert q.pending_by_tenant() == {"x": 2, "y": 1}


# -- deterministic telemetry teardown -----------------------------------------


def test_telemetry_close_is_fsynced_and_idempotent(tmp_path):
    path = str(tmp_path / "t.jsonl")
    log = TelemetryLog(path)
    sched = AdaptiveScheduler(_CalibratedStub(), telemetry=log)
    with sched:
        sched.submit_all([_req(seed=0), _req(seed=1)])
        sched.run()
    assert log.closed
    sched.close()                              # idempotent
    back = TelemetryLog.read(path)
    assert len(back) == 2
    # every line parsed — a truncated tail would have raised above — and
    # the new load fields round-trip
    assert all(s.inflight == 1 and s.load_factor == 1.0 for s in back)


def test_engine_close_shuts_pool_and_telemetry(tmp_path):
    path = str(tmp_path / "e.jsonl")
    eng = ConcurrentScheduler(_CalibratedStub(), window=2, capacity=1.0,
                              telemetry=TelemetryLog(path),
                              keep_outputs=False)
    with eng:
        eng.submit_all([_req(seed=s) for s in range(3)])
        eng.run()
    assert eng.telemetry.closed
    assert len(TelemetryLog.read(path)) == 3


def test_telemetry_log_context_manager(tmp_path):
    path = str(tmp_path / "cm.jsonl")
    sample = None
    with TelemetryLog(path) as log:
        from repro.serving import TelemetrySample
        sample = TelemetrySample(
            seq=1, tenant="a", workload="w", key="k", backend="b",
            partitions=1, tasks=1, cache_hit=False, predicted_s=1.0,
            measured_s=2.0, rel_error=1.0, inflight=3, load_factor=1.5,
            measured_norm_s=4.0 / 3.0)
        log.append(sample)
    assert TelemetryLog.read(path) == [sample]
