"""Request span tracing for the serving stack.

One :class:`Tracer` instance per scheduler records nested, named spans —
``decide``, ``tune.cold.batch``, ``dispatch``, ``retire``, ``refine`` —
each stamped from the *scheduler's own clock* (the tracer binds to the
injected clock at scheduler construction), so span timestamps, telemetry
latency stamps, and drift-window judgments can never disagree, and the
virtual-clock trace harness and the real concurrent engine share one
instrumentation code path.

Two recording APIs cover both worlds:

  ``span(name, ...)``    a context manager for live code (the real
      schedulers): enter/exit read the bound clock, nesting is tracked
      per thread (the engine's execute stage runs on pool workers), and
      the parent relationship is recorded explicitly;
  ``record(name, t0, t1, ...)``  an explicit-interval call for the
      discrete-event harness, whose stage intervals are computed on the
      virtual timeline rather than bracketed by real enter/exit.

Exports: ``export_jsonl`` (one span per line, greppable) and
``export_chrome`` — the Chrome trace-event format (``chrome://tracing``
/ https://ui.perfetto.dev): complete ``"ph": "X"`` events with
microsecond timestamps rebased to the trace start, one Perfetto track
per recording thread.

The disabled path must cost nothing: :data:`NULL_TRACER` is a process
singleton whose ``span()`` returns one shared no-op context manager —
no clock read, no allocation, no lock — so schedulers constructed
without a tracer (the default) keep their pre-observability hot path.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Optional

#: span name prefix -> attribution stage; ``stage_of("tune.cold.batch")``
#: is ``"tune"`` — the five-way split BENCH_overhead.json reports
STAGES = ("decide", "tune", "dispatch", "retire", "refine")


def stage_of(name: str) -> str:
    """The attribution stage a span name rolls up into (its first
    dot-component; unknown prefixes attribute to themselves)."""
    return name.split(".", 1)[0]


@dataclasses.dataclass
class SpanRecord:
    """One closed span.  ``t_start``/``t_end`` are seconds on the
    tracer's bound clock; ``cpu_s`` is thread CPU time consumed inside
    the span (None when the tracer was built with ``cpu=False`` or the
    span came from ``record()``)."""

    name: str
    t_start: float
    t_end: float
    tid: int = 0                    # dense per-tracer thread index
    trace_id: Optional[str] = None  # request correlation id
    parent: Optional[str] = None    # enclosing span's name (same thread)
    depth: int = 0                  # nesting depth on its thread
    cpu_s: Optional[float] = None
    attrs: Optional[dict] = None

    @property
    def duration_s(self) -> float:
        return self.t_end - self.t_start

    def to_json(self) -> dict:
        d = {"name": self.name, "t_start": self.t_start,
             "t_end": self.t_end, "tid": self.tid}
        if self.trace_id is not None:
            d["trace_id"] = self.trace_id
        if self.parent is not None:
            d["parent"] = self.parent
        if self.depth:
            d["depth"] = self.depth
        if self.cpu_s is not None:
            d["cpu_s"] = self.cpu_s
        if self.attrs:
            d["attrs"] = self.attrs
        return d


class _SpanCM:
    """A live span.  Created per ``span()`` call on an enabled tracer;
    enter stamps the clock (and optionally thread CPU time), exit closes
    the record and appends it to the tracer under its lock."""

    __slots__ = ("tracer", "name", "trace_id", "attrs",
                 "_t0", "_cpu0", "_frame")

    def __init__(self, tracer: "Tracer", name: str,
                 trace_id: Optional[str], attrs: Optional[dict]):
        self.tracer = tracer
        self.name = name
        self.trace_id = trace_id
        self.attrs = attrs

    def __enter__(self) -> "_SpanCM":
        stack = self.tracer._stack()
        self._frame = (self.name, len(stack))
        stack.append(self.name)
        self._cpu0 = time.thread_time() if self.tracer.cpu else None
        self._t0 = self.tracer.now()
        return self

    def __exit__(self, *exc) -> None:
        t1 = self.tracer.now()
        cpu = (time.thread_time() - self._cpu0
               if self._cpu0 is not None else None)
        stack = self.tracer._stack()
        stack.pop()
        name, depth = self._frame
        self.tracer._append(SpanRecord(
            name=name, t_start=self._t0, t_end=t1,
            tid=self.tracer._tid(),
            trace_id=self.trace_id,
            parent=stack[-1] if stack else None,
            depth=depth, cpu_s=cpu, attrs=self.attrs))


class _NullSpan:
    """The shared no-op span: zero clock reads, zero allocation."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Tracer:
    """Collects :class:`SpanRecord`\\ s from any thread.

    ``clock`` is any object with ``now() -> float``; leave it ``None``
    to have the owning scheduler bind its own clock at construction
    (the recommended wiring — one time source per scheduler).  An
    unbound tracer used standalone falls back to ``time.perf_counter``.

    ``cpu=True`` additionally records per-span *thread* CPU time
    (``time.thread_time``), the wall-vs-CPU split the hot-path profiler
    attributes Python overhead with.
    """

    enabled = True

    def __init__(self, clock=None, *, cpu: bool = False):
        self.clock = clock
        self.cpu = cpu
        self.spans: list[SpanRecord] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        self._tids: dict[int, int] = {}

    # -- time & thread bookkeeping ---------------------------------------

    def now(self) -> float:
        return (self.clock.now() if self.clock is not None
                else time.perf_counter())

    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def _append(self, rec: SpanRecord) -> None:
        with self._lock:
            self.spans.append(rec)

    # -- recording APIs ---------------------------------------------------

    def span(self, name: str, *, trace_id: Optional[str] = None,
             **attrs) -> _SpanCM:
        """Context manager bracketing one live stage."""
        return _SpanCM(self, name, trace_id, attrs or None)

    def record(self, name: str, t_start: float, t_end: float, *,
               trace_id: Optional[str] = None, tid: int = 0,
               parent: Optional[str] = None, **attrs) -> None:
        """Record an explicit interval — the discrete-event harness's
        API, whose stage boundaries live on the virtual timeline."""
        self._append(SpanRecord(
            name=name, t_start=t_start, t_end=t_end, tid=tid,
            trace_id=trace_id, parent=parent, attrs=attrs or None))

    def clear(self) -> None:
        with self._lock:
            self.spans = []

    def __len__(self) -> int:
        return len(self.spans)

    # -- exports ----------------------------------------------------------

    def export_jsonl(self, path: str) -> int:
        """One span per line; returns the span count written."""
        spans = list(self.spans)
        _ensure_dir(path)
        with open(path, "w") as f:
            for s in spans:
                f.write(json.dumps(s.to_json(), separators=(",", ":"))
                        + "\n")
        return len(spans)

    def export_chrome(self, path: str, *,
                      process_name: str = "repro-serving") -> int:
        """Chrome trace-event JSON (open in chrome://tracing or
        https://ui.perfetto.dev).  Timestamps are microseconds rebased
        to the earliest span, one track (tid) per recording thread;
        span attrs land in ``args``.  Returns the event count."""
        spans = sorted(self.spans, key=lambda s: (s.t_start, s.tid))
        t0 = spans[0].t_start if spans else 0.0
        events = [{"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
                   "args": {"name": process_name}}]
        for s in spans:
            args = dict(s.attrs or {})
            if s.trace_id is not None:
                args["trace_id"] = s.trace_id
            events.append({
                "name": s.name, "cat": stage_of(s.name), "ph": "X",
                "ts": (s.t_start - t0) * 1e6,
                "dur": max(s.duration_s, 0.0) * 1e6,
                "pid": 1, "tid": s.tid, "args": args,
            })
        _ensure_dir(path)
        with open(path, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"},
                      f, separators=(",", ":"))
        return len(spans)


class NullTracer:
    """The disabled tracer: every ``span()`` hands back one shared no-op
    context manager (identity-asserted by the overhead micro-test), and
    nothing is ever recorded.  ``clock`` exists so the scheduler's
    bind-my-clock wiring is branch-free."""

    enabled = False

    def __init__(self):
        self.clock = None
        self.spans: list = []

    def span(self, name: str, *, trace_id=None, **attrs) -> _NullSpan:
        return _NULL_SPAN

    def record(self, *a, **k) -> None:
        pass

    def clear(self) -> None:
        pass

    def __len__(self) -> int:
        return 0

    def export_jsonl(self, path: str) -> int:
        return 0

    def export_chrome(self, path: str, **k) -> int:
        return 0


#: process-wide disabled tracer; schedulers default to this
NULL_TRACER = NullTracer()


def _ensure_dir(path: str) -> None:
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
