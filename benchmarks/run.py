"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Consumes the profiled
sample cache (generated on first run; a cached run takes ~2-4 min, a cold
run also profiles the 39-program suite).

    PYTHONPATH=src python -m benchmarks.run [--programs a,b] [--datasets N]
    PYTHONPATH=src python -m benchmarks.run --quick    # tiny subset

A dry-run roofline summary (from benchmarks/data/dryrun/*.json, produced
by benchmarks/dryrun_sweep.py) is appended when available.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.core import dataset as ds  # noqa: E402

from benchmarks import paper_figures as pf  # noqa: E402

QUICK_PROGRAMS = ["vecadd", "binomial", "sgemm", "jacobi-1d", "mri-q",
                  "blackscholes", "dotprod", "fwt"]


def dryrun_summary() -> list[str]:
    rows = []
    for path in sorted(glob.glob(os.path.join(
            ROOT, "benchmarks", "data", "dryrun", "*.json"))):
        try:
            with open(path) as f:
                d = json.load(f)
        except Exception:
            continue
        if "roofline" not in d:
            continue
        r = d["roofline"]
        rows.append(
            f"dryrun.{d['arch']}.{d['shape']}."
            f"{'pod2' if 'pod' in d['mesh'] else 'pod1'},"
            f"{r['bound_s']*1e6:.0f},"
            f"dominant={r['dominant']},frac={r['roofline_fraction']:.4f}"
            if "bound_s" in r else
            f"dryrun.{d['arch']}.{d['shape']},"
            f"{max(r['compute_s'], r['memory_s'], r['collective_s'])*1e6:.0f},"
            f"dominant={r['dominant']},frac={r['roofline_fraction']:.4f}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--programs", default=None)
    ap.add_argument("--datasets", type=int, default=3)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--reps", type=int, default=2)
    args = ap.parse_args()

    if args.programs:
        programs = args.programs.split(",")
    elif args.quick:
        programs = QUICK_PROGRAMS
    else:
        programs = None  # all 39

    samples = ds.generate(programs, datasets_per_program=args.datasets,
                          reps=args.reps, verbose=True)
    print(f"# {len(samples)} profiled samples over "
          f"{len({s.program for s in samples})} programs")
    print("name,us_per_call,derived")

    for row in pf.fig2_heatmap(samples):
        print(row)
    fig9_rows, summary = pf.fig9_overall(samples)
    for row in fig9_rows:
        print(row)
    for row in pf.fig10_fixed(samples):
        print(row)
    for row in pf.fig12_analytical(samples):
        print(row)
    for row in pf.fig14_classifier(samples):
        print(row)
    for row in pf.table5_models(samples):
        print(row)
    for row in pf.search_overhead(samples):
        print(row)
    for row in dryrun_summary():
        print(row)
    print(f"# SUMMARY ours={summary['ours']:.3f}x "
          f"oracle={summary['oracle']:.3f}x "
          f"pct_of_oracle={summary['pct']:.1f}%")


if __name__ == "__main__":
    main()
